"""Batched data plane (paper §4.3): burst posting, burst progress, the
eager fast path, and the liveness/ordering guarantees that make batching
safe — doorbell splits preserve per-peer FIFO, the lock-free matching
probe never double-matches or drops, and burst signaling cannot wedge a
popper against a mid-ticket producer."""
import threading
import time

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # bare env: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (CommConfig, CommDesc, CommKind, HostMatchingEngine,
                        HostPacketPool, LocalCluster, MatchKind,
                        PostBatch, ThreadSafeCompletionQueue, done,
                        free_count, init_pool, make_key, pool_get,
                        pool_get_n, post_am_x, post_many, post_recv_x,
                        post_send_x)
from repro.core.completion import CompletionQueue
from repro.core.progress.fabric import (Fabric, PackedBurst, WireKind,
                                        WireMsg, payloads_to_bytes)
from repro.core.status import ErrorCode


# ---------------------------------------------------------------------------
# Fabric: drain semantics (satellite) + push_burst
# ---------------------------------------------------------------------------

class TestFabricBurst:
    def _msg(self, i=0, dst=1, dev=0):
        return WireMsg("eager_am", 0, dst, tag=i, device_index=dev)

    def test_drain_limit_zero_means_all(self):
        fab = Fabric(2)
        for i in range(5):
            assert fab.try_push(self._msg(i))
        assert [m.tag for m in fab.drain(1, 0, 0)] == [0, 1, 2, 3, 4]

    def test_drain_positive_limit_caps_burst(self):
        fab = Fabric(2)
        for i in range(5):
            fab.try_push(self._msg(i))
        assert [m.tag for m in fab.drain(1, 0, 2)] == [0, 1]
        assert [m.tag for m in fab.drain(1, 0, 3)] == [2, 3, 4]

    def test_drain_negative_limit_raises(self):
        fab = Fabric(2)
        with pytest.raises(ValueError):
            fab.drain(1, 0, -1)

    def test_push_burst_accepts_prefix_on_full(self):
        fab = Fabric(2, depth=3)
        msgs = [self._msg(i) for i in range(5)]
        assert fab.push_burst(msgs) == 3
        assert fab.full_events == 1
        assert [m.tag for m in fab.drain(1, 0)] == [0, 1, 2]
        assert fab.push_burst(msgs[3:]) == 2

    def test_push_burst_one_telemetry_bump(self):
        fab = Fabric(2)
        fab.push_burst([self._msg(i) for i in range(8)])
        assert fab.pushes == 8

    def test_push_burst_rejects_mixed_streams(self):
        fab = Fabric(3)
        with pytest.raises(Exception):
            fab.push_burst([self._msg(0, dst=1), self._msg(1, dst=2)])

    def test_payloads_to_bytes_one_stacked_copy(self):
        bufs = [np.full(8, i, np.uint8) for i in range(6)]
        rows = payloads_to_bytes(bufs)
        assert len(rows) == 6
        # rows are views of one stacked base — a single burst-sized copy
        base = rows[0].base
        assert base is not None and all(r.base is base for r in rows)
        # snapshots: mutating the source after staging must not leak in
        bufs[2][:] = 99
        assert rows[2][0] == 2

    def test_payloads_to_bytes_ragged_falls_back(self):
        rows = payloads_to_bytes([np.zeros(4, np.uint8),
                                  np.zeros(8, np.uint8)])
        assert [r.nbytes for r in rows] == [4, 8]


class TestPackedDrainConsistency:
    """Satellite regression: row-weighted ``stream_depth``, ``ready``,
    and ``drain(limit=k)`` must agree on "quiet" when packed doorbells
    sit in the stream — historically only scalar pushes were covered
    here, and ``drain`` counted doorbells as one row."""

    def _packed(self, k, tag=0):
        data = np.arange(k * 8, dtype=np.uint8).reshape(k, 8)
        return WireMsg(WireKind.EAGER_PACKED_AM, src=0, dst=1, tag=tag,
                       payload=PackedBurst(data, np.full(k, 8, np.int64),
                                           [tag] * k, k),
                       size=int(data.nbytes), rcomp=0)

    def _scalar(self, tag=0):
        return WireMsg(WireKind.EAGER_AM, src=0, dst=1, tag=tag,
                       payload=np.zeros(8, np.uint8), size=8, rcomp=0)

    def test_drain_limit_is_row_weighted(self):
        fab = Fabric(2, depth=64)
        assert fab.try_push(self._scalar(tag=0))
        assert fab.push_packed(self._packed(5, tag=1)) == 5
        assert fab.try_push(self._scalar(tag=2))
        assert fab.stream_depth(1, 0) == 7
        # limit=2 admits the scalar then the WHOLE doorbell (doorbells
        # pop atomically, so a limit may overshoot mid-doorbell) ...
        out = fab.drain(1, 0, 2)
        assert [m.kind for m in out] == [WireKind.EAGER_AM,
                                         WireKind.EAGER_PACKED_AM]
        # ... and the released weight is 6 rows, not 2 messages
        assert fab.stream_depth(1, 0) == 1
        assert [m.tag for m in fab.drain(1, 0)] == [2]

    def test_limit_below_doorbell_weight_still_pops_it_whole(self):
        fab = Fabric(2, depth=64)
        fab.push_packed(self._packed(6))
        out = fab.drain(1, 0, 1)
        assert len(out) == 1 and out[0].payload.count == 6
        assert fab.stream_depth(1, 0) == 0

    def test_depth_ready_and_drain_agree_on_quiet(self):
        fab = Fabric(2, depth=64)
        assert not fab.ready(1, 0) and fab.stream_depth(1, 0) == 0
        fab.push_packed(self._packed(4))
        # the idle fast path and the depth probe agree: occupied
        assert fab.ready(1, 0) and fab.stream_depth(1, 0) == 4
        assert fab.in_flight() == 4 and fab.pending_to(1) == 4
        assert len(fab.drain(1, 0, 4)) == 1
        # all three views agree again: quiet
        assert not fab.ready(1, 0)
        assert fab.stream_depth(1, 0) == 0
        assert fab.in_flight() == 0 and fab.pending_to(1) == 0
        assert fab.drain(1, 0) == []

    def test_partial_drain_keeps_views_consistent(self):
        fab = Fabric(2, depth=64)
        for t in range(3):
            fab.push_packed(self._packed(3, tag=t))
        assert fab.stream_depth(1, 0) == 9
        assert len(fab.drain(1, 0, 3)) == 1       # exactly one doorbell
        assert fab.stream_depth(1, 0) == 6 and fab.ready(1, 0)
        assert len(fab.drain(1, 0, 4)) == 2       # 3 < 4, next fills it
        assert fab.stream_depth(1, 0) == 0 and not fab.ready(1, 0)


# ---------------------------------------------------------------------------
# Packet pool: burst get/put (host + jittable)
# ---------------------------------------------------------------------------

class TestPoolBurst:
    def test_get_n_one_lock_round_trip(self):
        pool = HostPacketPool(n_lanes=1, packets_per_lane=32)
        base = pool.locks[0].acquisitions
        ids, stt = pool.get_n(0, 16)
        assert stt.is_done() and len(ids) == len(set(ids)) == 16
        assert pool.locks[0].acquisitions == base + 1
        pool.put_n(0, ids)
        assert pool.locks[0].acquisitions == base + 2
        assert pool.free_packets() == 32

    def test_get_n_short_grab_is_retry_with_prefix(self):
        pool = HostPacketPool(n_lanes=1, packets_per_lane=4)
        ids, stt = pool.get_n(0, 10)
        assert stt.is_retry() and stt.code == ErrorCode.RETRY_NOPACKET
        assert len(ids) == 4                      # the doorbell-split prefix
        ids2, st2 = pool.get_n(0, 2)
        assert st2.is_retry() and ids2 == []

    def test_get_n_steals_across_lanes(self):
        pool = HostPacketPool(n_lanes=2, packets_per_lane=8)
        ids, stt = pool.get_n(0, 10)              # needs the victim's half
        assert len(ids) >= 8 and pool.steals == 1

    def test_get_n_zero_is_noop(self):
        pool = HostPacketPool(n_lanes=1, packets_per_lane=4)
        assert pool.get_n(0, 0) == ([], pool.get_n(0, 0)[1])
        assert pool.free_packets() == 4

    def test_pool_get_n_matches_sequential_gets(self):
        import jax
        p1 = init_pool(2, 8)
        p2 = init_pool(2, 8)
        burst_fn = jax.jit(pool_get_n, static_argnums=2)
        p1, ids, got, stt = burst_fn(p1, 0, 5, 3)
        seq = []
        for _ in range(5):
            p2, pid, s2 = pool_get(p2, 0, 3)
            assert int(s2) == 0
            seq.append(int(pid))
        assert int(got) == 5 and int(stt) == 0
        assert [int(i) for i in ids] == seq
        assert int(free_count(p1)) == int(free_count(p2))

    def test_pool_get_n_short_grab_pads(self):
        p = init_pool(1, 4)
        p, ids, got, stt = pool_get_n(p, 0, 6, 0)
        assert int(got) == 4 and int(stt) == 1
        assert [int(i) for i in ids[4:]] == [-1, -1]
        assert int(free_count(p)) == 0

    def test_pool_get_n_steal_clamped_to_lane_room(self):
        """Regression: stealing into a NON-empty lane must clamp the
        transfer to the lane's remaining room — an unclamped roll wraps
        live slots past lane_cap, duplicating ids and losing others."""
        p = init_pool(2, 8, lane_cap=8)       # lane 0 full at cap
        p, ids, got, stt = pool_get_n(p, 0, 9, 0)
        taken = [int(i) for i in ids if int(i) >= 0]
        assert len(taken) == len(set(taken)) == int(got)
        # conservation: nothing duplicated, nothing lost
        assert int(free_count(p)) == 16 - int(got)
        remaining = {int(x) for x in np.asarray(p.slots).ravel() if x >= 0}
        assert remaining | set(taken) == set(range(16))
        assert remaining & set(taken) == set()


# ---------------------------------------------------------------------------
# Burst posting: doorbells, FIFO across splits, OFF batches
# ---------------------------------------------------------------------------

def _drain_tags(cq):
    tags = []
    while True:
        stt = cq.pop()
        if stt.is_retry():
            return tags
        tags.append(stt.tag)


class TestPostMany:
    def test_inject_burst_statuses_and_single_doorbell(self):
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64))
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        base_pushes = cl.fabric.pushes
        sts = r0.post_many([CommDesc(CommKind.AM, 1, np.zeros(8, np.uint8),
                                     tag=i, remote_comp=rc)
                            for i in range(16)])
        assert all(s.code == ErrorCode.DONE_INLINE for s in sts)
        assert cl.fabric.pushes == base_pushes + 16
        assert r0.engine.burst_posts == 1
        cl.quiesce()
        assert _drain_tags(cq) == list(range(16))

    def test_bufcopy_burst_amortizes_pool_locks(self):
        cfg = CommConfig(inject_max_bytes=1, packets_per_lane=64)
        cl = LocalCluster(2, cfg)
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        pool = r0.packet_pool
        base = sum(lk.acquisitions for lk in pool.locks)
        for _ in range(4):                        # 4 doorbells of 16
            r0.post_many([CommDesc(CommKind.AM, 1, np.zeros(8, np.uint8),
                                   remote_comp=rc) for _ in range(16)])
            cl.quiesce()
        acqs = sum(lk.acquisitions for lk in pool.locks) - base
        # scalar plane: 2 per message = 128; burst plane: 1 get_n + a few
        # batched put_n per doorbell
        assert acqs <= 16, acqs
        assert len(_drain_tags(cq)) == 64
        assert pool.free_packets() == pool.n_packets

    def test_doorbell_split_preserves_fifo_per_peer(self):
        """Mid-burst RETRY_NOPACKET splits the doorbell; re-posting the
        failed suffix must still deliver every peer's tags in post order
        (by_peer stripe: one stream per peer)."""
        cfg = CommConfig(inject_max_bytes=1, packets_per_lane=6,
                         n_channels=2)
        cl = LocalCluster(3, cfg)
        eps = cl.alloc_endpoint(n_devices=2, stripe="by_peer",
                                progress="shared")
        cqs = {r: cl[r].alloc_cq() for r in (1, 2)}
        rcs = {r: cl[r].register_rcomp(cqs[r]) for r in (1, 2)}
        # interleave 10 tagged messages per peer, bursts of 8, tiny pool
        # (6 packets/lane) so every doorbell splits mid-burst
        pending = [CommDesc(CommKind.AM, peer, np.zeros(8, np.uint8),
                            tag=t, remote_comp=rcs[peer])
                   for t in range(10) for peer in (1, 2)]
        sent_guard = 0
        while pending:
            sts = eps[0].post_many(pending[:8])
            accepted = sum(1 for s in sts if not s.is_retry())
            # prefix-accept: the statuses must never accept past a retry
            seen_retry = False
            for s in sts:
                if s.is_retry():
                    seen_retry = True
                else:
                    assert not seen_retry, "doorbell accepted past a retry"
            pending = pending[accepted:]
            cl.quiesce()
            sent_guard += 1
            assert sent_guard < 200, "burst posting made no progress"
        assert _drain_tags(cqs[1]) == list(range(10))
        assert _drain_tags(cqs[2]) == list(range(10))

    def test_round_robin_burst_rides_one_stream_and_rotates(self):
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64, n_channels=4))
        eps = cl.alloc_endpoint(n_devices=4, stripe="round_robin",
                                progress="dedicated")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        for burst in range(8):
            eps[0].post_am_many(1, [np.zeros(8, np.uint8)] * 4, rc,
                                tags=[burst * 4 + i for i in range(4)])
        # each doorbell landed whole on one device; bursts rotated
        assert [d.pushes for d in eps[0].devices] == [8, 8, 8, 8]
        cl.quiesce()
        # per-stream FIFO: receiver tag order within a stream == post order
        tags = _drain_tags(cq)
        assert sorted(tags) == list(range(32))
        per_burst = [tags[i:i + 4] for i in range(0, 32, 4)]
        assert all(b == sorted(b) for b in per_burst)

    def test_zerocopy_op_cuts_run_but_keeps_order(self):
        cfg = CommConfig(inject_max_bytes=8, bufcopy_max_bytes=64)
        cl = LocalCluster(2, cfg)
        r0, r1 = cl[0], cl[1]
        sync = r1.alloc_sync(expected=3)
        bufs = [np.zeros(128, np.uint8), np.zeros(8, np.uint8),
                np.zeros(8, np.uint8)]
        for i, b in enumerate(bufs):
            post_recv_x(r1, 0, b, None, i, sync)()
        sts = r0.post_many([
            CommDesc(CommKind.SEND, 1, np.full(8, 1, np.uint8), tag=1),
            CommDesc(CommKind.SEND, 1, np.full(128, 9, np.uint8), tag=0),
            CommDesc(CommKind.SEND, 1, np.full(8, 2, np.uint8), tag=2),
        ])
        assert not any(s.is_retry() for s in sts)
        cl.quiesce()
        ok, _ = sync.test()
        assert ok
        assert bufs[0][0] == 9 and bufs[1][0] == 1 and bufs[2][0] == 2

    def test_off_batch_spelling(self):
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64))
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        b = post_am_x(r0, 1, np.zeros(8, np.uint8), None, None,
                      rc).tag(0).batch()
        assert isinstance(b, PostBatch) and len(b) == 1
        post_am_x(r0, 1, np.zeros(8, np.uint8), None, None,
                  rc).tag(1).batch(b)
        sts = b.flush()
        assert len(sts) == 2 and len(b) == 0      # reusable after flush
        cl.quiesce()
        assert _drain_tags(cq) == [0, 1]

    def test_post_batch_rejects_non_post_builders(self):
        from repro.core import progress_x
        cl = LocalCluster(1)
        with pytest.raises(Exception):
            progress_x(cl[0]).batch().flush()

    def test_post_many_endpoint_of_other_rank_raises(self):
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64))
        eps = cl.alloc_endpoint(n_devices=1)
        with pytest.raises(Exception):
            post_many(cl[0], [CommDesc(CommKind.SEND, 1,
                                       np.zeros(4, np.uint8))],
                      endpoint=eps[1])


# ---------------------------------------------------------------------------
# Matching: lock-free probe-before-lock fast path (satellite hypothesis)
# ---------------------------------------------------------------------------

class TestMatchingFastPath:
    def test_fast_path_skips_bucket_lock(self):
        eng = HostMatchingEngine()
        key = make_key(0, 7)
        eng.insert(key, MatchKind.RECV, ("recv", None, None, None))
        lock = eng._lock_of(key)
        base = lock.acquisitions
        assert eng.match_now(key, MatchKind.SEND) is not None
        assert lock.acquisitions == base          # no lock taken
        assert eng.fast_matches == 1

    def test_fast_path_miss_returns_none_and_stores_nothing(self):
        eng = HostMatchingEngine()
        assert eng.match_now(make_key(0, 1), MatchKind.SEND) is None
        assert eng.pending() == 0

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 24), st.integers(0, 2 ** 31 - 1))
    def test_concurrent_recv_vs_deliver_never_double_or_drop(
            self, n_msgs, seed):
        """Posted recvs race eager deliveries on the same key: every
        delivery matches at most one recv, every recv is consumed at most
        once, and nothing is lost — matched + leftover always adds up."""
        rng = np.random.default_rng(seed)
        eng = HostMatchingEngine()
        key = make_key(0, 3)
        deliverer_got = []            # recvs consumed by deliveries
        receiver_got = []             # stored sends consumed by post_recv
        barrier = threading.Barrier(2)

        def receiver():
            barrier.wait()
            for i in range(n_msgs):
                if rng.integers(2):
                    time.sleep(0)
                m = eng.insert(key, MatchKind.RECV, ("recv", i))
                if m is not None:
                    receiver_got.append(m)

        def deliverer():
            barrier.wait()
            for j in range(n_msgs):
                # the engine's delivery discipline: lock-free probe first,
                # locked insert fallback
                m = eng.match_now(key, MatchKind.SEND)
                if m is None:
                    m = eng.insert(key, MatchKind.SEND, ("eager", j))
                if m is not None:
                    deliverer_got.append(m)

        ts = [threading.Thread(target=receiver),
              threading.Thread(target=deliverer)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in ts)
        # drain leftovers single-threaded
        leftover_recvs, leftover_sends = [], []
        while True:
            m = eng.match_now(key, MatchKind.SEND)
            if m is None:
                break
            leftover_recvs.append(m)
        while True:
            m = eng.match_now(key, MatchKind.RECV)
            if m is None:
                break
            leftover_sends.append(m)
        assert eng.pending() == 0
        assert all(m[0] == "recv" for m in deliverer_got + leftover_recvs)
        assert all(m[0] == "eager" for m in receiver_got + leftover_sends)
        # never double-matched: every recv / send consumed exactly once
        recv_ids = [m[1] for m in deliverer_got + leftover_recvs]
        send_ids = [m[1] for m in receiver_got + leftover_sends]
        assert sorted(set(recv_ids)) == sorted(recv_ids)
        assert sorted(set(send_ids)) == sorted(send_ids)
        # never dropped: every recv and every send is accounted for
        assert (len(deliverer_got) + len(receiver_got)
                + len(leftover_recvs) == n_msgs)
        assert (len(deliverer_got) + len(receiver_got)
                + len(leftover_sends) == n_msgs)


# ---------------------------------------------------------------------------
# signal_many: prefix-accept + backlog redelivery order
# ---------------------------------------------------------------------------

class TestSignalMany:
    def test_cq_signal_many_prefix_accepts(self):
        cq = CompletionQueue(capacity=3)
        sts = cq.signal_many([done(tag=i) for i in range(5)])
        assert [s.is_done() for s in sts] == [True] * 3 + [False] * 2
        assert sts[3].code == ErrorCode.RETRY_QUEUE_FULL
        assert [cq.pop().tag for _ in range(3)] == [0, 1, 2]

    def test_tscq_signal_many_prefix_accepts(self):
        cq = ThreadSafeCompletionQueue(capacity=2)
        sts = cq.signal_many([done(tag=i) for i in range(4)])
        assert [s.is_done() for s in sts] == [True, True, False, False]
        assert cq.pop().tag == 0 and cq.pop().tag == 1

    def test_mixed_drain_keeps_per_comp_wire_order(self):
        """Regression: a drain holding an eager AM then a PUT-with-signal
        to the SAME comp must deliver in wire order — the eager signal
        batch flushes before any immediate rendezvous/RMA signal."""
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64))
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        target = np.zeros(8, np.uint8)
        region = r1.register_memory(target)
        from repro.core import post_put_x
        post_am_x(r0, 1, np.zeros(8, np.uint8), None, None, rc).tag(1)()
        post_put_x(r0, 1, np.full(8, 5, np.uint8), (region.rid, 0), 8,
                   None, rc).tag(2)()
        # both messages sit in one stream; a single pass drains both
        r1.progress(r1.default_device)
        cl.quiesce()
        tags = _drain_tags(cq)
        assert tags == [1, 2], tags

    def test_engine_parks_rejected_burst_in_order(self):
        """A full CQ rejects the burst's tail; the backlog must redeliver
        it in order once the client drains."""
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64))
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq(capacity=4)
        rc = r1.register_rcomp(cq)
        r0.post_many([CommDesc(CommKind.AM, 1, np.zeros(8, np.uint8),
                               tag=i, remote_comp=rc) for i in range(10)])
        tags = []
        guard = 0
        while len(tags) < 10:
            cl.progress_all()
            tags.extend(_drain_tags(cq))
            guard += 1
            assert guard < 100
        assert tags == list(range(10))


# ---------------------------------------------------------------------------
# TSCQ liveness under burst signaling (satellite bugfix)
# ---------------------------------------------------------------------------

class TestTscqSpinBound:
    def test_wait_yields_against_mid_ticket_producer(self):
        """A producer that claimed a ticket but has not published makes
        len() > 0 while pop() fails; wait() must bounded-spin then yield
        (not busy-spin) until the slow producer publishes."""
        cq = ThreadSafeCompletionQueue()
        q = cq._q
        # simulate the descheduled producer: claim ticket 0, do NOT publish
        assert q._tail.compare_exchange(0, 1)
        assert len(cq) == 1                       # looks non-empty
        assert cq.pop().is_retry()                # but nothing published
        result = []

        def consumer():
            result.append(cq.wait(progress=None))

        t = threading.Thread(target=consumer, daemon=True)
        t.start()
        time.sleep(0.08)                          # consumer is in wait()
        assert t.is_alive()
        slot = q._slots[0]                        # producer finally publishes
        slot.data = done(tag=42)
        slot.seq = 1
        t.join(timeout=10)
        assert not t.is_alive()
        assert result and result[0].tag == 42
        # the spin bound engaged: the popper yielded instead of pegging
        assert cq.pop_yields > 0

    def test_wait_with_progress_driver_still_completes(self):
        cq = ThreadSafeCompletionQueue()
        cq.signal(done(tag=1))
        assert cq.wait(progress=lambda: None).tag == 1


# ---------------------------------------------------------------------------
# Burst progress: one try-lock acquisition drains a bounded burst
# ---------------------------------------------------------------------------

class TestBurstProgress:
    def test_bounded_drain_leaves_remainder(self):
        # scalar data plane: max_msgs bounds delivered completions 1:1
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64),
                          attrs={"doorbell_fused": False})
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        r0.post_many([CommDesc(CommKind.AM, 1, np.zeros(8, np.uint8),
                               tag=i, remote_comp=rc) for i in range(10)])
        dev = r1.default_device
        r1.engine.progress(dev, max_msgs=4)
        assert len(cq) == 4
        r1.engine.progress(dev, max_msgs=4)
        assert len(cq) == 8
        cl.quiesce()
        assert _drain_tags(cq) == list(range(10))

    def test_bounded_drain_counts_packed_doorbell_once(self):
        # fused data plane: the whole doorbell is ONE wire message, so a
        # drain limit admits all of its rows in one pass (DESIGN.md §13)
        cl = LocalCluster(2, CommConfig(inject_max_bytes=64),
                          attrs={"doorbell_fused": True})
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        r0.post_many([CommDesc(CommKind.AM, 1, np.zeros(8, np.uint8),
                               tag=i, remote_comp=rc) for i in range(10)])
        r1.engine.progress(r1.default_device, max_msgs=4)
        assert len(cq) == 10
        cl.quiesce()
        assert _drain_tags(cq) == list(range(10))

    def test_worker_pool_burst_knob(self):
        from repro.core import ProgressWorkerPool, resolve_one
        cl = LocalCluster(1)
        pool = ProgressWorkerPool.for_runtime(cl[0], n_workers=1)
        # the default resolves through the attribute chain (library
        # default 64, REPRO_ATTR_WORKER_BURST honored)
        assert pool.burst == resolve_one("worker_burst")
        assert pool.counters()["burst"] == pool.burst
        explicit = ProgressWorkerPool.for_runtime(cl[0], n_workers=1,
                                                  burst=16)
        assert explicit.burst == 16
        with pytest.raises(Exception):
            ProgressWorkerPool([(cl[0].engine, cl[0].default_device)],
                               burst=-1)
