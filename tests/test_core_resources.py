"""Unit + property tests for the LCI-X core resources (paper §4.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # bare env: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (BacklogQueue, CompletionGraph, CompletionHandler,
                        CompletionQueue, ErrorCode, FatalError,
                        HostMatchingEngine, HostPacketPool, MatchKind,
                        MatchingPolicy, MPMCArray, Synchronizer, done,
                        encode_key, free_count, init_pool, init_ring,
                        init_table, insert, insert_batch, make_key,
                        pending_count, pool_get, pool_put, retry, ring_pop,
                        ring_push, ring_size)
from repro.core.post import CommKind, Direction, classify
from repro.core.off import off


# ---------------------------------------------------------------------------
# packet pool (paper §4.1.2)
# ---------------------------------------------------------------------------

class TestHostPacketPool:
    def test_local_get_put(self):
        pool = HostPacketPool(n_lanes=2, packets_per_lane=4)
        pid, stt = pool.get(0)
        assert stt.is_done() and 0 <= pid < 8
        assert pool.put(0, pid).is_done()
        assert pool.free_packets() == 8

    def test_steal_half(self):
        pool = HostPacketPool(n_lanes=2, packets_per_lane=4, seed=1)
        got = [pool.get(0)[0] for _ in range(4)]        # drain lane 0
        pid, stt = pool.get(0)                          # must steal from 1
        assert stt.is_done() and pid >= 4
        assert pool.steals == 1

    def test_exhaustion_retry(self):
        pool = HostPacketPool(n_lanes=1, packets_per_lane=2)
        pool.get(0)
        pool.get(0)
        pid, stt = pool.get(0)
        assert pid == -1 and stt.is_retry()
        assert stt.code == ErrorCode.RETRY_NOPACKET

    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 3)),
                    max_size=200))
    @settings(max_examples=30, deadline=None)
    def test_conservation(self, ops):
        """No packet is ever lost or duplicated."""
        pool = HostPacketPool(n_lanes=4, packets_per_lane=4)
        held = []
        for is_get, lane in ops:
            if is_get:
                pid, stt = pool.get(lane)
                if stt.is_done():
                    held.append((lane, pid))
            elif held:
                lane0, pid = held.pop()
                pool.put(lane0, pid)
        assert pool.free_packets() + len(held) == 16
        live = [p for _, p in held]
        assert len(set(live)) == len(live)              # no duplicates


class TestFunctionalPool:
    def test_get_put_roundtrip(self):
        pool = init_pool(n_lanes=2, packets_per_lane=3)
        pool, pid, stt = jax.jit(pool_get)(pool, 0, 0)
        assert int(stt) == 0 and 0 <= int(pid) < 6
        pool, stt2 = jax.jit(pool_put)(pool, 0, pid)
        assert int(stt2) == 0
        assert int(free_count(pool)) == 6

    def test_steal_then_retry(self):
        pool = init_pool(n_lanes=2, packets_per_lane=2)
        for _ in range(2):                              # drain lane 0
            pool, pid, stt = pool_get(pool, 0, 0)
            assert int(stt) == 0
        pool, pid, stt = pool_get(pool, 0, 0)           # steals from lane 1
        assert int(stt) == 0 and int(pid) >= 2
        # drain the rest then expect retry
        pool, _, s1 = pool_get(pool, 0, 0)
        pool, _, s2 = pool_get(pool, 1, 0)
        pool, pid, s3 = pool_get(pool, 0, 0)
        assert int(s3) == 1 and int(pid) == -1

    @given(st.lists(st.tuples(st.booleans(), st.integers(0, 2)),
                    max_size=60))
    @settings(max_examples=20, deadline=None)
    def test_functional_conservation(self, ops):
        pool = init_pool(n_lanes=3, packets_per_lane=3)
        held = []
        for i, (is_get, lane) in enumerate(ops):
            if is_get:
                pool, pid, stt = pool_get(pool, lane, i)
                if int(stt) == 0:
                    held.append((lane, int(pid)))
            elif held:
                lane0, pid = held.pop()
                pool, _ = pool_put(pool, lane0, pid)
        assert int(free_count(pool)) + len(held) == 9
        live = [p for _, p in held]
        assert len(set(live)) == len(live)


# ---------------------------------------------------------------------------
# matching engine (paper §4.1.3 / §3.3.2)
# ---------------------------------------------------------------------------

class TestMatchingEngine:
    def test_send_then_recv(self):
        me = HostMatchingEngine()
        assert me.insert(make_key(0, 5), MatchKind.SEND, "payload") is None
        assert me.insert(make_key(0, 5), MatchKind.RECV, "buf") == "payload"
        assert me.pending() == 0

    def test_fifo_within_key(self):
        me = HostMatchingEngine()
        me.insert(make_key(1, 1), MatchKind.SEND, "a")
        me.insert(make_key(1, 1), MatchKind.SEND, "b")
        assert me.insert(make_key(1, 1), MatchKind.RECV, None) == "a"
        assert me.insert(make_key(1, 1), MatchKind.RECV, None) == "b"

    def test_wildcard_policies(self):
        k_send = make_key(3, 7, MatchingPolicy.RANK_ONLY)
        k_recv = make_key(3, 99, MatchingPolicy.RANK_ONLY)
        assert k_send == k_recv                         # tag wildcarded
        assert make_key(3, 7, MatchingPolicy.TAG_ONLY) == \
            make_key(55, 7, MatchingPolicy.TAG_ONLY)

    def test_custom_make_key(self):
        key = make_key(3, 7, custom=lambda r, t: r * 1000 + t)
        assert key == 3007

    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)),
                    max_size=80))
    @settings(max_examples=30, deadline=None)
    def test_match_conservation(self, pairs):
        """#matches == min(#sends, #recvs) per key; nothing vanishes."""
        me = HostMatchingEngine()
        from collections import Counter
        sends, recvs, matched = Counter(), Counter(), 0
        for i, (rank, tag) in enumerate(pairs):
            kind = MatchKind.SEND if i % 2 else MatchKind.RECV
            key = make_key(rank, tag)
            if me.insert(key, kind, i) is not None:
                matched += 1
            (sends if kind == MatchKind.SEND else recvs)[key] += 1
        expected = sum(min(sends[k], recvs[k])
                       for k in set(sends) | set(recvs))
        assert matched == expected
        assert me.pending() == sum(sends.values()) + sum(recvs.values()) \
            - 2 * matched

    def test_functional_engine_matches(self):
        table = init_table(n_buckets=64, bucket_cap=4)
        k = encode_key(2, 9)
        table, m1, s1 = insert(table, k, MatchKind.SEND, jnp.int32(42))
        assert int(m1) == -1 and int(s1) == 0
        table, m2, s2 = insert(table, k, MatchKind.RECV, jnp.int32(7))
        assert int(m2) == 42 and int(s2) == 1
        assert int(pending_count(table)) == 0

    def test_functional_bucket_overflow(self):
        table = init_table(n_buckets=1, bucket_cap=2)
        k1, k2, k3 = (encode_key(i, 0) for i in range(1, 4))
        table, _, s1 = insert(table, k1, MatchKind.SEND, jnp.int32(1))
        table, _, s2 = insert(table, k2, MatchKind.SEND, jnp.int32(2))
        table, _, s3 = insert(table, k3, MatchKind.SEND, jnp.int32(3))
        assert int(s3) == 2                              # bucket full: retry

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 2),
                              st.booleans()), min_size=1, max_size=24))
    @settings(max_examples=20, deadline=None)
    def test_functional_vs_host(self, ops):
        """The in-graph engine agrees with the host engine on match counts."""
        table = init_table(n_buckets=128, bucket_cap=24)
        me = HostMatchingEngine()
        f_matches = h_matches = 0
        for i, (rank, tag, is_send) in enumerate(ops):
            kind = MatchKind.SEND if is_send else MatchKind.RECV
            table, m, s = insert(table, encode_key(rank, tag), kind,
                                 jnp.int32(i))
            f_matches += int(m) != -1
            h_matches += me.insert(make_key(rank, tag), kind, i) is not None
        assert f_matches == h_matches


# ---------------------------------------------------------------------------
# backlog / ring (paper §4.1.5)
# ---------------------------------------------------------------------------

class TestBacklogAndRing:
    def test_backlog_fifo_and_flag(self):
        bq = BacklogQueue()
        assert bq.empty_flag
        bq.push("a")
        bq.push("b")
        assert not bq.empty_flag
        assert bq.pop()[0] == "a"
        assert bq.pop()[0] == "b"
        assert bq.pop()[1].is_retry()

    def test_backlog_capacity(self):
        bq = BacklogQueue(capacity=1)
        assert bq.push(1).is_done()
        assert bq.push(2).is_retry()

    @given(st.lists(st.booleans(), max_size=100))
    @settings(max_examples=30, deadline=None)
    def test_ring_fifo_property(self, ops):
        ring = init_ring(cap=8, width=1)
        model = []
        pushed = 0
        for is_push in ops:
            if is_push:
                ring, stt = ring_push(ring, [pushed])
                if int(stt) == 0:
                    model.append(pushed)
                pushed += 1
            else:
                ring, rec, stt = ring_pop(ring)
                if int(stt) == 0:
                    assert model and int(rec[0]) == model.pop(0)
                else:
                    assert not model
        assert int(ring_size(ring)) == len(model)


# ---------------------------------------------------------------------------
# completion objects (paper §4.1.4) + MPMC array (§4.1.1)
# ---------------------------------------------------------------------------

class TestCompletion:
    def test_handler(self):
        seen = []
        h = CompletionHandler(seen.append)
        h.signal(done(1))
        assert len(seen) == 1 and h.signals == 1

    def test_queue_capacity_retry(self):
        cq = CompletionQueue(capacity=1)
        assert cq.signal(done(1)).is_done()
        assert cq.signal(done(2)).is_retry()
        assert cq.pop().is_done()
        assert cq.pop().is_retry()

    def test_synchronizer_multi_signal(self):
        sy = Synchronizer(expected=3)
        for i in range(3):
            assert not sy.ready
            sy.signal(done(i))
        assert sy.ready
        ok, payloads = sy.test()
        assert ok and len(payloads) == 3
        with pytest.raises(FatalError):
            sy.signal(done(9))

    def test_mpmc_array_growth(self):
        arr = MPMCArray(initial_cap=2)
        idxs = [arr.append(i) for i in range(20)]
        assert idxs == list(range(20))
        assert arr.resizes >= 3                          # doubled repeatedly
        assert arr[7] == 7
        with pytest.raises(FatalError):
            _ = arr[25]


# ---------------------------------------------------------------------------
# completion graph (paper §3.2.5)
# ---------------------------------------------------------------------------

class TestCompletionGraph:
    def test_partial_order_and_values(self):
        g = CompletionGraph()
        a = g.add_node(lambda: 2)
        b = g.add_node(lambda: 3)
        c = g.add_node(lambda x, y: x * y, deps=[a, b])
        d = g.add_node(lambda z: z + 1, deps=[c])
        vals = g.execute()
        assert vals[d] == 7
        g.assert_partial_order()
        assert g.critical_path_len() == 3

    def test_diamond_fires_once(self):
        fired = []
        g = CompletionGraph()
        a = g.add_node(lambda: fired.append("a") or 1)
        b = g.add_node(lambda x: fired.append("b") or x, deps=[a])
        c = g.add_node(lambda x: fired.append("c") or x, deps=[a])
        d = g.add_node(lambda x, y: fired.append("d") or x + y, deps=[b, c])
        g.execute()
        assert sorted(fired) == ["a", "b", "c", "d"]
        assert fired[0] == "a" and fired[-1] == "d"

    def test_bad_edges_rejected_at_insertion(self):
        g = CompletionGraph()
        a = g.add_node(lambda: 1)
        b = g.add_node(lambda x: x, deps=[a])
        with pytest.raises(FatalError):                  # backward => cycle
            g.add_edge(b, a)
        with pytest.raises(FatalError):                  # self-edge
            g.add_edge(a, a)
        with pytest.raises(FatalError):                  # duplicate of a dep
            g.add_edge(a, b)
        with pytest.raises(FatalError):                  # unknown node
            g.add_edge(a, 99)
        g.execute()                                      # graph still valid


# ---------------------------------------------------------------------------
# OFF idiom (§3.1) + Table 1 (§3.2.4)
# ---------------------------------------------------------------------------

class TestOffAndTable1:
    def test_off_any_order(self):
        calls = []

        @off
        def op(a, b, *, opt1=0, opt2="x"):
            calls.append((a, b, opt1, opt2))
            return len(calls)

        assert op.x(1, 2).opt2("y").opt1(5)() == 1
        assert op.x(1, 2).opt1(5).opt2("y")() == 2
        assert calls[0] == calls[1] == (1, 2, 5, "y")

    def test_off_unknown_option(self):
        @off
        def op(a, *, known=0):
            return a

        with pytest.raises(TypeError):
            op.x(1).unknown(2)

    @pytest.mark.parametrize("direction,rbuf,rcomp,expect", [
        (Direction.OUT, None, None, CommKind.SEND),
        (Direction.OUT, None, 1, CommKind.AM),
        (Direction.OUT, "buf", None, CommKind.PUT),
        (Direction.OUT, "buf", 1, CommKind.PUT_SIGNAL),
        (Direction.IN, None, None, CommKind.RECV),
        (Direction.IN, "buf", None, CommKind.GET),
    ])
    def test_table1_valid_rows(self, direction, rbuf, rcomp, expect):
        assert classify(direction, rbuf, rcomp) == expect

    def test_table1_invalid_row(self):
        with pytest.raises(FatalError):
            classify(Direction.IN, None, 1)

    def test_get_with_signal_unimplemented(self):
        with pytest.raises(NotImplementedError):
            classify(Direction.IN, "buf", 1)
