"""The continuous-batching serving subsystem (DESIGN.md §17).

Covers the packed ResultTokens layout, slot/page admission through the
attr chain (validation at alloc, ``get_attr`` introspection), the
engine's end-to-end exactly-once token contract — including the
hypothesis property over interleaved prefill-insert/decode/drain with
thread-safe CQs, two drain workers, and ``chaos_drop`` faults — plus the
burst result-delivery path in the legacy scheduler and the coalescing
socket flush (satellites of the same PR).
"""
import errno
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # bare env: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import attrs as A
from repro.core.runtime import LocalCluster
from repro.core.status import FatalError, done, retry
from repro.core.transport.socket import SocketTransport
from repro.core.transport.wire import WireKind, WireMsg
from repro.serving import (ContinuousBatcher, PagedKVAllocator, ResultDrain,
                           ResultTokens, ServePlane, ServeScheduler,
                           ServeTransport, SlotAllocator, SlotData,
                           SyntheticModel, TokenClient, decode_token_row,
                           encode_token_row)
from repro.serving.batching import EOT_MAX_NEW
from repro.serving.slots import SERVING_ATTRS


# ---------------------------------------------------------------------------
# ResultTokens: the packed per-step array
# ---------------------------------------------------------------------------

class TestResultTokens:
    def test_pack_and_slot_views(self):
        rt = ResultTokens.pack(slots=[0, 2], rids=[7, 9],
                               tokens=[11, 13], lengths=[1, 4],
                               dones=[0, 1], n_slots=4)
        assert rt.n_slots == 4
        assert list(rt.active_slots()) == [0, 2]
        s2 = rt.get_result_at_slot(2)
        assert isinstance(s2, SlotData)
        assert s2.tokens[0] == 13 and s2.valid[0] == 1 and s2.lengths[0] == 4
        assert rt.get_result_at_slot(1).valid[0] == 0

    def test_wire_rows_roundtrip(self):
        rt = ResultTokens.pack(slots=[1, 3], rids=[5, 6],
                               tokens=[100, 200], lengths=[3, 1],
                               dones=[1, 0], n_slots=4)
        rows = rt.wire_rows()
        assert [rid for rid, _ in rows] == [5, 6]
        # row = [rid, seq, token, done]; seq == length - 1
        assert decode_token_row(rows[0][1]) == (5, 2, 100, 1)
        assert decode_token_row(rows[1][1]) == (6, 0, 200, 0)
        # uniform 16-byte rows: the fused-doorbell eligibility contract
        assert {r.nbytes for _, r in rows} == {16}

    def test_rejects_bad_shape_and_row(self):
        with pytest.raises(ValueError):
            ResultTokens(np.zeros((4, 3), np.int32))
        with pytest.raises(ValueError):
            decode_token_row(b"\x00" * 12)
        assert decode_token_row(encode_token_row(1, 2, 3, 1)) == (1, 2, 3, 1)


# ---------------------------------------------------------------------------
# slot allocator: admission through the attr chain
# ---------------------------------------------------------------------------

class TestSlotAllocator:
    def test_attrs_validate_at_alloc(self):
        with pytest.raises(A.AttrError, match="kv_slots"):
            SlotAllocator(kv_slots=0)
        with pytest.raises(A.AttrError, match="kv_page_tokens"):
            SlotAllocator(kv_page_tokens=-1)
        with pytest.raises(A.AttrError, match="kv_evict"):
            SlotAllocator(kv_evict="lru")

    def test_env_layer_reaches_allocator(self, monkeypatch):
        monkeypatch.setenv("REPRO_ATTR_KV_SLOTS", "3")
        monkeypatch.setenv("REPRO_ATTR_KV_EVICT", "preempt_longest")
        sa = SlotAllocator()
        assert sa.n_slots == 3
        assert sa.evict_policy == "preempt_longest"
        assert sa.get_attr("kv_slots") == 3
        monkeypatch.setenv("REPRO_ATTR_KV_EVICT", "bogus")
        with pytest.raises(A.AttrError, match="kv_evict"):
            SlotAllocator()

    def test_get_attr_surface(self):
        sa = SlotAllocator(kv_slots=2, kv_page_tokens=4, kv_pages=6)
        assert sa.get_attr("kv_pages") == 6
        assert sa.get_attr("free_slots") == 2
        assert sa.get_attr("occupancy") == 0.0
        echo = sa.attrs_echo()
        assert echo["values"]["kv_slots"] == 2
        assert echo["sources"]["kv_slots"] == "resource"
        assert echo["sources"]["kv_evict"] == "default"
        assert echo["sources"]["occupancy"] == "discovered"
        with pytest.raises(A.AttrError, match="nope"):
            sa.get_attr("nope")

    def test_admission_is_ternary_and_all_or_nothing(self):
        sa = SlotAllocator(kv_slots=2, kv_page_tokens=4, kv_pages=4)
        st = sa.admit(1, 8)             # 2 pages
        assert st.is_done() and st.value == 0
        assert sa.admit(2, 9).is_retry()   # needs 3 pages, 2 left
        assert sa.get_attr("free_pages") == 2   # rollback left them free
        assert sa.admit(2, 8).is_done()
        assert sa.admit(3, 4).is_retry()   # no slot left
        with pytest.raises(ValueError):
            sa.admit(1, 4)                  # double admit
        sa.release(1)
        assert sa.occupancy() == 0.5
        assert sa.admit(3, 4).is_done()
        assert sa.counters()["rejections"] == 2

    def test_victim_is_largest_footprint(self):
        sa = SlotAllocator(kv_slots=4, kv_page_tokens=4,
                           kv_evict="preempt_longest")
        for rid, tokens in ((1, 4), (2, 20), (3, 8)):
            assert sa.admit(rid, tokens).is_done()
        assert sa.victim() == 2
        refuse = SlotAllocator(kv_slots=4, kv_page_tokens=4)
        refuse.admit(1, 20)
        assert refuse.victim() is None     # policy "refuse" never evicts


# ---------------------------------------------------------------------------
# the engine end to end (single process, both roles on one cluster)
# ---------------------------------------------------------------------------

def _drive(server, client, specs, *, step_every=1, deadline_s=30.0):
    """Submit (prompt_len, max_new) specs open-loop and drain to empty."""
    rng = np.random.default_rng(1234)
    for i, (plen, max_new) in enumerate(specs):
        prompt = rng.integers(0, 1000, plen).astype(np.int32)
        rid, stat = client.submit(prompt, max_new)
        tries = 0
        while stat.is_retry():
            client.pump()
            server.step()
            tries += 1
            assert tries < 2000, "submit never accepted"
            rid, stat = client.submit(prompt, max_new, rid=rid)
        if i % step_every == 0:
            server.step()
    # an accepted prompt may still be in retransmit flight under chaos —
    # the server must keep stepping until it has *finished* every one
    t0 = time.monotonic()
    while not (server.completed >= len(specs) and server.idle):
        server.step()
        assert time.monotonic() - t0 < deadline_s, (
            f"server stalled: {server.counters()}")
    while client.drain.drained < client.expected_tokens:
        client.pump()
        if time.monotonic() - t0 > deadline_s:
            break
    return client.collect()


def _assert_exactly_once(report, n_requests):
    assert report["completed"] == n_requests
    assert report["lost"] == 0
    assert report["duplicated"] == 0
    assert report["mismatched"] == 0
    assert report["out_of_order"] == 0
    assert report["bad_done"] == 0
    assert report["unexpected"] == 0


class TestContinuousBatcher:
    def test_serve_roundtrip_exactly_once(self):
        cluster = LocalCluster(2)
        try:
            plane = ServePlane(cluster)
            model = SyntheticModel(seed=7)
            server = ContinuousBatcher(plane, model, kv_slots=4,
                                       kv_page_tokens=8, prefill_chunk=16)
            client = TokenClient(plane, model, drain_workers=2)
            specs = [(30, 8), (1, 1), (64, 4), (5, 12), (17, 3),
                     (40, 6), (2, 9), (33, 1)]
            report = _drive(server, client, specs)
            _assert_exactly_once(report, len(specs))
            assert report["tokens"] == sum(m for _, m in specs)
            assert len(report["ttft_s"]) == len(specs)
            assert server.slots.occupancy() == 0.0
        finally:
            cluster.close()

    def test_engine_attr_chain_and_introspection(self):
        cluster = LocalCluster(2, attrs={"kv_slots": 6, "prefill_chunk": 4})
        try:
            plane = ServePlane(cluster)
            server = ContinuousBatcher(plane, SyntheticModel(),
                                       max_batch=5)
            # runtime-config layer reached the engine; override beat it
            assert server.get_attr("kv_slots") == 6
            assert server.get_attr("prefill_chunk") == 4
            assert server.get_attr("max_batch") == 5
            for name in SERVING_ATTRS:
                server.get_attr(name)          # every serving attr answers
            assert server.get_attr("active_requests") == 0
            assert server.get_attr("occupancy") == 0.0
            echo = server.attrs_echo()
            assert echo["sources"]["kv_slots"] == "runtime"
            assert echo["sources"]["max_batch"] == "resource"
            with pytest.raises(A.AttrError, match="kv_page_tokens"):
                ContinuousBatcher(plane, SyntheticModel(), kv_page_tokens=0)
        finally:
            cluster.close()

    def test_zero_means_derived_geometry(self):
        cluster = LocalCluster(2)
        try:
            plane = ServePlane(cluster)
            server = ContinuousBatcher(plane, SyntheticModel(), kv_slots=3)
            assert server.slots.n_pages == 24      # kv_pages=0 -> 8/slot
            assert server.max_batch == 3           # max_batch=0 -> kv_slots
        finally:
            cluster.close()

    def test_preempt_longest_never_duplicates(self):
        cluster = LocalCluster(2)
        try:
            plane = ServePlane(cluster)
            model = SyntheticModel(seed=2)
            # 6 pages of 2 tokens: one long request hogs the pool until
            # admission preempts it for the short ones
            server = ContinuousBatcher(plane, model, kv_slots=3,
                                       kv_page_tokens=2, kv_pages=6,
                                       kv_evict="preempt_longest",
                                       prefill_chunk=4)
            client = TokenClient(plane, model, drain_workers=2)
            specs = [(4, 6), (2, 2), (2, 2), (1, 3), (2, 1)]
            report = _drive(server, client, specs, step_every=2,
                            deadline_s=40.0)
            _assert_exactly_once(report, len(specs))
            assert server.slots.preemptions > 0
        finally:
            cluster.close()

    def test_refuse_policy_backlogs_instead(self):
        cluster = LocalCluster(2)
        try:
            plane = ServePlane(cluster)
            model = SyntheticModel(seed=4)
            server = ContinuousBatcher(plane, model, kv_slots=1,
                                       kv_page_tokens=4)
            client = TokenClient(plane, model, drain_workers=2)
            specs = [(8, 4)] * 5
            report = _drive(server, client, specs)
            _assert_exactly_once(report, len(specs))
            assert server.slots.preemptions == 0
            assert server.counters()["backlog_max_depth"] > 0
        finally:
            cluster.close()

    def test_plane_requires_distinct_ranks_and_first_rcomp(self):
        cluster = LocalCluster(2)
        try:
            with pytest.raises(FatalError, match="distinct"):
                ServePlane(cluster, client_rank=0, server_rank=0)
            # steal handle 0 on the server runtime: the handshake
            # convention must fail loudly, not deliver to the wrong CQ
            cluster[1].register_rcomp(cluster[1].alloc_cq())
            with pytest.raises(FatalError, match="first"):
                ServePlane(cluster)
        finally:
            cluster.close()


# ---------------------------------------------------------------------------
# satellite: the exactly-once property under interleaving + chaos
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=1, max_value=24),
                          st.integers(min_value=1, max_value=8)),
                min_size=1, max_size=10),
       st.integers(min_value=1, max_value=4),
       st.booleans())
def test_property_interleaved_serve_exactly_once(specs, step_every, chaos):
    """Interleaved prefill-insert/decode/drain with thread-safe CQs and 2
    drain workers never drops, duplicates, or reorders a client's token
    stream — with or without chaos_drop=0.05 underneath."""
    attrs = {"chaos_drop": 0.05, "chaos_seed": 99} if chaos else {}
    cluster = LocalCluster(2, attrs=attrs)
    try:
        plane = ServePlane(cluster)
        model = SyntheticModel(seed=len(specs))
        server = ContinuousBatcher(plane, model, kv_slots=2,
                                   kv_page_tokens=4, prefill_chunk=8)
        client = TokenClient(plane, model, drain_workers=2)
        report = _drive(server, client, specs, step_every=step_every,
                        deadline_s=60.0)
        _assert_exactly_once(report, len(specs))
    finally:
        cluster.close()


# ---------------------------------------------------------------------------
# telemetry spans on every stage
# ---------------------------------------------------------------------------

def test_stage_spans_cover_the_pipeline():
    cluster = LocalCluster(2, attrs={"telemetry_level": "timers"})
    try:
        plane = ServePlane(cluster)
        model = SyntheticModel(seed=1)
        server = ContinuousBatcher(plane, model, kv_slots=4)
        client = TokenClient(plane, model, drain_workers=2)
        report = _drive(server, client, [(20, 4), (3, 2)])
        _assert_exactly_once(report, 2)
        from repro.core.telemetry import render_block
        spans = render_block(cluster.tele.snapshot())["spans"]
        for stage in ("serve.enqueue", "serve.prefill", "serve.insert",
                      "serve.decode", "serve.deliver", "serve.drain"):
            assert spans.get(stage, {}).get("count", 0) > 0, stage
    finally:
        cluster.close()


# ---------------------------------------------------------------------------
# satellite: scheduler result delivery rides post_am_many
# ---------------------------------------------------------------------------

class TestSchedulerBurstDelivery:
    def _serve(self, cluster, **kw):
        transport = ServeTransport(cluster)
        alloc = PagedKVAllocator(n_pages=64, page_size=8)
        sched = ServeScheduler(
            lambda toks, pos: (toks + 1) % 997, max_batch=8,
            allocator=alloc, transport=transport, **kw)
        return transport, sched

    def test_remote_results_arrive_in_one_burst(self):
        cluster = LocalCluster(2)
        try:
            transport, sched = self._serve(cluster)
            rids = [sched.submit_remote(np.arange(4, dtype=np.int32), 3)
                    for _ in range(6)]
            got = {}
            for _ in range(200):
                sched.step()
                transport.pump()
                for rid, toks in transport.poll_results():
                    got[rid] = toks
                if len(got) == len(rids):
                    break
            assert set(got) == set(rids)
            assert all(len(t) == 3 for t in got.values())
            assert sched.completed == len(rids)
            assert not sched._pending_sends and not sched._outbox
        finally:
            cluster.close()

    def test_retry_rejected_sends_park_in_order(self):
        cluster = LocalCluster(2)
        try:
            transport, sched = self._serve(cluster)
            # jam the wire: statuses come back retry, results must park
            real = transport.send_results
            transport.send_results = lambda batch: [retry()
                                                    for _ in batch]
            for _ in range(3):
                sched.submit_remote(np.arange(2, dtype=np.int32), 2)
            for _ in range(40):
                sched.step()
                transport.pump()
                if sched.completed == 3:
                    break
            assert len(sched._pending_sends) == 3     # parked, never lost
            order = [rid for rid, _ in sched._pending_sends]
            # un-jam: the parked batch redelivers, in order, via the burst
            transport.send_results = real
            got = []
            for _ in range(200):
                sched.step()
                transport.pump()
                got += transport.poll_results()
                if len(got) == 3:
                    break
            assert [rid for rid, _ in got] == order
            assert not sched._pending_sends
        finally:
            cluster.close()


# ---------------------------------------------------------------------------
# ResultDrain: stamps and per-worker streams
# ---------------------------------------------------------------------------

def test_result_drain_stamps_and_worker_results():
    cluster = LocalCluster(1)
    try:
        cq = cluster[0].alloc_cq(threadsafe=True)
        drain = ResultDrain(cq, 2, stamp=True).start()
        t0 = time.perf_counter()
        for i in range(50):
            cq.signal(done(np.int32(i), tag=i))
        deadline = time.monotonic() + 5
        while drain.drained < 50 and time.monotonic() < deadline:
            time.sleep(0.001)
        results = drain.stop()
        assert len(results) == 50
        assert sorted(st.tag for st in results) == list(range(50))
        chunks = drain.worker_results()
        assert len(chunks) == 3            # 2 workers + final sweep
        for chunk in chunks:
            for st_, stamp in chunk:
                assert stamp >= t0
    finally:
        cluster.close()


# ---------------------------------------------------------------------------
# satellite: socket flush coalescing with depth accounting
# ---------------------------------------------------------------------------

def _am(tag, dst=1):
    return WireMsg(WireKind.EAGER_AM, 0, dst, tag=tag,
                   payload=np.full(8, tag % 250, np.uint8), size=8, rcomp=0)


class _ThrottledSock:
    """Fake kernel socket: accepts at most ``cap`` bytes per send."""

    def __init__(self):
        self.cap = 0
        self.calls = []

    def send(self, blob):
        n = min(self.cap, len(blob))
        if n == 0:
            raise OSError(errno.EAGAIN, "would block")
        self.calls.append((len(blob), n))
        return n

    def close(self):
        pass


class TestSocketFlushCoalescing:
    def test_one_send_per_burst_with_depth_accounting(self, tmp_path):
        t = SocketTransport(2, rank=0, session=str(tmp_path / "s"))
        try:
            fake = _ThrottledSock()
            t._out[1] = fake
            for i in range(10):
                assert t.try_push(_am(i))     # EAGAIN: all stay buffered
            key = (1, 0)
            assert t._tx_weight[key] == 10 and len(t._txq[1]) == 10
            fake.cap = 1 << 20
            with t._lock:
                t._flush(1)
            assert len(fake.calls) == 1       # writev-style: ONE syscall
            assert t._tx_weight[key] == 0 and not t._txq[1]
            assert t._tx_flush_frames == 10
            assert t.get_attr("socket_flush_batches") >= 1
            assert t.get_attr("socket_flush_frames") == 10
        finally:
            t.close()

    def test_partial_send_reslices_head_only(self, tmp_path):
        t = SocketTransport(2, rank=0, session=str(tmp_path / "s"))
        try:
            fake = _ThrottledSock()
            t._out[1] = fake
            for i in range(3):
                assert t.try_push(_am(i))
            frames = [f for f, _, _ in t._txq[1]]
            key = (1, 0)
            # accept frame0 fully plus 3 bytes of frame1
            fake.cap = len(frames[0]) + 3
            with t._lock:
                t._flush(1)
            assert t._tx_weight[key] == 2      # only frame0's weight freed
            q = list(t._txq[1])
            assert len(q) == 2
            assert len(q[0][0]) == len(frames[1]) - 3   # head re-sliced
            assert q[1][0] == frames[2]                 # tail untouched
            # drain the rest: accounting converges to zero
            fake.cap = 1 << 20
            with t._lock:
                t._flush(1)
            assert t._tx_weight[key] == 0 and not t._txq[1]
            assert t._tx_flush_frames == 3
        finally:
            t.close()

    def test_real_pair_burst_is_coalesced_and_intact(self, tmp_path):
        a = SocketTransport(2, rank=0, session=str(tmp_path / "pair"))
        b = SocketTransport(2, rank=1, session=str(tmp_path / "pair"))
        try:
            msgs = [_am(i) for i in range(20)]
            assert a.push_burst(msgs) == 20
            flushes = a._tx_flushes
            assert a._tx_flush_frames >= 20
            assert flushes < 20               # strictly fewer sends than frames
            got = []
            for _ in range(400):
                got += b.drain(1, 0)
                if len(got) == 20:
                    break
            assert [m.tag for m in got] == list(range(20))
            assert all(bytes(m.payload) == bytes(_am(m.tag).payload)
                       for m in got)
        finally:
            a.close()
            b.close()
