"""Minimal stand-in for ``hypothesis`` on bare environments.

Implements just the surface the test-suite uses — ``given``, ``settings``,
and the ``integers/booleans/tuples/lists`` strategies — by drawing a fixed
number of seeded-random examples.  Deterministic per test (the seed is the
test name), no shrinking, no database.  When the real ``hypothesis`` is
installed the test modules import it instead; this shim only keeps the
property tests *running* (not just collected) without the dependency.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:  # noqa: N801 — mimics the `strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def sampled_from(seq):
        elems = list(seq)
        return _Strategy(lambda r: r.choice(elems))

    @staticmethod
    def tuples(*parts):
        return _Strategy(lambda r: tuple(p.draw(r) for p in parts))

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elem.draw(r) for _ in range(n)]
        return _Strategy(draw)


def settings(max_examples=20, deadline=None, **_ignored):
    """Records ``max_examples`` for the enclosing ``given``."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    """Runs the test once per drawn example (no shrinking)."""
    def deco(fn):
        n_examples = getattr(fn, "_fallback_max_examples", 20)

        # NOT functools.wraps: copying the signature would make pytest
        # treat the injected arguments as fixtures.
        def wrapper(*args):            # args = (self,) for methods, () else
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n_examples):
                vals = tuple(s.draw(rng) for s in strats)
                try:
                    fn(*args, *vals)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example for {fn.__qualname__}: "
                        f"{vals!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
