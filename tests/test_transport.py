"""Pluggable transport backends (DESIGN.md §14): the wire codec, the shm
ring and socket transports, backend selection through the attr chain, and
cross-backend parity of the full protocol stack (eager / bufcopy /
rendezvous) — every backend must deliver byte-identical payloads."""
import os
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                               # bare env: seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (AttrError, LocalCluster, PackedBurst, Transport,
                        backend_class, decode_msg, encode_msg,
                        make_transport, msg_weight, post_am, post_recv,
                        post_send)
from repro.core.matching import MatchingPolicy
from repro.core.transport.shm import ShmTransport
from repro.core.transport.sim import Fabric
from repro.core.transport.socket import SocketTransport
from repro.core.transport.wire import PACKED_KINDS, WireKind, WireMsg

SCALAR_KINDS = sorted(v for k, v in vars(WireKind).items()
                      if not k.startswith("_") and v not in PACKED_KINDS)


def _assert_msg_equal(a: WireMsg, b: WireMsg):
    assert a.kind == b.kind
    assert (a.src, a.dst, a.tag, a.size, a.op_id) == \
           (b.src, b.dst, b.tag, b.size, b.op_id)
    assert a.rcomp == b.rcomp
    assert a.matching_policy == b.matching_policy
    assert a.device_index == b.device_index
    assert a.remote_buf == (tuple(b.remote_buf)
                            if b.remote_buf is not None else None)
    if b.payload is None:
        assert a.payload is None
    elif isinstance(b.payload, tuple):
        assert a.payload == b.payload
    elif isinstance(b.payload, PackedBurst):
        got, want = a.payload, b.payload
        assert got.count == want.count
        assert got.tags == list(want.tags)
        assert got.wire_dtype == want.wire_dtype
        assert np.array_equal(got.sizes, want.sizes)
        for g, w in zip(got.delivered_payloads(),
                        want.delivered_payloads()):
            assert np.array_equal(g, w)
    else:
        assert np.array_equal(a.payload,
                              b.payload.reshape(-1).view(np.uint8))


# ---------------------------------------------------------------------------
# codec: stable binary round trip (satellite 1)
# ---------------------------------------------------------------------------

class TestCodecRoundtrip:
    @settings(max_examples=60, deadline=None)
    @given(st.sampled_from(SCALAR_KINDS),
           st.integers(0, 7), st.integers(0, 7),
           st.integers(0, 2**31 - 1),
           st.integers(-1, 2**31 - 1),
           st.integers(-1, 100),            # rcomp (-1 = None)
           st.sampled_from(list(MatchingPolicy)),
           st.integers(0, 5),
           st.integers(-1, 2),              # payload selector
           st.lists(st.integers(0, 255), min_size=0, max_size=64),
           st.booleans())
    def test_scalar_roundtrip(self, kind, src, dst, tag, op_id, rcomp,
                              policy, didx, pselect, body, with_rbuf):
        if pselect < 0:
            payload = None
        elif pselect == 0:
            payload = np.asarray(body, dtype=np.uint8)
        else:
            payload = tuple(body[:8])
        msg = WireMsg(kind, src, dst, tag=tag, payload=payload,
                      size=len(body), rcomp=None if rcomp < 0 else rcomp,
                      matching_policy=policy, op_id=op_id,
                      remote_buf=(tag % 5, op_id % 97) if with_rbuf
                      else None,
                      device_index=didx, ready_at=0.25)
        out, end = decode_msg(encode_msg(msg))
        assert end == len(encode_msg(msg))
        _assert_msg_equal(out, msg)
        assert out.ready_at == msg.ready_at

    @settings(max_examples=40, deadline=None)
    @given(st.integers(1, 6),               # rows
           st.integers(1, 24),              # max row bytes
           st.lists(st.integers(0, 2**31 - 1), min_size=6, max_size=6),
           st.booleans())                   # ragged?
    def test_packed_roundtrip(self, k, row_bytes, tags, ragged):
        rng = np.random.default_rng(k * 1000 + row_bytes)
        data = rng.integers(0, 256, (k, row_bytes), dtype=np.uint8)
        sizes = (rng.integers(0, row_bytes + 1, k).astype(np.int64)
                 if ragged else np.full(k, row_bytes, np.int64))
        burst = PackedBurst(data, sizes, [int(t) for t in tags[:k]], k)
        msg = WireMsg(WireKind.EAGER_PACKED_AM, 0, 1, payload=burst,
                      size=int(data.nbytes), rcomp=0)
        out, _ = decode_msg(encode_msg(msg))
        _assert_msg_equal(out, msg)
        assert msg_weight(out) == k

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 5), st.integers(1, 8))
    def test_packed_bf16_roundtrip(self, k, n_floats):
        """bf16-compressed rows decode to the same delivered f32 bytes."""
        import ml_dtypes
        f32 = np.linspace(-3, 3, n_floats, dtype=np.float32)
        row = f32.astype(ml_dtypes.bfloat16).view(np.uint8)
        # broadcast stride-0 rows — the message-rate hot path's wire image
        data = np.broadcast_to(row, (k, row.size))
        burst = PackedBurst(data, np.full(k, f32.nbytes, np.int64),
                            list(range(k)), k, wire_dtype="bf16")
        msg = WireMsg(WireKind.EAGER_PACKED_SEND, 0, 1, payload=burst,
                      size=int(data.nbytes))
        out, _ = decode_msg(encode_msg(msg))
        assert out.payload.wire_dtype == "bf16"
        for got, want in zip(out.payload.delivered_payloads(),
                             burst.delivered_payloads()):
            assert np.array_equal(got, want)

    def test_rejects_foreign_frames(self):
        from repro.core.status import FatalError
        with pytest.raises(FatalError, match="magic"):
            decode_msg(b"\x00" * 128)

    def test_codec_against_sim_backend(self):
        """Standalone contract: a decoded message is indistinguishable
        from the original to the sim fabric (satellite requirement)."""
        fab = Fabric(2)
        originals = [
            WireMsg(WireKind.EAGER_AM, 0, 1, tag=i,
                    payload=np.full(8, i, np.uint8), size=8, rcomp=0)
            for i in range(4)
        ]
        for m in originals:
            decoded, _ = decode_msg(encode_msg(m))
            assert fab.try_push(decoded)
        out = fab.drain(1, 0)
        assert [m.tag for m in out] == [0, 1, 2, 3]
        for got, want in zip(out, originals):
            _assert_msg_equal(got, want)


# ---------------------------------------------------------------------------
# backend registry + attr-chain selection (satellite 6)
# ---------------------------------------------------------------------------

class TestBackendSelection:
    def test_registry_resolves_all_backends(self):
        assert backend_class("sim") is Fabric
        assert backend_class("shm") is ShmTransport
        assert backend_class("socket") is SocketTransport
        for name in ("sim", "shm", "socket"):
            assert issubclass(backend_class(name), Transport)

    def test_unknown_backend_raises_attr_error(self):
        with pytest.raises(AttrError, match="registered backends"):
            backend_class("infiniband")
        with pytest.raises(AttrError):
            make_transport("infiniband", 2)

    def test_invalid_backend_attr_rejected_at_alloc(self):
        with pytest.raises(AttrError):
            LocalCluster(2, attrs={"fabric_backend": "carrier_pigeon"})

    def test_env_layer_selects_backend(self, monkeypatch):
        monkeypatch.setenv("REPRO_ATTR_FABRIC_BACKEND", "shm")
        cl = LocalCluster(2)
        try:
            assert cl.fabric.backend == "shm"
            # the chaos CI leg wraps the backend in ChaosTransport; the
            # wrapper echoes .backend and attrs but the concrete class
            # lives one level down
            base = getattr(cl.fabric, "inner", cl.fabric)
            assert isinstance(base, ShmTransport)
            assert cl.fabric.get_attr("fabric_backend") == "shm"
            assert cl.fabric.attr_source("fabric_backend") == "env"
        finally:
            cl.close()

    def test_introspection_on_fabric(self):
        cl = LocalCluster(2, attrs={"fabric_backend": "shm",
                                    "shm_ring_bytes": 65536})
        try:
            fab = cl.fabric
            assert fab.get_attr("fabric_backend") == "shm"
            assert fab.get_attr("shm_ring_bytes") == 65536
            assert fab.attr_source("fabric_backend") == "runtime"
            assert fab.attr_source("fabric_depth") == "default"
            echoed = fab.attrs
            assert echoed["fabric_backend"] == "shm"
            assert echoed["shm_ring_bytes"] == 65536
            assert "in_flight" in echoed
        finally:
            cl.close()

    def test_default_backend_is_sim(self, monkeypatch):
        # CI runs the whole suite under REPRO_ATTR_FABRIC_BACKEND=shm (and
        # the chaos leg under REPRO_ATTR_CHAOS_*); this test is about the
        # *library* default, so strip the env layer entirely
        monkeypatch.delenv("REPRO_ATTR_FABRIC_BACKEND", raising=False)
        for var in ("REPRO_ATTR_CHAOS_DROP", "REPRO_ATTR_CHAOS_DUP",
                    "REPRO_ATTR_CHAOS_REORDER", "REPRO_ATTR_CHAOS_DELAY_P",
                    "REPRO_ATTR_CHAOS_SEED", "REPRO_ATTR_CHAOS_KILL_RANK"):
            monkeypatch.delenv(var, raising=False)
        cl = LocalCluster(2)
        assert isinstance(cl.fabric, Fabric)
        assert cl.fabric.get_attr("fabric_backend") == "sim"
        assert cl.fabric.attr_source("fabric_backend") == "default"


# ---------------------------------------------------------------------------
# shm transport mechanics
# ---------------------------------------------------------------------------

def _shm_pair(tmp_path, **kw):
    """Producer (rank 0) and consumer (rank 1) instances sharing one
    session — the two-process topology, in one test process."""
    session = str(tmp_path / "sess")
    a = ShmTransport(2, rank=0, session=session, **kw)
    b = ShmTransport(2, rank=1, session=session, **kw)
    return a, b


def _am(i=0, dst=1, dev=0, nbytes=8):
    return WireMsg(WireKind.EAGER_AM, 0, dst, tag=i,
                   payload=np.full(nbytes, i % 256, np.uint8),
                   size=nbytes, rcomp=0, device_index=dev)


class TestShmTransport:
    def test_cross_instance_fifo(self, tmp_path):
        a, b = _shm_pair(tmp_path)
        try:
            for i in range(10):
                assert a.try_push(_am(i))
            assert b.stream_depth(1, 0) == 10       # unlocked head peek
            out = b.drain(1, 0)
            assert [m.tag for m in out] == list(range(10))
            assert np.array_equal(out[3].payload,
                                  np.full(8, 3, np.uint8))
            assert b.stream_depth(1, 0) == 0
            assert not b.ready(1, 0)
        finally:
            a.close(); b.close()

    def test_depth_bound_prefix_accept(self, tmp_path):
        a, b = _shm_pair(tmp_path, depth=3)
        try:
            msgs = [_am(i) for i in range(5)]
            assert a.push_burst(msgs) == 3
            assert a.full_events == 1
            assert [m.tag for m in b.drain(1, 0)] == [0, 1, 2]
            assert a.push_burst(msgs[3:]) == 2      # room recycled
        finally:
            a.close(); b.close()

    def test_ring_byte_backpressure_and_wraparound(self, tmp_path):
        """A ring much smaller than the traffic forces wraparound and
        byte-level back-pressure; nothing is lost or reordered."""
        a, b = _shm_pair(tmp_path, ring_bytes=4096)
        try:
            sent = recvd = 0
            tags = []
            while sent < 300:
                if a.try_push(_am(sent, nbytes=100)):
                    sent += 1
                else:
                    got = b.drain(1, 0, limit=7)
                    assert got, "full ring but nothing drainable"
                    tags += [m.tag for m in got]
                    recvd += len(got)
            tags += [m.tag for m in b.drain(1, 0)]
            assert tags == list(range(300))
            assert a.in_flight() == 0 or b.in_flight() == 0
        finally:
            a.close(); b.close()

    def test_packed_doorbell_row_weighted(self, tmp_path):
        a, b = _shm_pair(tmp_path, depth=10)
        try:
            data = np.arange(48, dtype=np.uint8).reshape(6, 8)
            burst = PackedBurst(data, np.full(6, 8, np.int64),
                                list(range(6)), 6)
            msg = WireMsg(WireKind.EAGER_PACKED_AM, 0, 1, payload=burst,
                          size=48, rcomp=0)
            assert a.push_packed(msg) == 6
            assert b.stream_depth(1, 0) == 6        # rows, not records
            assert a.push_packed(msg) == 4          # prefix-accept split
            out = b.drain(1, 0)
            assert [m.payload.count for m in out] == [6, 4]
            assert np.array_equal(out[1].payload.data, data[:4])
            assert b.stream_depth(1, 0) == 0
        finally:
            a.close(); b.close()

    def test_oversized_payload_spills(self, tmp_path):
        a, b = _shm_pair(tmp_path, ring_bytes=8192)
        try:
            big = np.arange(32 * 1024, dtype=np.uint8) % 251
            msg = WireMsg(WireKind.RDMA_PAYLOAD, 0, 1, payload=big,
                          size=big.nbytes, op_id=7)
            assert a.try_push(msg)
            session = a._dir
            assert any(n.startswith("spill_")
                       for n in os.listdir(session))
            out = b.drain(1, 0)
            assert len(out) == 1
            assert np.array_equal(out[0].payload, big)
            # consumed spill files are reaped
            assert not any(n.startswith("spill_")
                           for n in os.listdir(session))
        finally:
            a.close(); b.close()

    def test_threaded_producers_one_consumer(self, tmp_path):
        """In-process multithreaded producers ride the per-ring lock;
        SPSC is per process, so this must be safe (solo-mode tier-1)."""
        t = ShmTransport(2, ring_bytes=1 << 16)
        try:
            per_thread, n_threads = 200, 4
            done = threading.Barrier(n_threads + 1)

            def producer(base):
                for i in range(per_thread):
                    while not t.try_push(_am(base + i, nbytes=16)):
                        pass
                done.wait()

            threads = [threading.Thread(target=producer,
                                        args=(k * per_thread,))
                       for k in range(n_threads)]
            got = []
            for th in threads:
                th.start()
            while len(got) < per_thread * n_threads:
                got += t.drain(1, 0, limit=32)
            done.wait(timeout=30)
            for th in threads:
                th.join(timeout=30)
            assert sorted(m.tag for m in got) == \
                list(range(per_thread * n_threads))
            assert t.in_flight() == 0
        finally:
            t.close()

    def test_solo_session_dir_reaped_on_close(self):
        t = ShmTransport(2)
        d = t._dir
        t.try_push(_am(0))
        assert os.path.isdir(d)
        t.close()
        assert not os.path.exists(d)


# ---------------------------------------------------------------------------
# socket transport mechanics
# ---------------------------------------------------------------------------

class TestSocketTransport:
    def test_cross_instance_fifo(self, tmp_path):
        session = str(tmp_path / "socksess")
        a = SocketTransport(2, rank=0, session=session)
        b = SocketTransport(2, rank=1, session=session)
        try:
            for i in range(20):
                assert a.try_push(_am(i))
            got = []
            deadline = 200
            while len(got) < 20 and deadline:
                got += b.drain(1, 0)
                deadline -= 1
            assert [m.tag for m in got] == list(range(20))
            assert b.stream_depth(1, 0) == 0
        finally:
            a.close(); b.close()

    def test_packed_and_tuple_payloads(self, tmp_path):
        session = str(tmp_path / "socksess2")
        a = SocketTransport(2, rank=0, session=session)
        b = SocketTransport(2, rank=1, session=session)
        try:
            data = np.arange(24, dtype=np.uint8).reshape(3, 8)
            burst = PackedBurst(data, np.full(3, 8, np.int64),
                                [9, 8, 7], 3)
            assert a.push_packed(WireMsg(
                WireKind.EAGER_PACKED_AM, 0, 1, payload=burst,
                size=24, rcomp=0)) == 3
            assert a.try_push(WireMsg(WireKind.CTS, 0, 1,
                                      payload=(5,), op_id=3))
            got = []
            for _ in range(200):
                got += b.drain(1, 0)
                if len(got) == 2:
                    break
            assert msg_weight(got[0]) == 3
            assert np.array_equal(got[0].payload.data, data)
            assert got[1].payload == (5,)
        finally:
            a.close(); b.close()


# ---------------------------------------------------------------------------
# cross-backend parity: the full protocol stack end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["sim", "shm", "socket"])
class TestBackendParity:
    def test_eager_am_roundtrip(self, backend):
        cl = LocalCluster(2, attrs={"fabric_backend": backend})
        try:
            r0, r1 = cl[0], cl[1]
            cq = r1.alloc_cq()
            rc = r1.register_rcomp(cq)
            buf = np.arange(64, dtype=np.uint8)
            post_am(r0, 1, buf, remote_comp=rc)
            cl.quiesce()
            st = cq.pop()
            assert st.is_done()
            assert np.array_equal(
                np.asarray(st.value).view(np.uint8)[:64], buf)
        finally:
            cl.close()

    def test_send_recv_all_protocols(self, backend):
        """Eager, bufcopy, and zero-copy rendezvous payload sizes all
        deliver byte-identical data on every backend (rendezvous rides
        RTS/CTS tuple payloads + a multi-MB RDMA_PAYLOAD — the shm spill
        path)."""
        # eager_max lowered so 8000 B genuinely rides the bufcopy packets
        cl = LocalCluster(2, attrs={"fabric_backend": backend,
                                    "eager_max_bytes": 1024})
        try:
            r0, r1 = cl[0], cl[1]
            rng = np.random.default_rng(7)
            # inject-eager, bufcopy (≤ packet_bytes), zero-copy rendezvous
            for size in (64, 8000, 3 * 1024 * 1024):
                src = rng.integers(0, 256, size, dtype=np.uint8)
                dst = np.zeros(size, np.uint8)
                sync = r1.alloc_sync()
                post_recv(r1, 0, dst, size, tag=size % 997,
                          local_comp=sync)
                post_send(r0, 1, src, size, tag=size % 997)
                cl.quiesce()
                assert sync.test()[0]
                assert np.array_equal(dst, src), f"size {size}"
        finally:
            cl.close()


# ---------------------------------------------------------------------------
# drain-limit row weighting across backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["sim", "shm"])
def test_drain_limit_is_row_weighted(tmp_path, backend):
    """drain(limit=k) counts packed rows toward the cap on every backend
    that can see queued packed doorbells."""
    if backend == "sim":
        t = Fabric(2, depth=64)
    else:
        t = ShmTransport(2, depth=64)
    try:
        t.try_push(_am(0))
        data = np.zeros((5, 4), np.uint8)
        t.push_packed(WireMsg(WireKind.EAGER_PACKED_AM, 0, 1,
                              payload=PackedBurst(
                                  data, np.full(5, 4, np.int64),
                                  list(range(5)), 5),
                              size=20, rcomp=0))
        t.try_push(_am(1))
        assert t.stream_depth(1, 0) == 7
        out = t.drain(1, 0, limit=2)       # scalar + whole doorbell
        assert len(out) == 2 and msg_weight(out[1]) == 5
        assert t.stream_depth(1, 0) == 1   # depth dropped by the weight
        assert t.ready(1, 0)
        assert len(t.drain(1, 0)) == 1
        assert t.stream_depth(1, 0) == 0
    finally:
        t.close()
