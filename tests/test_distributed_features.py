"""Distributed-optimization features: compression, 1F1B pipeline graphs,
straggler detection, elastic mesh enumeration, collectives (subprocess)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (compress_grad, dequantize_int8,
                                           init_error_state, quantize_int8)
from repro.distributed.elastic import compatible_meshes, shrink_mesh
from repro.distributed.pipeline import (PipelinedModel, bubble_fraction,
                                        build_1f1b_comm_graph, schedule_1f1b)
from repro.distributed.straggler import HostWatchdog, StepTimeMonitor
from repro.models.common import ModelConfig


class TestCompression:
    def test_quant_roundtrip_error_bounded(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 3
        q, scale = quantize_int8(g)
        err = np.abs(np.asarray(dequantize_int8(q, scale) - g)).max()
        assert err <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_accumulates(self):
        """With error feedback, the RUNNING SUM of dequantized grads tracks
        the running sum of true grads (the EF guarantee)."""
        key = jax.random.PRNGKey(1)
        err = jnp.zeros((64,), jnp.float32)
        true_sum = jnp.zeros((64,))
        sent_sum = jnp.zeros((64,))
        for i in range(50):
            key, k = jax.random.split(key)
            g = jax.random.normal(k, (64,)) * 0.01
            q, scale, err = compress_grad(g, err)
            true_sum = true_sum + g
            sent_sum = sent_sum + dequantize_int8(q, scale)
        resid = np.abs(np.asarray(true_sum - sent_sum)).max()
        # residual is bounded by one quantization step, not O(steps)
        assert resid < 0.01

    def test_compressed_training_converges(self, helper_runner):
        helper_runner("compressed_training", devices=8)


class TestPipeline:
    @pytest.mark.parametrize("s,m", [(2, 4), (4, 8), (3, 3)])
    def test_schedule_valid(self, s, m):
        g, ids = schedule_1f1b(s, m)
        g.execute()
        g.assert_partial_order()
        assert len(g) == 2 * s * m

    def test_critical_path_matches_bubble(self):
        s, m = 4, 8
        g, _ = schedule_1f1b(s, m)
        g.execute()
        # 1F1B: critical path = 2*(s-1) warmup/cooldown + 2*m steady nodes
        assert g.critical_path_len() == 2 * (s - 1) + 2 * m
        assert bubble_fraction(s, m) == pytest.approx((s - 1) / (s - 1 + m))

    @pytest.mark.parametrize("s,m", [(2, 3), (3, 4)])
    def test_async_comm_graph_completes_over_the_wire(self, s, m):
        """1F1B with activation hand-offs as real comm nodes: the graph
        completes via start() + progress signaling and respects the
        schedule's partial order."""
        from repro.core import CommConfig, LocalCluster
        cl = LocalCluster(s, CommConfig(inject_max_bytes=64),
                          fabric_depth=1 << 14)
        eps = cl.alloc_endpoint(n_devices=2, name="pp")
        pg = build_1f1b_comm_graph(cl, n_micro=m, payload_bytes=16,
                                   endpoints=eps)
        g = pg.graph
        g.start()
        assert not g.test()[0]                   # async: not done at start
        while not g.test()[0]:
            cl.progress_all()
        g.assert_partial_order()
        # fwd activations really crossed the fabric: stage s_ sees the
        # marker chain value sum(1..s_) + micro
        for micro in range(m):
            exp = micro % 251
            for s_ in range(s - 1):
                exp = (exp + s_ + 1) % 251
                assert np.all(pg.act_in[(s_, micro)] == exp)
        # the shim path (execute = start + drain) reproduces the result
        vals = g.execute()
        g.assert_partial_order()
        assert len(vals) == len(g)

    def test_pipelined_grads_match_monolithic(self):
        key = jax.random.PRNGKey(0)
        w1 = jax.random.normal(key, (8, 8)) * 0.3
        w2 = jax.random.normal(jax.random.PRNGKey(1), (8, 8)) * 0.3
        xs = [jax.random.normal(jax.random.PRNGKey(10 + i), (4, 8))
              for i in range(4)]
        targets = [jax.random.normal(jax.random.PRNGKey(20 + i), (4, 8))
                   for i in range(4)]

        def s0(p, x):
            return jnp.tanh(x @ p)

        def s1(p, x):
            return x @ p

        def loss_fn(y, m):
            return ((y - targets[m]) ** 2).mean()

        pm = PipelinedModel([s0, s1], n_micro=4)
        loss_pp, grads_pp = pm.forward_backward([w1, w2], xs, loss_fn)

        def mono(w1, w2):
            losses = [((s1(w2, s0(w1, xs[m])) - targets[m]) ** 2).mean()
                      for m in range(4)]
            return jnp.stack(losses).sum()        # PP sums microbatch grads

        g1, g2 = jax.grad(mono, argnums=(0, 1))(w1, w2)
        np.testing.assert_allclose(np.asarray(grads_pp[0]), np.asarray(g1),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(grads_pp[1]), np.asarray(g2),
                                   atol=1e-5)


class TestStraggler:
    def test_zscore_flags_outlier(self):
        mon = StepTimeMonitor(window=20, z_threshold=3.0, warmup=5)
        for i in range(20):
            mon.record(i, 0.1 + 0.001 * (i % 3))
        rep = mon.record(20, 1.5)
        assert rep is not None and rep.zscore > 3.0
        assert mon.summary()["flagged"] == 1

    def test_steady_state_quiet(self):
        mon = StepTimeMonitor()
        for i in range(100):
            assert mon.record(i, 0.1) is None

    def test_watchdog(self):
        wd = HostWatchdog(n_hosts=4, grace=5)
        for h in range(4):
            wd.beat(h, 100 if h != 2 else 80)
        assert wd.dead_hosts() == [2]


class TestElastic:
    def test_compatible_meshes(self):
        cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                          n_heads=8, n_kv_heads=8, d_ff=128, vocab=256,
                          tp_target=4)
        meshes = compatible_meshes(cfg, 16)
        assert (4, 4) in meshes and (16, 1) in meshes
        # model=16 needs heads%16==0: 8 heads -> excluded
        assert (1, 16) not in meshes

    def test_shrink_mesh(self):
        assert shrink_mesh((16, 16), dead_fraction=0.5) == (8, 16)


def test_collectives_subprocess(helper_runner):
    helper_runner("collectives_check", devices=8)
