"""Helper: int8+error-feedback gradient compression converges like the
uncompressed baseline on a (2,4) mesh.  Run with 8 fake devices."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map

from repro.core.modes import CommConfig, CommMode
from repro.data import SyntheticPipeline
from repro.distributed.comm import Comm
from repro.distributed.compression import (grad_sync_compressed,
                                           init_error_state)
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, grad_sync
from repro.optim.adamw import OptState

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab=64, tp_target=4,
                  dtype=jnp.float32)
MESH = make_mesh((2, 4), ("data", "model"))


def run(compressed: bool, steps: int = 30):
    model = build_model(CFG)
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0, max_grad_norm=0.0)
    params, specs = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt)
    error = init_error_state(params)
    comm = Comm(CommConfig(mode=CommMode.LCI_DEDICATED),
                model_axis="model", data_axis="data")
    pspecs = jax.tree_util.tree_map(lambda sp: sp.pspec(), specs)
    bspec = {"tokens": P("model", "data"), "labels": P("model", "data")}
    err_specs = pspecs

    def step(params, opt_state, error, batch):
        def loss_fn(p):
            loss, m = model.loss(p, batch, comm)
            return loss, m
        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compressed:
            grads, error = grad_sync_compressed(grads, specs, error, comm)
        else:
            grads = grad_sync(grads, specs, comm)
        params, opt_state = adamw_update(grads, opt_state, params, opt)
        return params, opt_state, error, comm.pmean_all(loss)

    sspec = OptState(P(), pspecs, pspecs, pspecs)
    f = jax.jit(shard_map(
        step, mesh=MESH,
        in_specs=(pspecs, sspec, err_specs, bspec),
        out_specs=(pspecs, sspec, err_specs, P()), check_vma=False))
    pipe = SyntheticPipeline(vocab=64, seq_len=32, global_batch=8)
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(i).items()}
        params, opt_state, error, loss = f(params, opt_state, error, batch)
        losses.append(float(loss))
    return losses


def main():
    base = run(False)
    comp = run(True)
    print(f"baseline:   {base[0]:.3f} -> {np.mean(base[-5:]):.3f}")
    print(f"compressed: {comp[0]:.3f} -> {np.mean(comp[-5:]):.3f}")
    # compressed training must learn, and track the baseline closely
    assert np.mean(comp[-5:]) < comp[0] - 0.3
    assert abs(np.mean(comp[-5:]) - np.mean(base[-5:])) < 0.4, \
        (np.mean(comp[-5:]), np.mean(base[-5:]))


if __name__ == "__main__":
    main()
    print("HELPER-OK")
