"""Child half of the mid-commit kill test (test_chaos.py).

Commits step 0 normally, then starts a step-1 commit whose leaf writes
are slowed to a crawl and prints a marker once the first leaf write is
underway.  The parent SIGKILLs this process on the marker — mid-commit,
before the atomic rename — and asserts the store still reads as step 0.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import numpy as np

from repro.checkpoint import save_sync
from repro.checkpoint import store as _store


def main() -> None:
    ckpt = sys.argv[1]
    tree = {"w": np.arange(64, dtype=np.float64),
            "step": np.zeros((), np.int64)}
    save_sync(ckpt, 0, tree, meta={"next_step": 1})

    real_write = _store._write_leaf

    def slow_write(tmp, name, arr):
        print("COMMITTING", flush=True)     # parent kills on this marker
        time.sleep(5.0)                     # hold the commit open
        return real_write(tmp, name, arr)

    _store._write_leaf = slow_write
    tree["step"] = np.ones((), np.int64)
    save_sync(ckpt, 1, tree, meta={"next_step": 2})
    print("COMMITTED-1", flush=True)        # must never be reached


if __name__ == "__main__":
    main()
