"""Helper: checkpoint under mesh A (2,4), restore + train under mesh B
(4,2) — the elastic re-shard path.  Run with 8 fake devices."""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map

from repro.checkpoint import CheckpointStore
from repro.core.modes import CommConfig, CommMode
from repro.data import SyntheticPipeline
from repro.distributed.comm import Comm
from repro.distributed.elastic import compatible_meshes, reshard_state
from repro.launch.mesh import shard
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import AdamWConfig
from repro.optim.adamw import OptState
from repro.train import make_train_step, train_state_init
from repro.train.step import TrainState

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, tp_target=4,
                  dtype=jnp.float32)
MKEYS = ("loss", "ce", "ntok", "aux_lb", "aux_z", "dropped_frac",
         "grad_norm")


def make_step(mesh, specs, model, opt):
    comm = Comm(CommConfig(mode=CommMode.LCI_DEDICATED),
                model_axis="model", data_axis="data")
    pspecs = jax.tree_util.tree_map(lambda sp: sp.pspec(), specs)
    sspecs = TrainState(pspecs, OptState(P(), pspecs, pspecs, pspecs))
    bspec = {"tokens": P("model", "data"), "labels": P("model", "data")}
    fn = shard_map(make_train_step(model, specs, opt, comm), mesh=mesh,
                       in_specs=(sspecs, bspec),
                       out_specs=(sspecs, {k: P() for k in MKEYS}),
                       check_vma=False)
    return jax.jit(fn), sspecs


def main():
    assert (2, 4) in compatible_meshes(CFG, 8)
    assert (4, 2) in compatible_meshes(CFG, 8)
    model = build_model(CFG)
    opt = AdamWConfig(lr=1e-3)
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    pipe = SyntheticPipeline(vocab=256, seq_len=32, global_batch=8)
    wrap = lambda b: {k: jnp.asarray(v) for k, v in b.items()}

    mesh_a = make_mesh((2, 4), ("data", "model"))
    step_a, sspecs = make_step(mesh_a, specs, model, opt)
    for i in range(3):
        state, m = step_a(state, wrap(pipe.get_batch(i)))
    loss_a = float(m["loss"])

    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(2, state, meta={"next_step": 3}, blocking=True)

        # ---- new mesh (4, 2): elastic restore ----
        mesh_b = make_mesh((4, 2), ("data", "model"))
        host_state, manifest = store.restore(
            jax.tree_util.tree_map(np.asarray, state))
        step_b, sspecs_b = make_step(mesh_b, specs, model, opt)
        state_b = reshard_state(host_state, shard(mesh_b, sspecs_b))
        # continue training on the new mesh — must be finite and sane
        for i in range(manifest["meta"]["next_step"], 6):
            state_b, m = step_b(state_b, wrap(pipe.get_batch(i)))
        assert np.isfinite(float(m["loss"])), m
        print(f"elastic OK: loss_a={loss_a:.4f} loss_b={float(m['loss']):.4f}")

        # cross-check against an unresharded continuation on mesh A
        state_a2, _ = store.restore(jax.tree_util.tree_map(np.asarray, state))
        for i in range(3, 6):
            state_a2, m2 = step_a(state_a2, wrap(pipe.get_batch(i)))
        d_loss = abs(float(m2["loss"]) - float(m["loss"]))
        assert d_loss < 2e-3, f"elastic diverged: {d_loss}"
        print(f"elastic continuation matches: d_loss={d_loss:.2e}")


if __name__ == "__main__":
    main()
    print("HELPER-OK")
