import os
# XLA_FLAGS set by conftest (8 devices)
import sys
# PYTHONPATH set by conftest
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.core import collectives as C
from repro.core.modes import CommConfig, CommMode

mesh = make_mesh((8,), ("x",))
key = jax.random.PRNGKey(0)
X = jax.random.normal(key, (16, 32), jnp.float32)
W = jax.random.normal(jax.random.PRNGKey(1), (32, 24), jnp.float32)
W2 = jax.random.normal(jax.random.PRNGKey(2), (4, 24), jnp.float32)  # k_shard=4 per rank

modes = [CommConfig(mode=m) for m in CommMode]

def smap(f, in_specs, out_specs):
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False))

ok = True
for cfg in modes:
    # all_gather
    f = smap(lambda x: C.all_gather(x, "x", cfg), (P("x", None),), P(None, None))
    got = f(X)
    exp = np.tile(X, (1, 1))  # gathered = X itself replicated
    if not np.allclose(got, X):
        print(f"AG FAIL {cfg.mode}"); ok = False
    # all_gather_matmul
    f = smap(lambda x, w: C.all_gather_matmul(x, w, "x", cfg), (P("x", None), P(None, None)), P(None, None))
    got = f(X, W)
    exp = X @ W
    if not np.allclose(got, exp, atol=1e-4):
        print(f"AGMM FAIL {cfg.mode}", np.abs(got-exp).max()); ok = False
    # matmul_reduce_scatter: x (m, k) sharded on k over ranks; w (k, n) sharded on k
    Xk = jax.random.normal(key, (16, 32), jnp.float32)
    Wk = jax.random.normal(jax.random.PRNGKey(3), (32, 24), jnp.float32)
    f = smap(lambda x, w: C.matmul_reduce_scatter(x, w, "x", cfg), (P(None, "x"), P("x", None)), P("x", None))
    got = f(Xk, Wk)
    exp = Xk @ Wk
    if not np.allclose(got, exp, atol=1e-3):
        print(f"MMRS FAIL {cfg.mode}", np.abs(got-exp).max()); ok = False
    # reduce_scatter on raw tensor: input replicated per rank? semantics: each rank has local x, result = sum over ranks scattered
    f = smap(lambda x: C.reduce_scatter(x, "x", cfg), (P(None, None),), P("x", None))
    got = f(X)  # each rank's local copy is X -> sum = 8*X, scattered rows
    if not np.allclose(got, 8*X, atol=1e-3):
        print(f"RS FAIL {cfg.mode}", np.abs(got-8*X).max()); ok = False
    # all_reduce
    f = smap(lambda x: C.all_reduce(x, "x", cfg), (P(None, None),), P(None, None))
    got = f(X)
    if not np.allclose(got, 8*X, atol=1e-3):
        print(f"AR FAIL {cfg.mode}", np.abs(got-8*X).max()); ok = False
    # all_to_all
    Y = jax.random.normal(key, (8, 16, 8), jnp.float32)
    f = smap(lambda x: C.all_to_all(x, "x", split_axis=1, concat_axis=0, config=cfg), (P("x", None, None),), P("x", None, None))
    got = f(Y)
    exp_f = smap(lambda x: jax.lax.all_to_all(x, "x", split_axis=1, concat_axis=0, tiled=True), (P("x", None, None),), P("x", None, None))
    if not np.allclose(got, exp_f(Y)):
        print(f"A2A FAIL {cfg.mode}"); ok = False

# barrier / tree collectives
f = smap(lambda: C.dissemination_barrier("x")[None], (), P("x"))
tok = f()
assert np.all(np.asarray(tok) == 8), tok
val = jnp.arange(8.0).reshape(8,1) + 3
f = smap(lambda v: C.tree_broadcast(v.squeeze(0), "x", root=3)[None], (P("x", None),), P("x", None))
got = f(val)
assert np.allclose(got, 6.0), got   # rank 3's value = 3+3
f = smap(lambda v: C.tree_reduce(v.squeeze(0), "x", root=0)[None], (P("x", None),), P("x", None))
got = f(val)
assert np.allclose(np.asarray(got)[0], np.sum(np.asarray(val))), got
print("barrier/tree OK")
assert ok, "collective failures"
print("HELPER-OK")
