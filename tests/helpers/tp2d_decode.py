"""Helper: 2D-TP serving (weight-stationary decode) matches the classic
FSDP-gather decode AND the local oracle on a (2,4) mesh."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map

from repro.core.modes import CommConfig, CommMode
from repro.distributed.comm import Comm, local_comm
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.serving.engine import cache_pspecs, init_cache, make_serve_step

MESH = make_mesh((2, 4), ("data", "model"))
F = jnp.float32


def check(cfg, batch=4):
    m = build_model(cfg)
    params, specs = m.init(jax.random.PRNGKey(0))
    S = 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (S, batch), 0,
                                cfg.vocab)
    comm = Comm(CommConfig(mode=CommMode.LCI_DEDICATED),
                model_axis="model", data_axis="data")
    pspecs = jax.tree_util.tree_map(lambda sp: sp.pspec(), specs)

    def run(tp2d):
        cspecs = cache_pspecs(cfg, batch=batch, tp2d=tp2d)
        tok_spec = P("data") if (batch > 1 and not tp2d) else P()
        serve = make_serve_step(cfg, comm, joint_kv=batch == 1, tp2d=tp2d)
        fn = jax.jit(shard_map(
            serve, mesh=MESH, in_specs=(pspecs, cspecs, tok_spec),
            out_specs=(tok_spec, cspecs), check_vma=False))
        cache = init_cache(cfg, S, batch)
        preds = []
        for i in range(S):
            nxt, cache = fn(params, cache, tokens[i])
            preds.append(np.asarray(nxt))
        return np.stack(preds)

    # local oracle
    serve_l = jax.jit(make_serve_step(cfg))
    cache = init_cache(cfg, S, batch)
    oracle = []
    for i in range(S):
        nxt, cache = serve_l(params, cache, tokens[i])
        oracle.append(np.asarray(nxt))
    oracle = np.stack(oracle)

    classic = run(False)
    tp2d = run(True)
    a1 = (classic == oracle).mean()
    a2 = (tp2d == oracle).mean()
    print(f"{cfg.name:10s} classic={a1:.3f} tp2d={a2:.3f}")
    assert a1 > 0.95 and a2 > 0.95, (cfg.name, a1, a2)


check(ModelConfig(name="dense", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                  tp_target=4, dtype=F))
check(ModelConfig(name="gqa-par", family="dense", n_layers=2, d_model=64,
                  n_heads=8, n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                  norm="layernorm", parallel_block=True, tie_embeddings=True,
                  tp_target=4, dtype=F))
check(ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                  n_heads=0, n_kv_heads=0, d_ff=0, vocab=256, ssm_state=16,
                  ssm_headdim=16, ssm_chunk=8, tp_target=4, dtype=F))
check(ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=96, vocab=256, n_experts=8,
                  top_k=2, tp_target=4, dtype=F, capacity_factor=8.0,
                  shared_expert_ff=64))
print("HELPER-OK")
