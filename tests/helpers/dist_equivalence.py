"""Helper: distributed loss AND gradients equal the local oracle, for all
families × {BSP, LCI_DEDICATED}.  Run with 8 fake devices ((2,4) mesh)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map

from repro.core.modes import CommConfig, CommMode
from repro.distributed.comm import Comm, local_comm
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import grad_sync

MESH = make_mesh((2, 4), ("data", "model"))
F = jnp.float32


def check(cfg, extra=None, extra_spec=None, grad_check=False):
    m = build_model(cfg)
    params, specs = m.init(jax.random.PRNGKey(0))
    s, b = 32, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (s, b), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    bspec = {"tokens": P("model", "data"), "labels": P("model", "data")}
    if extra:
        batch.update(extra)
        bspec.update(extra_spec)

    loss_l, _ = jax.jit(lambda p, bt: m.loss(p, bt, local_comm()))(
        params, batch)
    grads_l = None
    if grad_check:
        grads_l = jax.jit(jax.grad(
            lambda p: m.loss(p, batch, local_comm())[0]))(params)

    pspecs = jax.tree_util.tree_map(lambda sp: sp.pspec(), specs)
    for mode in (CommMode.BSP, CommMode.LCI_DEDICATED):
        comm = Comm(CommConfig(mode=mode), model_axis="model",
                    data_axis="data")

        def dist_loss(p, bt):
            loss, _ = m.loss(p, bt, comm)
            return comm.pmean_data(loss)

        f = jax.jit(shard_map(dist_loss, mesh=MESH,
                                  in_specs=(pspecs, bspec), out_specs=P(),
                                  check_vma=False))
        loss_d = f(params, batch)
        d = abs(float(loss_l) - float(loss_d))
        assert d < 3e-3, (cfg.name, mode, float(loss_l), float(loss_d))
        print(f"OK loss {cfg.name:12s} {mode.value:14s} diff={d:.2e}")

        if grad_check:
            def dist_grads(p, bt):
                g = jax.grad(lambda pp: m.loss(pp, bt, comm)[0])(p)
                return grad_sync(g, specs, comm)

            fg = jax.jit(shard_map(dist_grads, mesh=MESH,
                                       in_specs=(pspecs, bspec),
                                       out_specs=pspecs, check_vma=False))
            grads_d = fg(params, batch)
            worst = 0.0
            for gl, gd in zip(jax.tree_util.tree_leaves(grads_l),
                              jax.tree_util.tree_leaves(grads_d)):
                gl, gd = np.asarray(gl), np.asarray(gd)
                denom = max(np.abs(gl).max(), 1e-3)
                worst = max(worst, float(np.abs(gl - gd).max() / denom))
            assert worst < 3e-2, (cfg.name, mode, worst)
            print(f"OK grad {cfg.name:12s} {mode.value:14s} "
                  f"rel_err={worst:.2e}")


def main():
    check(ModelConfig(name="planA", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      tp_target=4, dtype=F), grad_check=True)
    check(ModelConfig(name="planA-kvrep", family="dense", n_layers=2,
                      d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
                      vocab=256, tp_target=4, dtype=F, head_dim=16),
          grad_check=True)
    check(ModelConfig(name="planB-swa", family="dense", n_layers=2,
                      d_model=64, n_heads=3, n_kv_heads=3, d_ff=128,
                      vocab=256, tp_target=4, dtype=F, head_dim=16,
                      sliding_window=8, swa_every_nth_global=2))
    check(ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=96, vocab=256,
                      n_experts=8, top_k=2, tp_target=4, dtype=F,
                      capacity_factor=8.0, shared_expert_ff=64),
          grad_check=True)
    check(ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
                      ssm_state=16, ssm_headdim=16, ssm_chunk=8,
                      tp_target=4, dtype=F), grad_check=True)
    check(ModelConfig(name="hybrid", family="hybrid", n_layers=2,
                      d_model=64, n_heads=5, n_kv_heads=5, d_ff=128,
                      vocab=256, ssm_state=8, ssm_headdim=16, ssm_chunk=8,
                      tp_target=4, dtype=F, head_dim=16))
    check(ModelConfig(name="vlm", family="vlm", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      cross_attn_every=2, tp_target=4, dtype=F),
          extra={"image_embeds": jax.random.normal(
              jax.random.PRNGKey(5), (8, 4, 64), F)},
          extra_spec={"image_embeds": P(None, "data", None)})
    check(ModelConfig(name="whisper", family="audio", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab=256, norm="layernorm", mlp="gelu",
                      encoder_layers=2, tp_target=4, dtype=F,
                      tie_embeddings=True),
          extra={"frames": jax.random.normal(
              jax.random.PRNGKey(6), (16, 4, 64), F)},
          extra_spec={"frames": P("model", "data", None)})


if __name__ == "__main__":
    main()
    print("HELPER-OK")
