"""The unified `comp` surface (paper §3.2.5/§4.1.4) and the async graph.

Covers the redesign's contracts:

* every completion object allocated from a runtime satisfies one
  protocol — ``signal(Status) -> Status``, non-blocking ``test()``,
  progress-driven ``wait()``;
* the progress engine handles ``retry(RETRY_QUEUE_FULL)`` uniformly via
  the device backlog (redelivery, no drops);
* ``CompletionGraph`` is a true completion object: comm nodes (unfired
  OFF builders) are posted by ``graph.start()`` and completed by the
  progress engine — the acceptance scenario asserts a send/recv pair
  completes with no host-side synchronous fire and that the
  ``execute()`` shim matches the async path;
* Table-1 classify edge rows and OFF builder introspection/reuse;
* endpoint-centric posting (``endpoint=`` routing + Endpoint.post_comm).
"""
import numpy as np
import pytest

from repro.core import (CommConfig, Direction, FatalError, LocalCluster,
                        OffBuilder, Status, classify, done, off, post_am_x,
                        post_recv_x, post_send_x)
from repro.core.post import CommKind, post_comm_x

CFG = CommConfig(inject_max_bytes=64, bufcopy_max_bytes=512)


@pytest.fixture()
def pair():
    cl = LocalCluster(2, CFG)
    return cl, cl[0], cl[1]


# ---------------------------------------------------------------------------
# unified protocol: signal returns Status; test/wait everywhere
# ---------------------------------------------------------------------------

class TestUnifiedProtocol:
    def test_all_alloc_objects_share_the_protocol(self, pair):
        cl, r0, r1 = pair
        comps = [r0.alloc_handler(lambda s: None), r0.alloc_cq(),
                 r0.alloc_sync(1), r0.alloc_graph()]
        for comp in comps:
            assert callable(comp.signal) and callable(comp.test) \
                and callable(comp.wait), comp

    def test_signal_returns_status(self, pair):
        cl, r0, r1 = pair
        st = done(b"x", rank=0, tag=1)
        assert r0.alloc_handler(lambda s: None).signal(st).is_done()
        assert r0.alloc_cq().signal(st).is_done()
        assert r0.alloc_sync(2).signal(st).is_done()
        cq = r0.alloc_cq(capacity=1)
        assert cq.signal(st).is_done()
        assert cq.signal(st).is_retry()          # full -> retry, not raise

    def test_handler_test_and_wait(self, pair):
        cl, r0, r1 = pair
        seen = []
        h = r0.alloc_handler(seen.append)
        assert h.test() == (False, None)
        h.signal(done(7))
        ok, last = h.test()
        assert ok and last.get_buffer() == 7 and seen
        assert h.wait().get_buffer() == 7        # already ready: no driver

    def test_cq_wait_drives_progress_and_pops(self, pair):
        cl, r0, r1 = pair
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        post_am_x(r0, 1, np.arange(8, dtype=np.uint8), None, None,
                  rc).tag(3)()
        assert cq.test() == (False, None)        # nothing moved yet
        msg = cq.wait(cl)                        # caller names the driver
        assert msg.is_done() and msg.tag == 3 and len(cq) == 0

    def test_sync_wait_returns_payload_list(self, pair):
        cl, r0, r1 = pair
        sy = r1.alloc_sync(2)
        post_am_x(r0, 1, np.zeros(256, np.uint8), None, None,
                  r1.register_rcomp(sy))()
        post_am_x(r0, 1, np.zeros(256, np.uint8), None, None,
                  r1.register_rcomp(sy))()
        got = sy.wait(cl)
        assert len(got) == 2 and all(s.is_done() for s in got)

    def test_wait_times_out_fatally(self, pair):
        cl, r0, r1 = pair
        sy = r0.alloc_sync(1)
        with pytest.raises(FatalError, match="not ready"):
            sy.wait(cl, max_rounds=10)


class TestUniformRetryHandling:
    def test_full_cq_signal_parked_and_redelivered(self, pair):
        """retry(RETRY_QUEUE_FULL) goes to the backlog, uniformly, and the
        next progress pass redelivers — no message is dropped."""
        cl, r0, r1 = pair
        cq = r1.alloc_cq(capacity=1)
        rc = r1.register_rcomp(cq)
        for i in range(3):
            post_am_x(r0, 1, np.full(8, i, np.uint8), None, None,
                      rc).tag(i)()
        cl.quiesce()                             # delivers 1, parks 2
        seen = []
        for _ in range(3):
            seen.append(int(cq.wait(cl).get_buffer()[0]))
        assert sorted(seen) == [0, 1, 2]
        assert cq.pop().is_retry()               # nothing duplicated


# ---------------------------------------------------------------------------
# the async graph: comm nodes completed by the progress engine
# ---------------------------------------------------------------------------

class TestAsyncGraph:
    def test_send_recv_comm_nodes_async_acceptance(self, pair):
        """Acceptance: a graph holding a send/recv pair as comm nodes
        completes via start() + progress-engine signaling, and the
        execute() shim matches the async path."""
        cl, r0, r1 = pair
        buf = np.zeros(256, np.uint8)            # bufcopy: must be *posted*
        data = np.arange(256, dtype=np.uint8)
        g = r0.alloc_graph("pair")
        recv = g.add_comm(post_recv_x(r1, 0, buf, 256, 5), name="recv")
        send = g.add_comm(post_send_x(r0, 1, data, 256, 5), name="send")
        joined = []
        join = g.add_node(lambda r, s: joined.append((r, s)) or "joined",
                          deps=[recv, send], name="join")

        g.start()
        ready, _ = g.test()
        assert not ready                         # no host-side synchronous fire
        assert not joined
        while not g.test()[0]:                   # progress engine completes it
            cl.progress_all()
        async_vals = g.test()[1]
        g.assert_partial_order()
        assert np.array_equal(buf, data)
        assert async_vals[join] == "joined"
        # comm node values are the completion statuses
        assert isinstance(async_vals[recv], Status)
        assert async_vals[recv].is_done()

        # the execute() shim (start + drain) reproduces the async result
        buf[:] = 0
        shim_vals = g.execute()
        g.assert_partial_order()
        assert np.array_equal(buf, data)
        assert shim_vals[join] == async_vals[join]
        assert shim_vals.keys() == async_vals.keys()

    def test_comm_chain_partial_order(self, pair):
        """send_i fires only after recv_{i-1} completed — the wire carries
        the dependency."""
        cl, r0, r1 = pair
        n = 4
        bufs = [np.zeros(8, np.uint8) for _ in range(n)]
        g = r0.alloc_graph("chain")
        prev = None
        ids = []
        for i in range(n):
            src, dst = (0, 1) if i % 2 == 0 else (1, 0)
            r = g.add_comm(post_recv_x(cl[dst], src, bufs[i], 8, i),
                           name=f"recv{i}")
            s = g.add_comm(post_send_x(cl[src], dst,
                                       np.full(8, i, np.uint8), 8, i),
                           deps=[prev] if prev is not None else [],
                           name=f"send{i}")
            ids.append((r, s))
            prev = r
        g.start()
        vals = g.wait()                          # auto-drives the cluster
        g.assert_partial_order()
        for i, buf in enumerate(bufs):
            assert np.all(buf == i)
        pos = {nid: k for k, nid in enumerate(g.fire_order)}
        for (r_prev, _), (_, s_next) in zip(ids, ids[1:]):
            assert pos[r_prev] < pos[s_next]

    def test_graph_as_completion_object_signal_nodes(self, pair):
        """graph.signal() (the comp protocol) completes signal nodes —
        the graph can be the completion object of outside operations."""
        cl, r0, r1 = pair
        g = r1.alloc_graph("sig")
        trigger = g.add_signal_node(name="external")
        fired = []
        g.add_node(lambda s: fired.append(s), deps=[trigger])
        g.start()
        assert not g.test()[0]
        # the graph IS the remote completion object of an active message
        rc = r1.register_rcomp(g)
        post_am_x(r0, 1, np.full(8, 5, np.uint8), None, None, rc)()
        g.wait(cl)
        assert g.test()[0] and fired
        assert int(fired[0].get_buffer()[0]) == 5
        g.assert_partial_order()

    def test_signal_without_signal_nodes_is_fatal(self, pair):
        cl, r0, r1 = pair
        g = r0.alloc_graph("sig2")
        with pytest.raises(FatalError, match="no signal nodes"):
            g.signal(done())

    def test_comm_node_rejects_bound_local_comp(self, pair):
        cl, r0, r1 = pair
        g = r0.alloc_graph()
        h = r0.alloc_handler(lambda s: None)
        with pytest.raises(FatalError, match="local_comp"):
            g.add_comm(post_send_x(r0, 1, np.zeros(8, np.uint8), 8,
                                   0).local_comp(h))
        with pytest.raises(FatalError, match="OFF builder"):
            g.add_comm(lambda: None)

    def test_restart_inflight_rejected(self, pair):
        cl, r0, r1 = pair
        g = r0.alloc_graph()
        g.add_comm(post_send_x(r0, 1, np.zeros(256, np.uint8), 256, 1))
        g.start()
        with pytest.raises(FatalError, match="in flight"):
            g.start()
        cl.quiesce()


# ---------------------------------------------------------------------------
# Table-1 classify edge rows + OFF introspection/reuse (satellites)
# ---------------------------------------------------------------------------

class TestTable1EdgeRows:
    def test_in_with_remote_comp_without_remote_buf_is_fatal(self):
        with pytest.raises(FatalError, match="Table 1"):
            classify(Direction.IN, None, remote_comp=7)

    def test_in_with_remote_comp_without_remote_buf_via_post(self, pair):
        cl, r0, r1 = pair
        with pytest.raises(FatalError, match="Table 1"):
            post_comm_x(r0, Direction.IN, 1, np.zeros(8, np.uint8)) \
                .remote_comp(3)()

    def test_get_with_signal_not_implemented(self, pair):
        cl, r0, r1 = pair
        assert classify(Direction.IN, "buf", None) == CommKind.GET
        with pytest.raises(NotImplementedError, match="RDMA read"):
            classify(Direction.IN, "buf", 1)
        region = r1.register_memory(np.zeros(8, np.uint8))
        with pytest.raises(NotImplementedError):
            post_comm_x(r0, Direction.IN, 1, np.zeros(8, np.uint8)) \
                .remote_buf((region.rid, 0)).remote_comp(1)()


class TestOffIntrospection:
    def test_options_enumerates_set_values(self, pair):
        cl, r0, r1 = pair
        b = post_send_x(r0, 1, np.zeros(8, np.uint8)).tag(9) \
            .allow_retry(False)
        assert b.options() == {"tag": 9, "allow_retry": False}

    def test_unknown_option_typeerror_names_valid_set(self):
        @off
        def op(a, *, known=0):
            return a

        with pytest.raises(TypeError, match="known"):
            op.x(1).bogus(2)

    def test_is_set_and_get_see_positional_bindings(self, pair):
        cl, r0, r1 = pair
        b = post_send_x(r0, 1, np.zeros(8, np.uint8), 8, 4)
        assert b.is_set("tag") and b.get("tag") == 4
        assert not b.is_set("local_comp") and b.get("local_comp") is None
        b.set("tag", 11)                         # rebinds the positional
        assert b.get("tag") == 11

    def test_builder_reuse_posts_twice(self, pair):
        cl, r0, r1 = pair
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        b = post_am_x(r0, 1, np.arange(8, dtype=np.uint8), None, None, rc)
        assert isinstance(b, OffBuilder)
        assert b().is_done() and b().is_done()   # a builder is a reusable value
        cl.quiesce()
        assert len(cq) == 2


class TestSchedulerUnifiedComp:
    def test_bounded_result_cq_never_drops_completions(self):
        """A full client CQ rejects the result signal with retry; the
        scheduler parks and redelivers it instead of dropping tokens."""
        from repro.serving.kv_cache import PagedKVAllocator
        from repro.serving.scheduler import ServeScheduler
        sched = ServeScheduler(lambda toks, pos: toks, max_batch=8,
                               allocator=PagedKVAllocator(n_pages=64,
                                                          page_size=16))
        cq = sched.alloc_cq(capacity=2)          # unified comp API
        for _ in range(5):
            st = sched.submit(np.array([1, 2], np.int32), 1, comp=cq)
            assert st.is_posted()
        while sched.completed < 5:
            sched.step()
        got = 0
        for _ in range(50):
            st = cq.pop()
            if st.is_retry():
                if got == 5:
                    break
                sched.step()                     # redelivers parked signals
                continue
            got += 1
        assert got == 5


# ---------------------------------------------------------------------------
# endpoint-centric posting
# ---------------------------------------------------------------------------

class TestEndpointPosting:
    def test_endpoint_kwarg_routes_onto_the_bundle(self):
        cl = LocalCluster(2, CFG)
        eps = cl.alloc_endpoint(n_devices=2, stripe="round_robin",
                                name="kw")
        for i in range(4):
            post_send_x(cl[0], 1, np.zeros(8, np.uint8), 8,
                        i).endpoint(eps[0])()
            post_recv_x(cl[1], 0, np.zeros(8, np.uint8), 8,
                        i).endpoint(eps[1])()
        cl.quiesce()
        posts = [d["posts"] for d in eps[0].counters()["devices"]]
        assert all(p > 0 for p in posts), posts

    def test_endpoint_and_device_are_exclusive(self):
        cl = LocalCluster(2, CFG)
        eps = cl.alloc_endpoint(name="x")
        with pytest.raises(FatalError, match="not both"):
            post_send_x(cl[0], 1, np.zeros(8, np.uint8), 8, 0) \
                .endpoint(eps[0]).device(cl[0].default_device)()

    def test_foreign_endpoint_rejected(self):
        cl = LocalCluster(2, CFG)
        eps = cl.alloc_endpoint(name="f")
        with pytest.raises(FatalError, match="belongs to"):
            post_send_x(cl[0], 1, np.zeros(8, np.uint8), 8, 0) \
                .endpoint(eps[1])()

    def test_endpoint_post_comm_generic(self):
        cl = LocalCluster(2, CFG)
        eps = cl.alloc_endpoint(n_devices=2, name="g")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        st = eps[0].post_comm(Direction.OUT, 1, np.arange(8, dtype=np.uint8),
                              remote_comp=rc, tag=2)
        assert st.is_done()                      # inject AM
        assert cq.wait(cl).tag == 2
