"""Concurrency subsystem (paper §4.1/§4.2) — invariants under real threads.

Every stress test here is timeout-bounded (threads are joined with a
deadline and the test fails loudly if one is stuck) so a deadlock in the
lock discipline fails fast instead of hanging CI.

Covered invariants:
* TryLock — mutual exclusion, contention counting, spin-backoff fallback.
* Atomics — exact counts under N incrementing threads, CAS semantics,
  bounded credits never oversubscribe.
* LCQ — no lost or duplicated items through N producers / M consumers.
* HostPacketPool — no double-allocated packet ids under concurrent
  get/put/steal; conservation of packets.
* HostMatchingEngine — per-bucket insert linearizability (every match
  pairs exactly one send with one recv; nothing matched twice).
* BacklogQueue — thread-safe, and ``push_front`` redelivery can never
  fail at capacity (regression: a full backlog must still redeliver in
  FIFO order).
* ProgressWorkerPool / EndpointSpec(progress="workers") — worker threads
  drive real traffic to completion with zero losses.
* ServeScheduler.start_result_drain — results drained from worker
  threads arrive exactly once.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                      # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (LCQ, AtomicCounter, AtomicCredit, AtomicFlag,
                        BacklogQueue, CommConfig, EndpointSpec, FatalError,
                        HostMatchingEngine, HostPacketPool, LocalCluster,
                        MatchKind, ProgressWorkerPool,
                        ThreadSafeCompletionQueue, TryLock, done, post_am_x)
from repro.core.concurrency.lcq import drain as lcq_drain
from repro.core.packet_pool import init_pool, pool_get
from repro.core.status import ErrorCode

JOIN_TIMEOUT = 30.0          # any thread alive after this = deadlock = fail


def run_threads(fns, timeout=JOIN_TIMEOUT):
    """Start one thread per fn, join with a deadline, surface errors."""
    errors = []

    def wrap(fn):
        def inner():
            try:
                fn()
            except BaseException as e:                   # re-raised below
                errors.append(e)
        return inner

    threads = [threading.Thread(target=wrap(fn), daemon=True)
               for fn in fns]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(max(0.0, deadline - time.monotonic()))
    stuck = [t for t in threads if t.is_alive()]
    assert not stuck, f"threads wedged (deadlock?): {stuck}"
    if errors:
        raise errors[0]


@pytest.fixture(autouse=True)
def fast_gil_switching():
    """Preempt every 50us so threads really interleave inside critical
    sections — otherwise CPython's 5ms default hides most races."""
    import sys
    old = sys.getswitchinterval()
    sys.setswitchinterval(5e-5)
    yield
    sys.setswitchinterval(old)


# ---------------------------------------------------------------------------
# locks
# ---------------------------------------------------------------------------

class TestTryLock:
    def test_try_acquire_counts_contention(self):
        lk = TryLock(name="t")
        assert lk.try_acquire()
        assert not lk.try_acquire()      # non-reentrant: second try fails
        assert lk.contentions == 1
        lk.release()
        assert lk.try_acquire()
        lk.release()
        assert lk.acquisitions == 2

    def test_reentrant_variant(self):
        lk = TryLock(name="r", reentrant=True)
        with lk:
            with lk:                      # same thread: legal
                pass
        # another thread cannot take it while held
        lk.acquire()
        saw = []
        run_threads([lambda: saw.append(lk.try_acquire())])
        lk.release()
        assert saw == [False]

    def test_mutual_exclusion_under_stress(self):
        lk = TryLock(name="mx")
        counter = {"v": 0}               # plain int: the lock protects it
        N, T = 2000, 4

        def worker():
            for _ in range(N):
                lk.acquire()             # spin-backoff blocking path
                counter["v"] += 1
                lk.release()

        run_threads([worker] * T)
        assert counter["v"] == N * T
        assert lk.acquisitions == N * T

    def test_stats_shape(self):
        lk = TryLock(name="s")
        row = lk.stats()
        assert set(row) == {"name", "acquisitions", "contentions", "spins"}


# ---------------------------------------------------------------------------
# atomics
# ---------------------------------------------------------------------------

class TestAtomics:
    def test_counter_exact_under_threads(self):
        c = AtomicCounter()
        N, T = 5000, 4
        run_threads([lambda: [c.fetch_add(1) for _ in range(N)]] * T)
        assert c.load() == N * T

    def test_fetch_add_tickets_unique(self):
        c = AtomicCounter()
        tickets = [[] for _ in range(4)]

        def taker(out):
            for _ in range(1000):
                out.append(c.fetch_add(1))

        run_threads([lambda o=o: taker(o) for o in tickets])
        flat = [t for chunk in tickets for t in chunk]
        assert sorted(flat) == list(range(4000))     # no dup, no gap

    def test_compare_exchange(self):
        c = AtomicCounter(5)
        assert not c.compare_exchange(4, 9)
        assert c.compare_exchange(5, 9)
        assert c.load() == 9

    def test_flag(self):
        f = AtomicFlag()
        assert not f.test_and_set()
        assert f.test_and_set()
        f.clear()
        assert not f.is_set()

    def test_credit_never_oversubscribes(self):
        cr = AtomicCredit(10)
        holders = AtomicCounter()
        peak = AtomicCounter()

        def worker():
            for _ in range(500):
                if cr.try_acquire():
                    n = holders.add(1)
                    # racy max is fine: only used as a lower bound probe
                    if n > peak.load():
                        peak.store(n)
                    assert n <= 10, "credit oversubscribed"
                    holders.add(-1)
                    cr.release()

        run_threads([worker] * 4)
        assert cr.used == 0
        assert peak.load() <= 10


# ---------------------------------------------------------------------------
# LCQ: the FAA fixed-size MPMC queue
# ---------------------------------------------------------------------------

class TestLCQ:
    def test_fifo_single_thread(self):
        q = LCQ(4)
        for i in range(4):
            assert q.push(i)
        assert not q.push(99)            # full -> non-blocking False
        assert [q.pop()[0] for _ in range(4)] == [0, 1, 2, 3]
        assert q.pop() == (None, False)  # empty
        # wrap-around lap
        assert q.push(7) and q.pop() == (7, True)

    def test_no_lost_no_dup_mpmc(self):
        """N producers, M consumers: every pushed item popped exactly once."""
        q = LCQ(64)                      # small: forces full/empty races
        NP, NC, PER = 4, 4, 3000
        popped = [[] for _ in range(NC)]
        produced = AtomicCounter()
        done_flag = AtomicFlag()

        def producer(base):
            for i in range(PER):
                item = base * PER + i
                while not q.push(item):
                    time.sleep(1e-6)     # full: back off, never drop
                produced.fetch_add(1)

        def consumer(out):
            while True:
                item, ok = q.pop()
                if ok:
                    out.append(item)
                elif done_flag.is_set() and not len(q):
                    item, ok = q.pop()   # final race-free sweep
                    if ok:
                        out.append(item)
                    else:
                        return
                else:
                    time.sleep(1e-6)

        producers = [lambda b=b: producer(b) for b in range(NP)]

        def run_all():
            errors = []
            cthreads = [threading.Thread(target=lambda o=o: consumer(o),
                                         daemon=True) for o in popped]
            for t in cthreads:
                t.start()
            run_threads(producers)
            done_flag.test_and_set()
            deadline = time.monotonic() + JOIN_TIMEOUT
            for t in cthreads:
                t.join(max(0.0, deadline - time.monotonic()))
            assert not any(t.is_alive() for t in cthreads), "consumer stuck"

        run_all()
        flat = sorted(x for chunk in popped for x in chunk)
        assert flat == list(range(NP * PER)), (
            f"lost={NP * PER - len(flat)} or duplicated")

    def test_push_many_pop_many_single_thread(self):
        q = LCQ(8)
        assert q.push_many(list(range(5))) == 5
        assert q.pop_many(3) == [0, 1, 2]
        assert q.push_many(list(range(5, 12))) == 6   # only 6 slots free
        assert q.pop_many() == [3, 4, 5, 6, 7, 8, 9, 10]
        assert q.pop_many() == []                     # empty
        # scalar/batch interleave across wrap-around laps
        for _ in range(5):
            assert q.push(99)
            assert q.push_many([1, 2]) == 2
            assert q.pop() == (99, True)
            assert q.pop_many() == [1, 2]

    def test_push_many_full_accepts_zero(self):
        q = LCQ(4)
        assert q.push_many([0, 1, 2, 3]) == 4
        assert q.push_many([9, 9]) == 0               # full, nothing lost
        assert q.pop_many() == [0, 1, 2, 3]

    def test_batch_mpmc_no_lost_no_dup(self):
        """Mixed scalar/batch producers and consumers: every item popped
        exactly once (the single-CAS bulk ticket claims must not double-
        grant or skip slots under contention)."""
        q = LCQ(64)
        NP, NC, PER = 4, 4, 3000
        popped = [[] for _ in range(NC)]
        done_flag = AtomicFlag()

        def producer(base):
            rng = random.Random(base)
            i = 0
            while i < PER:
                hi = min(i + rng.randint(1, 7), PER)
                if rng.random() < 0.3:
                    if q.push(base * PER + i):
                        i += 1
                else:
                    i += q.push_many([base * PER + j
                                      for j in range(i, hi)])
                if i < PER:
                    time.sleep(0)

        def consumer(out):
            rng = random.Random(id(out))
            while True:
                got = q.pop_many(rng.randint(1, 9))
                if got:
                    out.extend(got)
                elif done_flag.is_set() and not len(q):
                    out.extend(q.pop_many())          # final sweep
                    if not len(q):
                        return
                else:
                    time.sleep(1e-6)

        cthreads = [threading.Thread(target=lambda o=o: consumer(o),
                                     daemon=True) for o in popped]
        for t in cthreads:
            t.start()
        run_threads([lambda b=b: producer(b) for b in range(NP)])
        done_flag.test_and_set()
        deadline = time.monotonic() + JOIN_TIMEOUT
        for t in cthreads:
            t.join(max(0.0, deadline - time.monotonic()))
        assert not any(t.is_alive() for t in cthreads), "consumer stuck"
        flat = sorted(x for chunk in popped for x in chunk)
        assert flat == list(range(NP * PER)), (
            f"lost={NP * PER - len(flat)} or duplicated")

    def test_threadsafe_cq_signal_many_prefix(self):
        cq = ThreadSafeCompletionQueue(capacity=16)
        res = cq.signal_many([done(tag=i) for i in range(20)])
        assert [r.is_retry() for r in res] == [False] * 16 + [True] * 4
        assert all(r.code == ErrorCode.RETRY_QUEUE_FULL for r in res[16:])
        assert [s.tag for s in cq.pop_many()] == list(range(16))
        assert cq.pop_many() == []
        assert lcq_drain(cq) == []                    # bulk drain path

    def test_threadsafe_cq_protocol(self):
        cq = ThreadSafeCompletionQueue(capacity=2)
        assert cq.signal(done(1)).is_done()
        assert cq.signal(done(2)).is_done()
        st = cq.signal(done(3))
        assert st.is_retry() and st.code == ErrorCode.RETRY_QUEUE_FULL
        ready, _ = cq.test()
        assert ready
        assert cq.pop().get_buffer() == 1        # FIFO
        assert cq.signal(done(3)).is_done()      # slot freed


# ---------------------------------------------------------------------------
# packet pool under concurrent get/put/steal
# ---------------------------------------------------------------------------

class TestPacketPoolThreaded:
    def test_no_double_allocation(self):
        """Under concurrent get/put/steal no packet id is ever held by two
        lanes at once, and every packet survives the churn."""
        pool = HostPacketPool(n_lanes=4, packets_per_lane=8)
        in_use = [AtomicFlag() for _ in range(pool.n_packets)]
        T, N = 4, 4000

        def worker(lane):
            held = []
            for i in range(N):
                pkt, st = pool.get(lane)
                if st.is_done():
                    assert not in_use[pkt].test_and_set(), (
                        f"packet {pkt} double-allocated")
                    held.append(pkt)
                if held and (i % 3 == 0 or len(held) > 4):
                    p = held.pop()
                    in_use[p].clear()
                    pool.put(lane, p)
            for p in held:
                in_use[p].clear()
                pool.put(lane, p)

        run_threads([lambda l=l: worker(l) for l in range(T)])
        assert pool.free_packets() == pool.n_packets, "packets leaked"
        assert pool.gets == T * N

    def test_steal_failure_is_retry_not_block(self):
        pool = HostPacketPool(n_lanes=2, packets_per_lane=4)
        # empty lane 0 so a get must steal from lane 1
        for _ in range(4):
            pool.get(0)
        # hold lane 1's lock from "another thread"
        acquired = []
        release = threading.Event()

        def holder():
            pool.locks[1].acquire()
            acquired.append(True)
            release.wait(JOIN_TIMEOUT)
            pool.locks[1].release()

        t = threading.Thread(target=holder, daemon=True)
        t.start()
        while not acquired:
            time.sleep(1e-4)
        pkt, st = pool.get(0)            # must not block on the victim
        release.set()
        t.join(JOIN_TIMEOUT)
        assert pkt == -1 and st.is_retry()
        assert st.code == ErrorCode.RETRY_NOPACKET
        assert pool.steal_lock_failures == 1


# ---------------------------------------------------------------------------
# functional pool: victim selection property (satellite fix)
# ---------------------------------------------------------------------------

class TestPoolGetVictim:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(min_value=1, max_value=8),
           st.integers(min_value=0, max_value=7),
           st.integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1))
    def test_victim_never_self(self, n_lanes, lane, seed):
        """For every (lanes, lane, seed) — including negative seeds — the
        steal path either succeeds from a *different* lane or retries;
        the chosen victim never aliases the caller's own lane."""
        lane = lane % n_lanes
        pool = init_pool(n_lanes, packets_per_lane=2)
        # empty the caller's lane so get() takes the steal path
        pool, a, _ = pool_get(pool, lane, 0)
        pool, b, _ = pool_get(pool, lane, 0)
        pool, pid, status = pool_get(pool, lane, seed)
        if n_lanes == 1:
            assert int(status) == 1      # only retry is possible
            return
        # mirror of the host formula, with the explicit non-negative mod
        offset = seed % max(n_lanes - 1, 1)
        victim = (lane + 1 + offset) % n_lanes
        assert victim != lane
        if int(status) == 0:
            assert int(pid) >= 0
            # the packet really came from the victim's seeded range
            assert int(pid) // 2 != lane or int(pid) in (int(a), int(b))


# ---------------------------------------------------------------------------
# matching engine linearizability
# ---------------------------------------------------------------------------

class TestMatchingThreaded:
    def test_insert_linearizable_per_bucket(self):
        """T threads concurrently insert sends+recvs on shared keys; every
        match must pair exactly one send with one recv — no value matched
        twice, none invented, and counts must reconcile."""
        me = HostMatchingEngine(n_buckets=16)
        T, PER_KEY = 4, 500
        keys = [("k", i) for i in range(8)]
        matched = [[] for _ in range(2 * T)]

        def inserter(kind, out, base):
            for i in range(PER_KEY):
                key = keys[i % len(keys)]
                got = me.insert(key, kind, (kind.name, base, i))
                if got is not None:
                    out.append(got)

        fns = []
        for t in range(T):
            fns.append(lambda o=matched[2 * t], b=t:
                       inserter(MatchKind.SEND, o, b))
            fns.append(lambda o=matched[2 * t + 1], b=t:
                       inserter(MatchKind.RECV, o, b))
        run_threads(fns)

        flat = [v for chunk in matched for v in chunk]
        assert len(set(flat)) == len(flat), "a value was matched twice"
        # a SEND insert returns a RECV value and vice versa
        assert me.matches == len(flat)
        assert me.inserts == 2 * T * PER_KEY
        assert me.pending() == me.inserts - 2 * me.matches


# ---------------------------------------------------------------------------
# backlog queue (incl. the push_front capacity-bypass regression)
# ---------------------------------------------------------------------------

class TestBacklogThreaded:
    def test_push_front_bypasses_capacity(self):
        """Regression: a full backlog must still accept a redelivery —
        push_front is a requeue of an already-admitted item and can never
        fail — and FIFO order must survive."""
        bq = BacklogQueue(capacity=2)
        assert bq.push("a").is_done()
        assert bq.push("b").is_done()
        assert bq.push("c").is_retry()           # tail respects capacity
        item, st = bq.pop()
        assert item == "a" and st.is_done()
        assert bq.push("x").is_done()            # full again: a,b -> b,x
        assert bq.push_front("a").is_done()      # redelivery MUST succeed
        assert len(bq) == 3                      # transiently over capacity
        order = []
        while True:
            item, st = bq.pop()
            if st.is_retry():
                break
            order.append(item)
        assert order == ["a", "b", "x"], "redelivery broke FIFO"

    def test_thread_safe_push_pop(self):
        bq = BacklogQueue()
        T, N = 4, 2000
        popped = [[] for _ in range(T)]
        stop = AtomicFlag()

        def producer(base):
            for i in range(N):
                assert bq.push((base, i)).is_done()

        def consumer(out):
            while True:
                item, st = bq.pop()
                if st.is_done():
                    out.append(item)
                elif stop.is_set() and bq.empty_flag:
                    return
                else:
                    time.sleep(1e-6)

        cthreads = [threading.Thread(target=lambda o=o: consumer(o),
                                     daemon=True) for o in popped]
        for t in cthreads:
            t.start()
        run_threads([lambda b=b: producer(b) for b in range(T)])
        stop.test_and_set()
        deadline = time.monotonic() + JOIN_TIMEOUT
        for t in cthreads:
            t.join(max(0.0, deadline - time.monotonic()))
        assert not any(t.is_alive() for t in cthreads)
        flat = [x for chunk in popped for x in chunk]
        assert sorted(flat) == sorted((b, i) for b in range(T)
                                      for i in range(N))


# ---------------------------------------------------------------------------
# progress workers end-to-end
# ---------------------------------------------------------------------------

def _post_all(r0, rc, n, dev=None, payload=None):
    payload = payload if payload is not None else np.zeros(8, np.uint8)
    sent = 0
    while sent < n:
        x = post_am_x(r0, 1, payload, None, None, rc)
        if dev is not None:
            x = x.device(dev)
        if not x().is_retry():
            sent += 1
        else:
            time.sleep(1e-5)
    return sent


class TestProgressWorkers:
    def test_worker_pool_delivers_everything(self):
        """Main thread posts; the worker pool alone drives all progress."""
        cfg = CommConfig(inject_max_bytes=1, packets_per_lane=64)
        cl = LocalCluster(2, cfg, fabric_depth=1 << 14)
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq(threadsafe=True)
        rc = r1.register_rcomp(cq)
        N = 500
        with cl.alloc_workers(n_workers=3):
            _post_all(r0, rc, N)
            deadline = time.monotonic() + JOIN_TIMEOUT
            while cq.pushes < N:
                assert time.monotonic() < deadline, (
                    f"workers stalled: {cq.pushes}/{N}")
                time.sleep(1e-4)
        assert cq.pushes == N, "lost completions"
        cl.quiesce()
        assert r0.packet_pool.free_packets() == r0.packet_pool.n_packets

    def test_try_progress_skips_held_device(self):
        cl = LocalCluster(2)
        r0, r1 = cl[0], cl[1]
        dev = r0.default_device
        # deliverable work on the device's incoming stream: an idle
        # device short-circuits to False before consulting the lock,
        # and this test is about the try-lock discipline
        cq = r0.alloc_cq(threadsafe=True)
        rc = r0.register_rcomp(cq)
        while post_am_x(r1, 0, np.zeros(8, np.uint8), None, None,
                        rc)().is_retry():
            time.sleep(1e-5)
        dev.progress_lock.acquire()
        held = []
        run_threads([lambda: held.append(r0.engine.try_progress(dev))])
        dev.progress_lock.release()
        assert held == [None]            # moved on, did not block
        assert r0.engine.try_progress(dev) is not None

    def test_try_progress_idle_fast_path(self):
        """An idle device reports False without taking the progress
        lock — even when another thread holds it."""
        cl = LocalCluster(2)
        r0 = cl[0]
        dev = r0.default_device
        dev.progress_lock.acquire()
        try:
            acqs = dev.progress_lock.stats()["acquisitions"]
            assert r0.engine.try_progress(dev) is False
            assert dev.progress_lock.stats()["acquisitions"] == acqs
        finally:
            dev.progress_lock.release()

    def test_endpoint_workers_spec(self):
        cfg = CommConfig(inject_max_bytes=1, packets_per_lane=64,
                         n_channels=2)
        cl = LocalCluster(2, cfg, fabric_depth=1 << 14)
        r0, r1 = cl[0], cl[1]
        spec = EndpointSpec(name="w", n_devices=2, progress="workers",
                            n_workers=2)
        ep0 = r0.alloc_endpoint(spec=spec)
        ep1 = r1.alloc_endpoint(spec=dataclasses.replace(spec, name="w1"))
        cq = r1.alloc_cq(threadsafe=True)
        rc = r1.register_rcomp(cq)
        N = 300
        with ep0, ep1:
            sent = 0
            while sent < N:
                if not ep0.post_am(1, np.zeros(8, np.uint8),
                                   remote_comp=rc).is_retry():
                    sent += 1
                else:
                    time.sleep(1e-5)
            deadline = time.monotonic() + JOIN_TIMEOUT
            while cq.pushes < N:
                assert time.monotonic() < deadline, "endpoint workers stalled"
                time.sleep(1e-4)
        assert cq.pushes == N
        counters = ep0.counters()
        assert counters["workers"]["n_workers"] == 2
        assert not ep0.workers.running   # context manager stopped them

    def test_workers_spec_validation(self):
        with pytest.raises(FatalError):
            EndpointSpec(progress="shared", n_workers=2)
        with pytest.raises(FatalError):
            EndpointSpec(progress="workers", n_workers=-1)
        cl = LocalCluster(1)
        ep = cl[0].alloc_endpoint(progress="shared")
        with pytest.raises(FatalError):
            ep.start_workers()

    def test_free_endpoint_stops_workers(self):
        cl = LocalCluster(1)
        ep = cl[0].alloc_endpoint(progress="workers", n_devices=1)
        ep.start_workers()
        assert ep.workers.running
        cl[0].free_endpoint(ep)
        assert not ep.workers.running


# ---------------------------------------------------------------------------
# scheduler result drain from worker threads
# ---------------------------------------------------------------------------

class TestSchedulerDrain:
    def _sched(self, max_batch=8):
        from repro.serving import PagedKVAllocator, ServeScheduler

        def decode_fn(tokens, positions):
            return np.asarray(tokens) + 1

        return ServeScheduler(decode_fn, max_batch=max_batch,
                              allocator=PagedKVAllocator(n_pages=64,
                                                         page_size=16))

    def test_results_drained_exactly_once(self):
        sched = self._sched()
        cq = sched.alloc_cq(threadsafe=True)
        N = 24
        for _ in range(N):
            sched.submit(np.array([1, 2, 3]), max_new=4, comp=cq,
                         allow_retry=False)
        drain = sched.start_result_drain(cq, n_workers=3)
        deadline = time.monotonic() + JOIN_TIMEOUT
        while sched.completed < N:
            assert time.monotonic() < deadline, "scheduler stalled"
            sched.step()
        results = drain.stop()
        assert len(results) == N, "a result was lost or duplicated"
        rids = [st.tag for st in results]
        assert len(set(rids)) == N

    def test_drain_requires_threadsafe_cq(self):
        sched = self._sched()
        with pytest.raises(FatalError):
            sched.start_result_drain(sched.alloc_cq(), n_workers=2)


# ---------------------------------------------------------------------------
# engine-level: no lost completions through the full posting path
# ---------------------------------------------------------------------------

class TestEndToEndStress:
    def test_posters_and_workers_no_lost_completions(self):
        """T poster threads + a worker pool, bufcopy protocol, small pool:
        steals, retries, and backlog all exercised; exact delivery count
        and full packet-pool conservation at the end."""
        T, PER = 3, 400
        cfg = CommConfig(inject_max_bytes=1, packets_per_lane=16,
                         n_channels=T)
        cl = LocalCluster(2, cfg, fabric_depth=256)
        r0, r1 = cl[0], cl[1]
        devs = [r0.alloc_device() for _ in range(T)]
        [r1.alloc_device() for _ in range(T)]
        cq = r1.alloc_cq(threadsafe=True)
        rc = r1.register_rcomp(cq)

        with cl.alloc_workers(n_workers=2):
            run_threads([lambda d=d: _post_all(r0, rc, PER, dev=d)
                         for d in devs])
            deadline = time.monotonic() + JOIN_TIMEOUT
            while cq.pushes < T * PER:
                assert time.monotonic() < deadline, (
                    f"stalled at {cq.pushes}/{T * PER}")
                time.sleep(1e-4)
        assert cq.pushes == T * PER
        cl.quiesce()
        assert r0.packet_pool.free_packets() == r0.packet_pool.n_packets
