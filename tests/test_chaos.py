"""Chaos plane end to end (DESIGN.md §16): fault injection, the
reliability protocol that survives it, post deadlines, rank death, codec
hardening, and the recovery pieces (straggler window, cfg-aware shrink,
mid-commit kill, spmd rank-kill smoke)."""
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # bare env: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import ErrorCode, LocalCluster, post_am, post_recv
from repro.core.transport.chaos import ChaosConfig, ChaosTransport
from repro.core.transport.codec import CodecError, decode_msg, encode_msg
from repro.core.transport.wire import WireKind, WireMsg

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
HELPERS = os.path.join(os.path.dirname(__file__), "helpers")

FAULTS = {"chaos_drop": 0.05, "chaos_dup": 0.05, "chaos_reorder": 0.05}


def _child_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # children must see the library defaults, not this process's CI leg
    for k in list(env):
        if k.startswith("REPRO_ATTR_CHAOS"):
            del env[k]
    return env


def _deliver_all(cl, sender, receiver, n, *, size=32):
    """Post n tagged AMs sender->receiver, quiesce, return delivered tags
    in arrival order."""
    cq = receiver.alloc_cq()
    rc = receiver.register_rcomp(cq)
    for i in range(n):
        buf = np.full(size, i % 256, np.uint8)
        st = post_am(sender, receiver.rank, buf, remote_comp=rc, tag=i)
        while st.is_retry():
            sender.progress()
            st = post_am(sender, receiver.rank, buf, remote_comp=rc, tag=i)
    cl.quiesce()
    tags = []
    while True:
        st = cq.pop()
        if st.is_retry():
            return tags
        assert st.is_done()
        tags.append(st.tag)


# ---------------------------------------------------------------------------
# fault injection mechanics (the wrapper itself)
# ---------------------------------------------------------------------------

class TestChaosTransport:
    def test_inactive_config_skips_wrap(self):
        cl = LocalCluster(2, attrs={"chaos_drop": 0.0, "chaos_dup": 0.0,
                                    "chaos_reorder": 0.0,
                                    "chaos_delay_p": 0.0})
        try:
            assert not isinstance(cl.fabric, ChaosTransport)
        finally:
            cl.close()

    def test_active_config_wraps_and_counts(self):
        cl = LocalCluster(2, attrs={"chaos_drop": 0.2, "chaos_seed": 3,
                                    **{k: 0.0 for k in
                                       ("chaos_dup", "chaos_reorder")}})
        try:
            fab = cl.fabric
            assert isinstance(fab, ChaosTransport)
            tags = _deliver_all(cl, cl[0], cl[1], 100)
            assert tags == list(range(100))           # healed, in order
            assert fab.dropped.load() > 0             # faults really fired
            assert cl[0].rel is not None              # auto-armed rel
            assert cl[0].rel.counters()["retransmits"] > 0
        finally:
            cl.close()

    def test_same_seed_same_fault_sequence(self):
        """Determinism: the same seed over the same push/drain pattern
        makes identical fault decisions (the replay contract).  Unit
        level on purpose — end to end, retransmit *timing* feeds back
        into the drain pattern, which is exactly what replay fixes."""
        from repro.core.transport.sim import Fabric

        def run(seed):
            chaos = ChaosTransport(
                Fabric(2), ChaosConfig(seed=seed, drop=0.3, dup=0.2,
                                       reorder=0.2))
            survived = []
            for i in range(40):
                msg = WireMsg(WireKind.EAGER_AM, 0, 1, tag=i,
                              payload=np.zeros(4, np.uint8), size=4,
                              rcomp=0, device_index=0)
                msg.seq, msg.epoch = i, 0          # fault-eligible
                assert chaos.try_push(msg)
                survived += [m.tag for m in chaos.drain(1, 0)]
            survived += [m.tag for m in chaos.drain(1, 0)]
            return survived

        assert run(11) == run(11)
        assert run(11) != run(12)

    def test_acks_never_faulted(self):
        """Control traffic (seq < 0) passes untouched even at drop=1:
        only reliability-stamped messages are fault-eligible."""
        from repro.core.transport.sim import Fabric
        chaos = ChaosTransport(Fabric(2), ChaosConfig(seed=1, drop=1.0))
        ack = WireMsg(WireKind.ACK, 0, 1, payload=(5, 0), device_index=0)
        assert ack.seq < 0
        assert chaos.try_push(ack)
        out = chaos.drain(1, 0)
        assert len(out) == 1 and out[0].kind == WireKind.ACK
        assert chaos.dropped.load() == 0

    def test_dead_rank_swallows_traffic(self):
        from repro.core.transport.sim import Fabric
        chaos = ChaosTransport(Fabric(2), ChaosConfig(kill_rank=1))
        msg = WireMsg(WireKind.EAGER_AM, 0, 1,
                      payload=np.zeros(8, np.uint8), size=8, rcomp=0,
                      device_index=0)
        assert chaos.try_push(msg)        # accepted-and-dropped, no wedge
        assert chaos.drain(1, 0) == []
        assert chaos.dead_dropped.load() > 0


# ---------------------------------------------------------------------------
# the reliability property: no loss, no dup, per-stream FIFO
# ---------------------------------------------------------------------------

class TestReliabilityProperty:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(20, 80))
    def test_exactly_once_in_order_sim(self, seed, n):
        cl = LocalCluster(2, attrs={"chaos_seed": seed, **FAULTS})
        try:
            tags = _deliver_all(cl, cl[0], cl[1], n)
            assert tags == list(range(n))
        finally:
            cl.close()

    @pytest.mark.parametrize("backend", ["sim", "shm", "socket"])
    def test_exactly_once_in_order_backends(self, backend):
        """The acceptance bar: 5% drop = dup = reorder on every backend,
        zero lost and zero duplicated completions."""
        cl = LocalCluster(2, attrs={"fabric_backend": backend,
                                    "chaos_seed": 1234, **FAULTS})
        try:
            tags = _deliver_all(cl, cl[0], cl[1], 120)
            assert tags == list(range(120))
        finally:
            cl.close()

    def test_bufcopy_source_comps_exactly_once(self):
        """Dropped-then-retransmitted bufcopy sends still signal their
        local comp exactly once (ack-driven completion)."""
        cl = LocalCluster(2, attrs={"chaos_seed": 5, "eager_max_bytes": 0,
                                    **FAULTS})
        try:
            scq = cl[0].alloc_cq()
            cq = cl[1].alloc_cq()
            rc = cl[1].register_rcomp(cq)
            for i in range(60):
                st = post_am(cl[0], 1, np.full(32, i % 256, np.uint8),
                             local_comp=scq, remote_comp=rc, tag=i)
                while st.is_retry():
                    cl[0].progress()
                    st = post_am(cl[0], 1, np.full(32, i, np.uint8),
                                 local_comp=scq, remote_comp=rc, tag=i)
            cl.quiesce()
            sends = 0
            while scq.pop().is_done():
                sends += 1
            assert sends == 60
            assert not cl[0].pending_ops        # nothing leaked
        finally:
            cl.close()

    def test_fused_doorbell_under_chaos(self):
        """Packed doorbells allocate per-row seqs: a dropped burst heals
        row-exact, delivered once each and in order."""
        cl = LocalCluster(2, attrs={"chaos_seed": 77, "doorbell_fused": True,
                                    "eager_max_bytes": 64, **FAULTS})
        try:
            eps = cl.alloc_endpoint(n_devices=1, name="burst")
            cq = cl[1].alloc_cq()
            rc = cl[1].register_rcomp(cq)
            total = 0
            for base in range(0, 120, 8):
                bufs = [np.full(16, (base + j) % 256, np.uint8)
                        for j in range(8)]
                sts = eps[0].post_am_many(1, bufs, rc,
                                          tags=list(range(base, base + 8)))
                total += len(sts)
                cl.progress_all()
            cl.quiesce()
            tags = []
            while True:
                st = cq.pop()
                if st.is_retry():
                    break
                tags.append(st.tag)
            assert tags == list(range(total))
        finally:
            cl.close()


# ---------------------------------------------------------------------------
# deadlines and rank death
# ---------------------------------------------------------------------------

class TestDeadlinesAndDeath:
    def test_post_deadline_expires_err_timeout(self):
        """drop=1.0: nothing ever arrives, so the completion deadline
        fires ERR_TIMEOUT on the send's comp exactly once."""
        cl = LocalCluster(2, attrs={"chaos_drop": 1.0, "chaos_seed": 2,
                                    "eager_max_bytes": 0,
                                    "post_deadline_us": 20_000,
                                    "retry_limit": 1_000_000})
        try:
            scq = cl[0].alloc_cq()
            cq = cl[1].alloc_cq()
            rc = cl[1].register_rcomp(cq)
            st = post_am(cl[0], 1, np.zeros(32, np.uint8),
                         local_comp=scq, remote_comp=rc, tag=9)
            assert st.is_posted()
            deadline = time.monotonic() + 10.0
            got = None
            while got is None and time.monotonic() < deadline:
                cl.progress_all()
                s = scq.pop()
                if not s.is_retry():
                    got = s
            assert got is not None and got.is_err()
            assert got.code == ErrorCode.ERR_TIMEOUT
        finally:
            cl.close()

    def test_recv_deadline_expires(self):
        cl = LocalCluster(2, attrs={"reliability": "on",
                                    "post_deadline_us": 10_000})
        try:
            cq = cl[1].alloc_cq()
            buf = np.zeros(16, np.uint8)
            st = post_recv(cl[1], 0, buf, 16, 3, cq)
            assert st.is_posted()
            deadline = time.monotonic() + 10.0
            got = None
            while got is None and time.monotonic() < deadline:
                cl.progress_all()
                s = cq.pop()
                if not s.is_retry():
                    got = s
            assert got is not None and got.is_err()
            assert got.code == ErrorCode.ERR_TIMEOUT
        finally:
            cl.close()

    def test_post_to_dead_peer_fails_at_post_time(self):
        cl = LocalCluster(2, attrs={"reliability": "on"})
        try:
            cl[0].mark_peer_dead(1)
            st = post_am(cl[0], 1, np.zeros(8, np.uint8), remote_comp=0)
            assert st.is_err() and st.code == ErrorCode.ERR_PEER_DEAD
        finally:
            cl.close()

    def test_in_flight_fails_peer_dead_on_death(self):
        """Posts outstanding when the peer dies complete ERR_PEER_DEAD on
        the next sweep — no hang, nothing leaked."""
        cl = LocalCluster(2, attrs={"chaos_drop": 1.0, "chaos_seed": 3,
                                    "eager_max_bytes": 0,
                                    "retry_limit": 1_000_000})
        try:
            scq = cl[0].alloc_cq()
            cq = cl[1].alloc_cq()
            rc = cl[1].register_rcomp(cq)
            for i in range(5):
                post_am(cl[0], 1, np.zeros(32, np.uint8),
                        local_comp=scq, remote_comp=rc, tag=i)
            assert cl[0].pending_ops
            cl[0].mark_peer_dead(1)
            deadline = time.monotonic() + 10.0
            codes = []
            while len(codes) < 5 and time.monotonic() < deadline:
                cl[0].progress()
                s = scq.pop()
                if not s.is_retry():
                    codes.append(s.code)
            assert codes == [ErrorCode.ERR_PEER_DEAD] * 5
            assert not cl[0].pending_ops
        finally:
            cl.close()


# ---------------------------------------------------------------------------
# codec hardening: corrupted bytes raise CodecError, never leak
# ---------------------------------------------------------------------------

def _sample_msg():
    return WireMsg(WireKind.EAGER_AM, 0, 1, tag=42,
                   payload=np.arange(24, dtype=np.uint8), size=24,
                   rcomp=3, device_index=1, seq=7, epoch=1)


class TestCodecFuzz:
    def test_roundtrip(self):
        frame = encode_msg(_sample_msg())
        msg, off = decode_msg(frame)
        assert off == len(frame)
        assert msg.tag == 42 and msg.seq == 7 and msg.epoch == 1
        np.testing.assert_array_equal(msg.payload,
                                      np.arange(24, dtype=np.uint8))

    def test_truncation_every_length(self):
        frame = encode_msg(_sample_msg())
        for n in range(len(frame)):
            with pytest.raises(CodecError):
                decode_msg(frame[:n])

    def test_bad_magic_and_version(self):
        frame = bytearray(encode_msg(_sample_msg()))
        bad = bytes([frame[0] ^ 0xFF]) + bytes(frame[1:])
        with pytest.raises(CodecError, match="magic"):
            decode_msg(bad)
        frame[2] ^= 0x55                          # version byte
        with pytest.raises(CodecError, match="version|magic"):
            decode_msg(bytes(frame))

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_single_bit_flips_never_leak(self, seed):
        """Any one-bit corruption either still parses to a message or
        raises CodecError — never struct.error / IndexError / ValueError.
        Payload-body flips are always *caught* (the crc32)."""
        import random
        rng = random.Random(seed)
        frame = bytearray(encode_msg(_sample_msg()))
        pos = rng.randrange(len(frame))
        frame[pos] ^= 1 << rng.randrange(8)
        body_start = len(frame) - 24              # _P_BYTES raw payload
        try:
            decode_msg(bytes(frame))
        except CodecError:
            return                                # typed failure: fine
        # parsed: the flip must have hit a header field the crc does not
        # cover — payload corruption can never slip through
        assert pos < body_start

    def test_torn_concatenation(self):
        """Frames back to back parse cleanly; a torn second frame fails
        typed, leaving the first intact."""
        a, b = encode_msg(_sample_msg()), encode_msg(_sample_msg())
        both = a + b[: len(b) // 2]
        msg, off = decode_msg(both)
        assert msg.tag == 42 and off == len(a)
        with pytest.raises(CodecError):
            decode_msg(both, off)


# ---------------------------------------------------------------------------
# recovery machinery: straggler window, cfg-aware shrink
# ---------------------------------------------------------------------------

class TestStragglerWindow:
    def test_consecutive_stragglers_both_flagged(self):
        """Regression: flagged samples stay out of the window, so two
        slow steps in a row cannot normalize each other."""
        from repro.distributed.straggler import StepTimeMonitor
        mon = StepTimeMonitor(window=20, z_threshold=3.0, warmup=5)
        for i in range(10):
            mon.record(i, 1.0 + 0.001 * (i % 3))
        assert mon.record(10, 5.0) is not None
        assert mon.record(11, 5.0) is not None    # second one still seen
        assert len(mon.flagged) == 2
        # the baseline is uncontaminated: a normal step is not flagged
        assert mon.record(12, 1.001) is None


class TestShrinkMeshCfg:
    def test_cfg_snaps_to_compatible(self):
        from repro.configs.gemma3_1b import SMOKE
        from repro.distributed.elastic import (compatible_meshes,
                                               shrink_mesh)
        shape = shrink_mesh((4, 2), 0.25, SMOKE)   # 8 -> target 6
        n = shape[0] * shape[1]
        assert n <= 6
        assert tuple(shape) in {(d, m) for d, m in
                                compatible_meshes(SMOKE, n)}

    def test_cfg_none_keeps_model_axis(self):
        from repro.distributed.elastic import shrink_mesh
        assert shrink_mesh((4, 2), 0.5) == (2, 2)

    def test_prefers_old_model_width(self):
        """Among equal device counts the old model width wins — the
        cheapest re-shard keeps the TP axis in place."""
        from repro.configs.gemma3_1b import SMOKE
        from repro.distributed.elastic import compatible_meshes, shrink_mesh
        shape = shrink_mesh((2, 2), 0.0, SMOKE)    # nothing died
        assert shape[0] * shape[1] == 4
        if (2, 2) in compatible_meshes(SMOKE, 4):
            assert shape == (2, 2)

    def test_incompatible_raises(self, monkeypatch):
        """Survivors that cannot host the model at any width get a typed
        error, not a silent bad mesh."""
        from repro.configs.gemma3_1b import SMOKE
        from repro.distributed import elastic
        monkeypatch.setattr(elastic, "compatible_meshes",
                            lambda cfg, n: [])
        with pytest.raises(ValueError, match="no mesh"):
            elastic.shrink_mesh((4, 2), 0.5, SMOKE)


# ---------------------------------------------------------------------------
# crash safety: mid-commit kill, spmd rank death
# ---------------------------------------------------------------------------

class TestMidCommitKill:
    def test_kill_during_commit_keeps_prior_checkpoint(self, tmp_path):
        from repro.checkpoint import latest_step, restore
        ckpt = str(tmp_path / "ckpt")
        proc = subprocess.Popen(
            [sys.executable, os.path.join(HELPERS, "ckpt_kill.py"), ckpt],
            stdout=subprocess.PIPE, text=True, env=_child_env())
        try:
            marker = proc.stdout.readline()
            assert "COMMITTING" in marker, marker
            proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=30)
        finally:
            if proc.poll() is None:
                proc.kill()
        assert proc.returncode == -signal.SIGKILL
        # the torn step-1 commit is invisible: LATEST still says 0 and
        # the restore verifies hashes cleanly
        assert latest_step(ckpt) == 0
        like = {"w": np.zeros(64, np.float64), "step": np.zeros((),
                                                               np.int64)}
        got, manifest = restore(ckpt, like)
        assert manifest["step"] == 0
        np.testing.assert_array_equal(got["w"],
                                      np.arange(64, dtype=np.float64))
        assert not os.path.exists(os.path.join(ckpt, "step_00000001"))


@pytest.mark.slow
class TestSpmdChaosKill:
    def test_rank_kill_recovers(self, tmp_path):
        """2-rank spmd job, launcher SIGKILLs rank 1 mid-stream: the
        survivor detects via heartbeat, completes outstanding posts as
        ERR_PEER_DEAD, shrinks the mesh, restores resharded — exit 0."""
        env = _child_env()
        env.setdefault("REPRO_ATTR_FABRIC_BACKEND", "shm")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.spmd", "--ranks", "2",
             "--chaos-kill", "1", "--kill-after", "0.5",
             "--hb-timeout", "1.0", "--timeout", "120"],
            capture_output=True, text=True, timeout=180, env=env)
        out = r.stdout + r.stderr
        assert r.returncode == 0, out
        assert "peer_dead" in out and "recovered" in out, out
