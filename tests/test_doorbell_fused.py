"""Fused doorbell data plane (DESIGN.md §13).

Covers the PR's tentpole end-to-end: the packed stage-copy
(``pack_payloads`` / the Pallas doorbell kernel), the single-descriptor
wire path (``push_packed`` / :class:`PackedBurst`), burst matching
(``match_now_n`` / ``match_now_burst`` / functional ``probe_batch``),
the fused allocate-and-stage (``pool_get_copy_n``), the ``wire_bf16``
compression attribute, and — the load-bearing property — byte- and
status-equivalence between the fused and the PR-4 scalar data planes.
"""
import sys

import numpy as np
import jax.numpy as jnp
import ml_dtypes
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (CommConfig, CommDesc, CommKind, HostMatchingEngine,
                        LocalCluster, MatchKind, MatchingPolicy, PackedBurst,
                        init_buffers, init_pool, init_table, insert_batch,
                        make_key, pack_payloads, pool_get_copy_n, post_recv,
                        probe, probe_batch)
from repro.core.progress.fabric import (Fabric, WireKind, WireMsg,
                                        payloads_to_bytes)
from repro.core.status import ErrorCode


# ---------------------------------------------------------------------------
# pack_payloads / payloads_to_bytes staging fast paths
# ---------------------------------------------------------------------------

class TestPackPayloads:
    def test_same_object_broadcast(self):
        p = np.arange(6, dtype=np.float32)
        data, sizes, wd = pack_payloads([p] * 5)
        assert data.shape == (5, 24) and wd is None
        assert data.strides[0] == 0                 # broadcast, no copies
        assert list(sizes) == [24] * 5
        assert np.array_equal(data[3], p.view(np.uint8))

    def test_uniform_stack(self):
        bufs = [np.full(4, i, np.int32) for i in range(6)]
        data, sizes, wd = pack_payloads(bufs)
        assert data.shape == (6, 16) and wd is None
        for i, b in enumerate(bufs):
            assert np.array_equal(data[i], b.view(np.uint8))

    def test_ragged_zero_padded(self):
        bufs = [np.arange(3, dtype=np.uint8), np.arange(7, dtype=np.uint8)]
        data, sizes, wd = pack_payloads(bufs)
        assert data.shape == (2, 7) and list(sizes) == [3, 7]
        assert np.array_equal(data[0, :3], bufs[0])
        assert not data[0, 3:].any()                # padding is zeros

    def test_bf16_applies_only_to_uniform_f32(self):
        f32 = [np.arange(4, dtype=np.float32)] * 3
        data, sizes, wd = pack_payloads(f32, wire_bf16=True)
        assert wd == "bf16" and data.shape == (3, 8)   # half the bytes
        assert list(sizes) == [16] * 3                 # delivered = f32
        ints = [np.arange(4, dtype=np.int32)] * 3
        data, _, wd = pack_payloads(ints, wire_bf16=True)
        assert wd is None and data.shape == (3, 16)    # bypass untouched

    def test_payloads_to_bytes_uniform_short_circuit(self):
        bufs = [np.full((2, 2), i, np.float64) for i in range(5)]
        fast = payloads_to_bytes(bufs)
        slow = [b.reshape(-1).view(np.uint8) for b in bufs]
        assert all(np.array_equal(f, s) for f, s in zip(fast, slow))

    def test_payloads_to_bytes_mixed_dtype_byte_exact(self):
        # regression for the stacked fast path: same nbytes, different
        # dtypes must still produce each payload's OWN bytes
        bufs = [np.arange(4, dtype=np.int32),
                np.arange(2, dtype=np.float64),
                np.frombuffer(b"0123456789abcdef", dtype=np.uint8).copy()]
        assert all(b.nbytes == 16 for b in bufs)
        out = payloads_to_bytes(bufs)
        for got, b in zip(out, bufs):
            assert np.array_equal(got, b.reshape(-1).view(np.uint8))


# ---------------------------------------------------------------------------
# PackedBurst + push_packed: weighted depth, prefix splits
# ---------------------------------------------------------------------------

def _packed_msg(k, row_bytes=8, dst=1, dev=0, tag=0):
    data = np.arange(k * row_bytes, dtype=np.uint8).reshape(k, row_bytes)
    burst = PackedBurst(data, np.full(k, row_bytes, np.int64),
                        [tag] * k, k)
    return WireMsg(WireKind.EAGER_PACKED_AM, src=0, dst=dst, tag=tag,
                   payload=burst, size=int(data.nbytes), rcomp=0,
                   device_index=dev)


class TestPushPacked:
    def test_packed_counts_rows_toward_depth(self):
        fab = Fabric(2, depth=10)
        assert fab.push_packed(_packed_msg(6)) == 6
        assert fab.stream_depth(1, 0) == 6
        assert fab.in_flight() == 6 and fab.pending_to(1) == 6
        # only 4 rows of room left: prefix-accept
        assert fab.push_packed(_packed_msg(6)) == 4
        assert fab.stream_depth(1, 0) == 10
        assert fab.push_packed(_packed_msg(3)) == 0    # full

    def test_prefix_split_slices_rows(self):
        fab = Fabric(2, depth=4)
        msg = _packed_msg(7)
        assert fab.push_packed(msg) == 4
        out = fab.drain(1, 0)
        assert len(out) == 1
        pb = out[0].payload
        assert pb.count == 4
        assert np.array_equal(pb.data, msg.payload.data[:4])
        assert out[0].size == pb.data.nbytes

    def test_drain_releases_packed_weight(self):
        fab = Fabric(2, depth=8)
        fab.push_packed(_packed_msg(5))
        assert fab.stream_depth(1, 0) == 5
        assert len(fab.drain(1, 0)) == 1
        assert fab.stream_depth(1, 0) == 0 and fab.in_flight() == 0
        # room is fully recycled afterwards
        assert fab.push_packed(_packed_msg(8)) == 8

    def test_scalar_and_packed_share_the_bound(self):
        fab = Fabric(2, depth=6)
        assert fab.try_push(WireMsg(WireKind.EAGER_AM, src=0, dst=1,
                                    payload=np.zeros(1, np.uint8), size=1,
                                    rcomp=0))
        assert fab.push_packed(_packed_msg(9)) == 5

    def test_delivered_payloads_bf16_roundtrip(self):
        f32 = np.linspace(-3, 3, 8, dtype=np.float32).reshape(2, 4)
        data, sizes, wd = pack_payloads(list(f32), wire_bf16=True)
        burst = PackedBurst(data, sizes, [0, 0], 2, wd)
        outs = burst.delivered_payloads()
        for got, want in zip(outs, f32):
            dec = got.view(np.float32)
            assert dec.dtype == np.float32 and got.nbytes == 16
            np.testing.assert_allclose(dec, want, atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# burst matching: host engine + functional probes
# ---------------------------------------------------------------------------

class TestBurstMatching:
    def test_match_now_n_pops_fifo(self):
        m = HostMatchingEngine(n_buckets=64, n_locks=4)
        key = make_key(1, 7, MatchingPolicy.RANK_TAG)
        for i in range(3):
            m.insert(key, MatchKind.RECV, ("recv", i))
        got = m.match_now_n(key, MatchKind.SEND, 5)
        assert [v[1] for v in got] == [0, 1, 2]      # FIFO, short is fine
        assert m.match_now_n(key, MatchKind.SEND, 1) == []

    def test_match_now_burst_groups_duplicate_keys(self):
        m = HostMatchingEngine(n_buckets=64, n_locks=4)
        ka = make_key(1, 1, MatchingPolicy.RANK_TAG)
        kb = make_key(1, 2, MatchingPolicy.RANK_TAG)
        m.insert(ka, MatchKind.RECV, "a0")
        m.insert(ka, MatchKind.RECV, "a1")
        m.insert(kb, MatchKind.RECV, "b0")
        out = m.match_now_burst([ka, kb, ka, ka], MatchKind.SEND)
        assert out == ["a0", "b0", "a1", None]       # aligned, FIFO per key

    def test_functional_probe_batch_matches_scan(self):
        table = init_table(n_buckets=32, bucket_cap=4)
        keys = jnp.asarray([5, 9, 5, 40], jnp.int32)
        vals = jnp.asarray([50, 90, 51, 400], jnp.int32)
        table, _, status = insert_batch(
            table, keys, jnp.full(4, int(MatchKind.RECV), jnp.int32), vals)
        assert list(np.asarray(status)) == [0, 0, 0, 0]   # all stored
        q = jnp.asarray([5, 5, 9, 7, 5], jnp.int32)
        table, out_vals, hits = probe_batch(table, q, int(MatchKind.SEND))
        assert list(np.asarray(hits)) == [1, 1, 1, 0, 0]
        assert list(np.asarray(out_vals)[:3]) == [50, 51, 90]  # FIFO dups
        # the popped entries are really gone
        table, _, hit = probe(table, jnp.int32(9), int(MatchKind.SEND))
        assert not bool(hit)


# ---------------------------------------------------------------------------
# pool_get_copy_n: fused allocate-and-stage
# ---------------------------------------------------------------------------

class TestPoolGetCopyN:
    def test_full_burst_writes_all_rows(self):
        pool = init_pool(n_lanes=1, packets_per_lane=8)
        buf = init_buffers(8, 16)
        payload = jnp.arange(4 * 10, dtype=jnp.uint8).reshape(4, 10)
        pool, buf, ids, got, status = pool_get_copy_n(pool, buf, 0,
                                                      payload, 0)
        assert int(got) == 4 and int(status) == 0
        for i, pid in enumerate(np.asarray(ids)):
            row = np.asarray(buf[int(pid)])
            assert np.array_equal(row[:10], np.asarray(payload[i]))
            assert not row[10:].any()                # packet-width padding

    def test_short_grab_writes_prefix_only(self):
        pool = init_pool(n_lanes=1, packets_per_lane=2)
        buf = init_buffers(2, 8)
        payload = jnp.full((5, 8), 7, jnp.uint8)
        pool, buf, ids, got, status = pool_get_copy_n(pool, buf, 0,
                                                      payload, 0)
        assert int(got) == 2 and int(status) != 0
        ids = np.asarray(ids)
        assert (ids[2:] == -1).all()
        assert np.asarray(buf)[np.sort(ids[:2])].all()

    def test_oversize_row_rejected_statically(self):
        pool = init_pool(n_lanes=1, packets_per_lane=2)
        buf = init_buffers(2, 8)
        with pytest.raises(ValueError):
            pool_get_copy_n(pool, buf, 0, jnp.zeros((1, 9), jnp.uint8), 0)


# ---------------------------------------------------------------------------
# doorbell Pallas kernel vs jnp oracle
# ---------------------------------------------------------------------------

class TestDoorbellKernel:
    def test_stage_copy_matches_ref(self):
        from repro.kernels.doorbell import stage_copy, stage_copy_ref
        x = jnp.asarray(np.random.RandomState(0)
                        .randn(16, 5).astype(np.float32))
        for bf16 in (False, True):
            out = np.asarray(stage_copy(x, wire_bf16=bf16))
            ref = np.asarray(stage_copy_ref(x, wire_bf16=bf16))
            assert np.array_equal(out, ref)
        assert np.array_equal(
            np.asarray(stage_copy(x)).view(np.float32), np.asarray(x))

    def test_stage_copy_push_lands_in_packets(self):
        from repro.kernels.doorbell import stage_copy, stage_copy_push
        x = jnp.asarray(np.random.RandomState(1)
                        .randn(4, 3).astype(np.float32))
        pool = init_pool(n_lanes=1, packets_per_lane=8)
        buf = init_buffers(8, 32)
        pool, buf, ids, got, status = stage_copy_push(pool, buf, 0, x, 0,
                                                      wire_bf16=True)
        assert int(got) == 4 and int(status) == 0
        want = np.asarray(stage_copy(x, wire_bf16=True))
        for i, pid in enumerate(np.asarray(ids)):
            assert np.array_equal(np.asarray(buf[int(pid)])[:6], want[i])


# ---------------------------------------------------------------------------
# wire_bf16 end-to-end round trip
# ---------------------------------------------------------------------------

def _pump(cl, eps, rounds=6):
    for _ in range(rounds):
        for ep in eps:
            ep.progress()


class TestWireBf16:
    def test_f32_roundtrip_within_tolerance(self):
        cl = LocalCluster(2, attrs={"eager_max_bytes": 64,
                                    "doorbell_fused": True,
                                    "wire_bf16": True})
        eps = cl.alloc_endpoint(n_devices=1, name="ep")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        rng = np.random.RandomState(3)
        bufs = [rng.randn(4).astype(np.float32) for _ in range(8)]
        sts = eps[0].post_am_many(1, bufs, rc)
        assert all(s.is_done() for s in sts)
        _pump(cl, eps)
        got = []
        while True:
            s = cq.pop()
            if not s.is_done():
                break
            v = np.asarray(s.value).view(np.float32)
            assert v.nbytes == 16                    # f32 at delivery
            got.append(tuple(np.round(v, 1)))
        assert len(got) == 8
        want = sorted(tuple(np.round(b, 1)) for b in bufs)
        assert sorted(got) == want                   # lossy but close

    def test_non_float_bypass_byte_exact(self):
        cl = LocalCluster(2, attrs={"eager_max_bytes": 64,
                                    "doorbell_fused": True,
                                    "wire_bf16": True})
        eps = cl.alloc_endpoint(n_devices=1, name="ep")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        bufs = [np.arange(i, i + 4, dtype=np.int32) for i in range(8)]
        eps[0].post_am_many(1, bufs, rc)
        _pump(cl, eps)
        got = set()
        while True:
            s = cq.pop()
            if not s.is_done():
                break
            got.add(tuple(np.asarray(s.value).view(np.int32)))
        assert got == {tuple(b) for b in bufs}       # untouched bytes


# ---------------------------------------------------------------------------
# the load-bearing property: fused == scalar data plane
# ---------------------------------------------------------------------------

def _cluster(fused, *, em=16, ppl=64, depth=1 << 16):
    # pool_lanes=1: segment-level steal attempts legitimately differ
    # between one packed get_n and K scalar gets, so single-lane pools
    # keep allocation order bit-identical for the comparison.
    # chaos_* zeroed at the runtime layer: this property compares exact
    # delivered bytes between two data planes, so env-injected faults
    # (the chaos CI leg) must not perturb either side
    return LocalCluster(2, attrs={"eager_max_bytes": em,
                                  "doorbell_fused": fused,
                                  "packets_per_lane": ppl,
                                  "pool_lanes": 1,
                                  "chaos_drop": 0.0, "chaos_dup": 0.0,
                                  "chaos_reorder": 0.0,
                                  "chaos_delay_p": 0.0},
                        fabric_depth=depth)


def _st_sig(sts):
    return [(s.kind, s.code) for s in sts]


def _drive_am(fused, sizes, tags, em, ppl, depth):
    cl = _cluster(fused, em=em, ppl=ppl, depth=depth)
    eps = cl.alloc_endpoint(n_devices=1, name="ep")
    cq = cl[1].alloc_cq()
    rc = cl[1].register_rcomp(cq)
    bufs = [np.arange(sz, dtype=np.uint8) + (3 * i) % 251
            for i, sz in enumerate(sizes)]
    sts = eps[0].post_am_many(1, bufs, rc, tags=list(tags))
    _pump(cl, eps)
    got = []
    while True:
        s = cq.pop()
        if not s.is_done():
            break
        got.append((s.tag, bytes(np.asarray(s.value))))
    return _st_sig(sts), sorted(got)


def _drive_send(fused, sizes, tags, recv_tags, em, ppl, depth):
    cl = _cluster(fused, em=em, ppl=ppl, depth=depth)
    eps = cl.alloc_endpoint(n_devices=1, name="ep")
    scq, dcq = cl[0].alloc_cq(), cl[1].alloc_cq()
    recvs = [np.zeros(max(sizes, default=1), np.uint8) for _ in recv_tags]
    for rb, t in zip(recvs, recv_tags):
        post_recv(cl[1], 0, rb, tag=t, local_comp=dcq)
    bufs = [np.arange(sz, dtype=np.uint8) + (5 * i) % 251
            for i, sz in enumerate(sizes)]
    sts = eps[0].post_send_many(1, bufs, tags=list(tags), local_comp=scq)
    _pump(cl, eps, rounds=8)
    ndone = 0
    while dcq.pop().is_done():
        ndone += 1
    nsrc = 0
    while scq.pop().is_done():
        nsrc += 1
    return (_st_sig(sts), ndone, nsrc,
            [bytes(rb) for rb in recvs])


class TestFusedScalarEquivalence:
    @settings(max_examples=12, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 32), st.integers(0, 2)),
                    min_size=1, max_size=20),
           st.integers(0, 2))
    def test_am_equivalence(self, ops, scenario):
        sizes = [s for s, _ in ops]
        tags = [t for _, t in ops]
        em, ppl, depth = [(16, 64, 1 << 16),   # plain mixed inject/bufcopy
                          (8, 4, 1 << 16),     # pool exhaustion splits
                          (16, 64, 3),         # fabric back-pressure splits
                          ][scenario]
        f_sts, f_got = _drive_am(True, sizes, tags, em, ppl, depth)
        s_sts, s_got = _drive_am(False, sizes, tags, em, ppl, depth)
        assert f_sts == s_sts                  # identical split points
        assert f_got == s_got                  # identical delivered bytes

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 24), st.integers(0, 1)),
                    min_size=1, max_size=12),
           st.lists(st.integers(0, 1), min_size=0, max_size=12))
    def test_send_equivalence(self, ops, recv_tags):
        # duplicate match keys on both sides; pre-posted recvs may
        # under- or over-cover the burst (unexpected-queue fallback)
        sizes = [s for s, _ in ops]
        tags = [t for _, t in ops]
        f = _drive_send(True, sizes, tags, recv_tags, 8, 64, 1 << 16)
        s = _drive_send(False, sizes, tags, recv_tags, 8, 64, 1 << 16)
        assert f == s


class TestFusedGating:
    def test_short_runs_ride_the_scalar_path(self):
        cl = _cluster(True)
        eps = cl.alloc_endpoint(n_devices=1, name="ep")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        before = cl[0].fabric.pushes
        k = cl[0].fused_min_burst - 1
        eps[0].post_am_many(1, [np.zeros(4, np.uint8)] * k, rc)
        assert cl[0].fabric.pushes - before == k   # k scalar wire msgs

    def test_fused_run_is_one_descriptor(self):
        cl = _cluster(True)
        eps = cl.alloc_endpoint(n_devices=1, name="ep")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        eps[0].post_am_many(1, [np.zeros(4, np.uint8)] * 8, rc)
        out = cl[0].fabric.drain(1, eps[0].devices[0].index)
        assert len(out) == 1
        assert out[0].kind == WireKind.EAGER_PACKED_AM
        assert out[0].payload.count == 8

    def test_attr_off_disables_fusion(self):
        cl = _cluster(False)
        eps = cl.alloc_endpoint(n_devices=1, name="ep")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        eps[0].post_am_many(1, [np.zeros(4, np.uint8)] * 8, rc)
        out = cl[0].fabric.drain(1, eps[0].devices[0].index)
        assert len(out) == 8
        assert all(m.kind == WireKind.EAGER_AM for m in out)
