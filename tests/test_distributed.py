"""Distributed correctness (subprocess: 8 fake devices, (2,4) mesh).

The heavyweight guarantees of the framework:
* every family's shard_map loss == local loss (BSP and LCI modes);
* grad_sync'd distributed gradients == single-device gradients;
* ring collectives == XLA collectives == local oracles.
"""
import pytest


@pytest.mark.slow
def test_all_families_distributed_equivalence(helper_runner):
    out = helper_runner("dist_equivalence", devices=8, timeout=1500)
    assert out.count("OK loss") >= 16       # 8 configs x 2 modes
    assert out.count("OK grad") >= 8        # grad-checked configs x 2


@pytest.mark.slow
def test_tp2d_decode_matches_classic_and_oracle(helper_runner):
    """2D-TP weight-stationary serving (§Perf cell 1) is exact."""
    out = helper_runner("tp2d_decode", devices=8, timeout=1200)
    assert out.count("tp2d=1.000") >= 4
