"""Serving engine: teacher-forced decode must reproduce the training
forward's next-token predictions, for every family; plus the paged
allocator and the continuous-batching scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.completion import CompletionQueue
from repro.distributed.comm import local_comm
from repro.models.common import ModelConfig
from repro.models.layers import greedy_sample, lm_head_logits
from repro.models.registry import build_model
from repro.serving import PagedKVAllocator, ServeScheduler
from repro.serving.engine import (DecodeCache, init_cache, make_serve_step,
                                  precompute_cross_kv)

F = jnp.float32
S, B = 16, 2


def _agreement(cfg, extra=None, n_mem=0):
    m = build_model(cfg)
    params, _ = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (S, B), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if extra:
        batch.update(extra)
    comm = local_comm()
    x, _ = jax.jit(lambda p, bt: m.forward(p, bt, remat=False))(params,
                                                                batch)
    head = params.get("lm_head", params["emb"])
    oracle = jax.vmap(lambda xp: greedy_sample(
        lm_head_logits(xp, head, comm, real_vocab=cfg.vocab), comm))(x)

    cache = init_cache(cfg, S, B, n_memory=n_mem)
    if n_mem:
        if cfg.is_encdec:
            from repro.models import lm as lm_mod
            from repro.models.blocks import tp_plan
            mem = lm_mod._encode(params, batch, cfg, comm, tp_plan(cfg, 1),
                                 remat=False)
        else:
            mem = extra["image_embeds"]
        ck, cv = precompute_cross_kv(params, mem, cfg, comm)
        cache = DecodeCache(k=cache.k, v=cache.v, ssm_state=cache.ssm_state,
                            conv_tail=cache.conv_tail, cross_k=ck,
                            cross_v=cv, length=cache.length)
    step = jax.jit(make_serve_step(cfg))
    preds = []
    for i in range(S):
        nxt, cache = step(params, cache, tokens[i])
        preds.append(np.asarray(nxt))
    return (np.stack(preds) == np.asarray(oracle)).mean()


CASES = {
    "dense": (ModelConfig(name="dense", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab=128, tp_target=4, dtype=F), None, 0),
    "parallel": (ModelConfig(name="parallel", family="dense", n_layers=2,
                             d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                             vocab=128, tp_target=4, dtype=F,
                             norm="layernorm", parallel_block=True,
                             tie_embeddings=True), None, 0),
    "swa-qk": (ModelConfig(name="swa-qk", family="dense", n_layers=3,
                           d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
                           vocab=128, tp_target=4, dtype=F, head_dim=32,
                           sliding_window=6, swa_every_nth_global=3,
                           qk_norm=True), None, 0),
    "moe": (ModelConfig(name="moe", family="moe", n_layers=2, d_model=64,
                        n_heads=4, n_kv_heads=4, d_ff=96, vocab=128,
                        n_experts=8, top_k=2, tp_target=4, dtype=F,
                        capacity_factor=8.0, shared_expert_ff=64), None, 0),
    "ssm": (ModelConfig(name="ssm", family="ssm", n_layers=2, d_model=64,
                        n_heads=0, n_kv_heads=0, d_ff=0, vocab=128,
                        ssm_state=16, ssm_headdim=16, ssm_chunk=8,
                        tp_target=4, dtype=F), None, 0),
    "hybrid": (ModelConfig(name="hybrid", family="hybrid", n_layers=2,
                           d_model=64, n_heads=5, n_kv_heads=5, d_ff=128,
                           vocab=128, ssm_state=8, ssm_headdim=16,
                           ssm_chunk=8, tp_target=4, dtype=F, head_dim=16,
                           sliding_window=6, global_layers=(0,)), None, 0),
    "vlm": (ModelConfig(name="vlm", family="vlm", n_layers=4, d_model=64,
                        n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                        cross_attn_every=2, tp_target=4, dtype=F),
            {"image_embeds": jax.random.normal(jax.random.PRNGKey(5),
                                               (8, B, 64), F)}, 8),
    "whisper": (ModelConfig(name="whisper", family="audio", n_layers=2,
                            d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                            vocab=128, norm="layernorm", mlp="gelu",
                            encoder_layers=2, tp_target=4, dtype=F,
                            tie_embeddings=True),
                {"frames": jax.random.normal(jax.random.PRNGKey(6),
                                             (8, B, 64), F)}, 8),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name):
    cfg, extra, n_mem = CASES[name]
    assert _agreement(cfg, extra, n_mem) > 0.95


class TestPagedAllocator:
    def test_admit_extend_release(self):
        alloc = PagedKVAllocator(n_pages=8, page_size=4)
        st = alloc.admit(1, prompt_len=10)        # needs 3 pages
        assert st.is_done() and alloc.free_pages == 5
        assert alloc.extend(1, 16).is_done()      # grow to 4 pages
        assert alloc.free_pages == 4
        alloc.release(1)
        assert alloc.free_pages == 8

    def test_all_or_nothing_admission(self):
        alloc = PagedKVAllocator(n_pages=2, page_size=4)
        assert alloc.admit(1, 8).is_done()
        st = alloc.admit(2, 8)                    # no pages left
        assert st.is_retry()
        assert alloc.free_pages == 0              # no partial reservation

    def test_page_table_lookup(self):
        alloc = PagedKVAllocator(n_pages=4, page_size=4)
        alloc.admit(7, 8)
        table = alloc.tables[7]
        page, off = table.slot_of(5)
        assert off == 1 and page == table.pages[1]


class TestScheduler:
    def _engine(self):
        # fake decode: next token = token + 1
        def decode_fn(tokens, positions):
            return tokens + 1
        return decode_fn

    def test_continuous_batching_completes(self):
        alloc = PagedKVAllocator(n_pages=64, page_size=4)
        sched = ServeScheduler(self._engine(), max_batch=4, allocator=alloc)
        cq = CompletionQueue()
        for i in range(10):
            st = sched.submit(np.array([i]), max_new=3, comp=cq,
                              allow_retry=False)
            assert not st.is_retry()
        rounds = 0
        while sched.completed < 10:
            sched.step()
            rounds += 1
            assert rounds < 100
        outs = []
        while True:
            st = cq.pop()
            if st.is_retry():
                break
            outs.append(st.get_buffer())
        assert len(outs) == 10
        assert all(len(o) == 3 for o in outs)

    def test_backlog_under_page_pressure(self):
        alloc = PagedKVAllocator(n_pages=4, page_size=4)   # tiny
        sched = ServeScheduler(self._engine(), max_batch=8,
                               allocator=alloc)
        sts = [sched.submit(np.array([1, 2]), max_new=4, allow_retry=False)
               for _ in range(6)]
        assert any(s.code.name == "POSTED_BACKLOG" for s in sts)
        rounds = 0
        while sched.completed < 6:
            sched.step()
            rounds += 1
            assert rounds < 200
        assert sched.completed == 6
        assert alloc.free_pages == 4
