"""SPMD launcher (launch/spmd.py): bootstrap env exchange, the mmap
generation-counter barrier, the happy-path 2-process window demo, and —
the teardown satellite — rank death mid-window: the launcher must reap
the process group, surface a nonzero exit, and never hang (every join
here is timeout-bounded, matching the tests/test_concurrency.py
discipline)."""
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.launch import spmd

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _env_without_spmd():
    env = {k: v for k, v in os.environ.items()
           if not k.startswith("REPRO_SPMD_")}
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


class TestBootstrap:
    def test_requires_launcher_env(self, monkeypatch):
        monkeypatch.delenv(spmd.RANK_ENV, raising=False)
        with pytest.raises(RuntimeError, match="REPRO_SPMD_RANK"):
            spmd.bootstrap()

    def test_reads_launcher_env(self, monkeypatch, tmp_path):
        monkeypatch.setenv(spmd.RANK_ENV, "1")
        monkeypatch.setenv(spmd.NRANKS_ENV, "4")
        monkeypatch.setenv(spmd.SESSION_ENV, str(tmp_path))
        ctx = spmd.bootstrap()
        assert (ctx.rank, ctx.n_ranks) == (1, 4)
        assert ctx.session == str(tmp_path)


class TestBarrier:
    def test_two_ranks_meet(self, tmp_path):
        ctxs = [spmd.SpmdContext(r, 2, str(tmp_path)) for r in range(2)]
        errs = []

        def arrive(ctx):
            try:
                for _ in range(5):       # generations advance in lockstep
                    ctx.barrier(timeout=20.0)
            except Exception as e:       # pragma: no cover - failure path
                errs.append(e)

        threads = [threading.Thread(target=arrive, args=(c,))
                   for c in ctxs]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive(), "barrier thread wedged"
        assert not errs
        for c in ctxs:
            c.close()

    def test_lone_rank_times_out(self, tmp_path):
        ctx = spmd.SpmdContext(0, 2, str(tmp_path))
        with pytest.raises(TimeoutError, match="barrier"):
            ctx.barrier(timeout=0.2)
        ctx.close()


class TestLauncher:
    @pytest.mark.parametrize("backend", ["shm", "socket"])
    def test_two_process_window_demo(self, backend):
        """The acceptance smoke: 2 OS-process ranks run the message
        window cross-process with lost=0 / leaked=0."""
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.spmd", "--ranks", "2",
             "--backend", backend, "--iters", "5", "--window", "16",
             "--timeout", "90"],
            env=_env_without_spmd(), capture_output=True, text=True,
            timeout=120)
        assert out.returncode == 0, out.stderr + out.stdout
        # ranks share stdout, so lines may interleave — count substrings
        assert out.stdout.count("spmd-demo rank") == 2
        assert out.stdout.count("lost=0 leaked=0") == 2

    def test_attr_overrides_reach_children(self, tmp_path):
        probe = ("import os, sys; sys.path.insert(0, os.environ['SRC']); "
                 "from repro.core import LocalCluster; "
                 "cl = LocalCluster(2); "
                 "assert cl.fabric.depth == 123, cl.fabric.depth; "
                 "assert cl.fabric.attr_source('fabric_depth') == 'env'")
        env = _env_without_spmd()
        env["SRC"] = SRC
        old = dict(os.environ)
        os.environ.update(env)
        try:
            code = spmd.launch([sys.executable, "-c", probe], 2,
                               backend="shm",
                               attr_overrides={"fabric_depth": "123"},
                               timeout=60)
        finally:
            os.environ.clear()
            os.environ.update(old)
        assert code == 0

    def test_rank_death_reaps_group_nonzero_exit(self):
        """Satellite: one rank dies mid-window (exit 3) while its peer
        would happily spin forever; the launcher must kill the survivor's
        whole process group, return nonzero, and come back well under the
        join bound."""
        victim = (
            "import os, sys, time\n"
            "sys.path.insert(0, os.environ['SRC'])\n"
            "from repro.launch.spmd import bootstrap\n"
            "ctx = bootstrap()\n"
            "ctx.barrier(timeout=30)\n"
            "if ctx.rank == 1:\n"
            "    os._exit(3)\n"          # death mid-window
            "# rank 0: a grandchild too — group kill must reap it\n"
            "import subprocess\n"
            "child = subprocess.Popen([sys.executable, '-c',\n"
            "                          'import time; time.sleep(600)'])\n"
            "open(os.path.join(ctx.session_keep, 'grandchild'),\n"
            "     'w').write(str(child.pid))\n"
            "while True:\n"
            "    time.sleep(0.1)\n"      # spins until the launcher kills us
        )
        # stash the grandchild pid OUTSIDE the session dir (the launcher
        # removes the session on teardown)
        victim = victim.replace("ctx.session_keep",
                                "os.environ['PIDDIR']")
        env = _env_without_spmd()
        env["SRC"] = SRC
        import tempfile
        piddir = tempfile.mkdtemp(prefix="spmd-test-")
        env["PIDDIR"] = piddir
        old = dict(os.environ)
        os.environ.update(env)
        t0 = time.monotonic()
        try:
            code = spmd.launch([sys.executable, "-c", victim], 2,
                               backend="shm", timeout=60)
        finally:
            os.environ.clear()
            os.environ.update(old)
        elapsed = time.monotonic() - t0
        assert code == 3                  # the dead rank's exit surfaced
        assert elapsed < 45, f"teardown took {elapsed:.1f}s"
        # the survivor's grandchild must be gone too (process-group kill)
        pid_file = os.path.join(piddir, "grandchild")
        deadline = time.monotonic() + 10
        reaped = False
        while time.monotonic() < deadline:
            if not os.path.exists(pid_file):
                reaped = True             # rank 0 died before spawning it
                break
            pid = int(open(pid_file).read())
            try:
                os.kill(pid, 0)
            except ProcessLookupError:
                reaped = True
                break
            time.sleep(0.1)
        import shutil
        shutil.rmtree(piddir, ignore_errors=True)
        assert reaped, "grandchild survived the process-group teardown"

    def test_timeout_kills_everything(self):
        hang = ("import os, sys, time\n"
                "time.sleep(600)\n")
        env = _env_without_spmd()
        old = dict(os.environ)
        os.environ.update(env)
        t0 = time.monotonic()
        try:
            code = spmd.launch([sys.executable, "-c", hang], 2,
                               backend="shm", timeout=2.0)
        finally:
            os.environ.clear()
            os.environ.update(old)
        assert code == 124
        assert time.monotonic() - t0 < 30
