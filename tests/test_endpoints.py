"""Endpoints, striping policies, dedicated engines, and deterministic
back-pressure through the progress subsystem (paper §3.2.3 / §4.4)."""
import time

import numpy as np
import pytest

from repro.core import (CommConfig, Endpoint, EndpointSpec, ErrorCode,
                        FatalError, LocalCluster, ProgressEngine,
                        post_recv_x, post_send_x)
from repro.core.modes import CommMode

CFG = CommConfig(inject_max_bytes=64, bufcopy_max_bytes=512)


@pytest.fixture()
def pair():
    cl = LocalCluster(2, CFG)
    return cl, cl[0], cl[1]


class TestBackPressure:
    """Paper §4.4 steps (2)/(3): full fabric -> retry -> backlog -> drain,
    deterministically and in order."""

    def test_fill_retry_backlog_drain_in_order(self):
        cl = LocalCluster(2, CFG, fabric_depth=2)
        r0 = cl[0]
        dev = r0.default_device
        # fill the 2-deep wire queue
        for tag in (0, 1):
            assert post_send_x(r0, 1, np.full(8, tag, np.uint8), 8,
                               tag)().is_done()
        # (2) full queue surfaces retry as a *value*
        st = post_send_x(r0, 1, np.full(8, 2, np.uint8), 8, 2)()
        assert st.is_retry()
        assert cl.fabric.full_events >= 1
        assert dev.backlog.empty_flag            # retry did NOT enqueue
        # (3) allow_retry=False parks ops in the backlog queue, in order
        for tag in (2, 3):
            st = post_send_x(r0, 1, np.full(8, tag, np.uint8), 8,
                             tag).allow_retry(False)()
            assert st.is_posted()
            assert st.code == ErrorCode.POSTED_BACKLOG
        assert not dev.backlog.empty_flag
        # progress drains backlog FIFO behind the wire queue: delivery
        # order at the receiver is exactly tag 0,1,2,3
        cl.quiesce()
        assert dev.backlog.empty_flag
        assert cl.fabric.pending_to(1) == 0
        order = []
        for tag in range(4):
            buf = np.zeros(8, np.uint8)
            st = post_recv_x(cl[1], 0, buf, 8, tag)()
            assert st.is_done()
            order.append(int(buf[0]))
        assert order == [0, 1, 2, 3]

    def test_backlogged_op_survives_multiple_full_rounds(self):
        cl = LocalCluster(2, CFG, fabric_depth=1)
        r0 = cl[0]
        post_send_x(r0, 1, np.zeros(8, np.uint8), 8, 0)()
        st = post_send_x(r0, 1, np.zeros(8, np.uint8), 8,
                         1).allow_retry(False)()
        assert st.code == ErrorCode.POSTED_BACKLOG
        # progressing only the sender can't free the depth-1 queue, the
        # backlog op stays parked (no loss); receiver progress unblocks it
        r0.progress()
        assert cl.fabric.pending_to(1) == 1
        cl.quiesce()
        assert cl.fabric.pending_to(1) == 0


class TestStriping:
    def test_round_robin_lands_evenly(self, pair):
        cl, r0, r1 = pair
        eps = cl.alloc_endpoint(n_devices=3, stripe="round_robin",
                                name="rr")
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        for i in range(9):
            assert eps[0].post_am(1, np.full(8, i, np.uint8),
                                  remote_comp=rc).is_done()
        cl.quiesce()
        assert [d.posts for d in eps[0].devices] == [3, 3, 3]
        assert [d.pushes for d in eps[0].devices] == [3, 3, 3]
        got = sorted(int(cq.pop().get_buffer()[0]) for _ in range(9))
        assert got == list(range(9))

    def test_by_peer_pins_each_peer_to_one_device(self):
        cl = LocalCluster(4, CFG)
        eps = cl.alloc_endpoint(n_devices=2, stripe="by_peer", name="bp")
        cqs = [cl[r].alloc_cq() for r in range(4)]
        rcs = [cl[r].register_rcomp(cqs[r]) for r in range(4)]
        for peer in (1, 2, 3, 1, 3):
            eps[0].post_am(peer, np.zeros(8, np.uint8), remote_comp=rcs[peer])
        cl.quiesce()
        # peers 1,3 (odd) -> device 1; peer 2 -> device 0
        assert [d.posts for d in eps[0].devices] == [1, 4]
        # device choice is a pure function of the peer
        assert (eps[0].select_device(rank=2) is eps[0].devices[0]
                and eps[0].select_device(rank=3) is eps[0].devices[1])

    def test_by_size_isolates_size_classes(self, pair):
        cl, r0, r1 = pair
        eps = cl.alloc_endpoint(n_devices=2, stripe="by_size", name="bs")
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        for _ in range(3):
            eps[0].post_am(1, np.zeros(8, np.uint8), remote_comp=rc)
        eps[0].post_am(1, np.zeros(4096, np.uint8), remote_comp=rc)
        cl.quiesce()
        # small (<= inject threshold) -> device 0, bulk -> device 1
        assert [d.posts for d in eps[0].devices] == [3, 1]

    def test_explicit_size_boundaries(self, pair):
        cl, r0, r1 = pair
        spec = EndpointSpec(name="custom", n_devices=3, stripe="by_size",
                            size_boundaries=(100, 1000))
        ep = r0.alloc_endpoint(spec=spec)
        assert ep.select_device(size=50) is ep.devices[0]
        assert ep.select_device(size=500) is ep.devices[1]
        assert ep.select_device(size=5000) is ep.devices[2]

    def test_unknown_policy_rejected(self):
        with pytest.raises(FatalError):
            EndpointSpec(stripe="hash")
        with pytest.raises(FatalError):
            EndpointSpec(progress="thread")
        with pytest.raises(FatalError):
            EndpointSpec(n_devices=0)


class TestProgressPolicy:
    def test_dedicated_allocates_engine_per_device(self, pair):
        cl, r0, r1 = pair
        ep = r0.alloc_endpoint(n_devices=3, progress="dedicated")
        assert len(ep.engines) == 3
        assert all(e is not r0.engine for e in ep.engines)
        assert [e.devices for e in ep.engines] == \
            [[d] for d in ep.devices]

    def test_shared_uses_runtime_engine(self, pair):
        cl, r0, r1 = pair
        ep = r0.alloc_endpoint(n_devices=2, progress="shared")
        assert ep.engines == [r0.engine]

    def test_dedicated_engines_deliver(self, pair):
        cl, r0, r1 = pair
        eps = cl.alloc_endpoint(n_devices=2, progress="dedicated",
                                name="ded")
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        for i in range(4):
            eps[0].post_am(1, np.full(8, i, np.uint8), remote_comp=rc)
        # drive ONLY the endpoint's own engines (no cluster-wide quiesce)
        for _ in range(8):
            eps[0].progress()
            eps[1].progress()
        got = sorted(int(cq.pop().get_buffer()[0]) for _ in range(4))
        assert got == [0, 1, 2, 3]
        assert all(e.passes > 0 for e in eps[1].engines)

    def test_for_mode_maps_comm_modes(self):
        spec = EndpointSpec.for_mode(CommMode.LCI_DEDICATED, 4)
        assert spec.progress == "dedicated" and spec.n_devices == 4
        spec = EndpointSpec.for_mode(CommMode.LCI_SHARED, 4)
        assert spec.progress == "shared"


class TestEndpointLifecycle:
    def test_alloc_free_roundtrip(self, pair):
        cl, r0, r1 = pair
        n0 = len(r0.devices)
        ep = r0.alloc_endpoint(n_devices=2)
        assert len(r0.devices) == n0 + 2
        r0.free_endpoint(ep)
        assert len(r0.devices) == n0 and not r0.endpoints

    def test_device_indices_never_reused(self, pair):
        cl, r0, r1 = pair
        ep_a = r0.alloc_endpoint(n_devices=2)
        ep_b = r0.alloc_endpoint(n_devices=1)
        live = ep_b.devices[0].index
        r0.free_endpoint(ep_a)
        ep_c = r0.alloc_endpoint(n_devices=2)
        # a freed device's fabric stream must never alias a later bundle
        assert live not in [d.index for d in ep_c.devices]

    def test_free_with_undrained_traffic_rejected(self):
        cl = LocalCluster(2, CFG)
        eps = cl.alloc_endpoint(n_devices=1, name="busy")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        eps[0].post_am(1, np.zeros(8, np.uint8), remote_comp=rc)
        # the message sits undrained in rank 1's incoming stream
        with pytest.raises(FatalError):
            cl[1].free_endpoint(eps[1])
        cl.quiesce()
        cl[1].free_endpoint(eps[1])          # drained: free succeeds

    def test_free_endpoint_is_atomic(self):
        cl = LocalCluster(2, CFG)
        eps = cl.alloc_endpoint(n_devices=2, stripe="round_robin",
                                name="atomic")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        eps[0].post_am(1, np.zeros(8, np.uint8), remote_comp=rc)
        eps[0].post_am(1, np.zeros(8, np.uint8), remote_comp=rc)
        # drain only the FIRST stream: the second device stays busy
        cl[1].progress(eps[1].devices[0])
        n_before = len(cl[1].devices)
        with pytest.raises(FatalError):
            cl[1].free_endpoint(eps[1])
        # the failed free must not have removed ANY device
        assert len(cl[1].devices) == n_before
        assert eps[1] in cl[1].endpoints
        cl.quiesce()
        cl[1].free_endpoint(eps[1])          # retry after drain succeeds
        assert len(cl[1].devices) == n_before - 2

    def test_comm_cfg_round_trips_progress_policy(self):
        from repro.distributed.comm import Comm
        base = Comm(CommConfig(mode=CommMode.LCI_SHARED))
        shared = base.with_endpoint(
            EndpointSpec.for_mode(CommMode.LCI_SHARED, 4))
        assert shared.cfg.mode == CommMode.LCI_SHARED
        assert shared.cfg.n_channels == 4
        ded = base.with_endpoint(
            EndpointSpec.for_mode(CommMode.LCI_DEDICATED, 4))
        assert ded.cfg.mode == CommMode.LCI_DEDICATED
        bsp = Comm(CommConfig(mode=CommMode.BSP)).with_endpoint(
            EndpointSpec(n_devices=4, progress="dedicated"))
        assert bsp.cfg.mode == CommMode.BSP   # baseline never overridden

    def test_cluster_alloc_is_symmetric(self, pair):
        cl, r0, r1 = pair
        eps = cl.alloc_endpoint(n_devices=2, name="sym")
        assert len(eps) == 2
        assert [d.index for d in eps[0].devices] == \
            [d.index for d in eps[1].devices]

    def test_counters_shape(self, pair):
        cl, r0, r1 = pair
        ep = r0.alloc_endpoint(n_devices=2, name="c")
        c = ep.counters()
        assert c["name"] == "c" and len(c["devices"]) == 2
        assert {"index", "lane", "posts", "pushes", "progresses"} <= \
            set(c["devices"][0])


class TestServeTransport:
    def test_prefill_decode_isolation_roundtrip(self):
        from repro.serving import (PagedKVAllocator, ServeScheduler,
                                   ServeTransport)
        cl = LocalCluster(2, CFG)
        tr = ServeTransport(cl, n_prefill=2, n_decode=1)
        sched = ServeScheduler(lambda t, p: t + 1, max_batch=4,
                               allocator=PagedKVAllocator(n_pages=64,
                                                          page_size=4),
                               transport=tr)
        rids = [sched.submit_remote(np.array([i]), max_new=3)
                for i in range(6)]
        results = {}
        # wall-clock bound, not iteration bound: under the chaos CI leg
        # dropped messages heal via retransmit backoff (~ms), which a
        # tight fixed-count loop would outrun
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            sched.step()
            tr.pump()
            for rid, toks in tr.poll_results():
                results[rid] = toks
            if len(results) == 6:
                break
        assert set(results) == set(rids)
        assert all(len(v) == 3 for v in results.values())
        c = tr.counters()
        # prompts rode the prefill endpoint, tokens the decode endpoint —
        # never the other way around
        assert sum(d["posts"] for d in c["prefill"][0]["devices"]) == 6
        assert sum(d["posts"] for d in c["decode"][1]["devices"]) == 6
        assert sum(d["posts"] for d in c["decode"][0]["devices"]) == 0
