"""Shared fixtures.  NOTE: no XLA_FLAGS here — the main pytest process
sees exactly 1 device; multi-device tests run subprocess helpers from
tests/helpers/ with the flag set in the child's environment only."""
import os
import subprocess
import sys

import pytest

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_helper(name: str, *args: str, devices: int = 8,
               timeout: int = 900) -> str:
    """Run tests/helpers/<name>.py in a child with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    r = subprocess.run(
        [sys.executable, os.path.join(HELPERS, name + ".py"), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, (
        f"helper {name} failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def helper_runner():
    return run_helper
