"""Training substrate: convergence, determinism, loop behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticPipeline, TokenFilePipeline
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import AdamWConfig, cosine_schedule, linear_warmup
from repro.train import make_train_step, train_state_init

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=64, tp_target=4,
                  dtype=jnp.float32)


def test_overfit_fixed_batch():
    model = build_model(CFG)
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, specs, opt))
    pipe = SyntheticPipeline(vocab=64, seq_len=32, global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}
    first = None
    for _ in range(80):
        state, metrics = step(state, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < 0.5 < first


def test_stream_learning():
    model = build_model(CFG)
    opt = AdamWConfig(lr=3e-3, weight_decay=0.0)
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, specs, opt))
    pipe = SyntheticPipeline(vocab=64, seq_len=32, global_batch=8)
    losses = []
    for i in range(40):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in pipe.get_batch(i).items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.4


def test_training_is_deterministic():
    model = build_model(CFG)
    opt = AdamWConfig(lr=1e-3)

    def run():
        state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
        step = jax.jit(make_train_step(model, specs, opt))
        pipe = SyntheticPipeline(vocab=64, seq_len=16, global_batch=4)
        for i in range(5):
            state, _ = step(state, {k: jnp.asarray(v) for k, v in
                                    pipe.get_batch(i).items()})
        return state

    s1, s2 = run(), run()
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_clip_engages():
    model = build_model(CFG)
    opt = AdamWConfig(lr=1e-3, max_grad_norm=1e-6)
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, specs, opt))
    pipe = SyntheticPipeline(vocab=64, seq_len=16, global_batch=4)
    before = jax.tree_util.tree_map(np.asarray, state.params)
    state, m = step(state, {k: jnp.asarray(v) for k, v in
                            pipe.get_batch(0).items()})
    # clip to 1e-6: the Adam update is still O(lr), but grad_norm reported
    # is the pre-clip norm
    assert float(m["grad_norm"]) > 1e-3


def test_schedules():
    warm = linear_warmup(1.0, 10)
    assert float(warm(0)) == 0.0
    assert abs(float(warm(5)) - 0.5) < 1e-6
    assert float(warm(20)) == 1.0
    cos = cosine_schedule(1.0, 10, 110, final_frac=0.1)
    assert abs(float(cos(10)) - 1.0) < 1e-5
    assert float(cos(110)) == pytest.approx(0.1, abs=1e-5)


def test_token_file_pipeline(tmp_path):
    path = tmp_path / "tokens.bin"
    np.arange(10_000, dtype=np.uint16).tofile(path)
    pipe = TokenFilePipeline(str(path), vocab=1 << 15, seq_len=64,
                             global_batch=4)
    b0 = pipe.get_batch(0)
    b0_again = pipe.get_batch(0)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    assert b0["tokens"].shape == (64, 4)
    # labels are next-token shifted
    np.testing.assert_array_equal(b0["labels"][:-1], b0["tokens"][1:])


def test_synthetic_pipeline_determinism():
    p1 = SyntheticPipeline(vocab=100, seq_len=32, global_batch=4, seed=7)
    p2 = SyntheticPipeline(vocab=100, seq_len=32, global_batch=4, seed=7)
    np.testing.assert_array_equal(p1.get_batch(11)["tokens"],
                                  p2.get_batch(11)["tokens"])
    assert not np.array_equal(p1.get_batch(1)["tokens"],
                              p1.get_batch(2)["tokens"])
