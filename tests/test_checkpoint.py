"""Checkpointing: atomic commit, hashes, async, resume, GC, elasticity."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointStore, latest_step, restore,
                              save_async, save_sync)
from repro.core.status import FatalError
from repro.data import SyntheticPipeline
from repro.models.common import ModelConfig
from repro.models.registry import build_model
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_state_init
from repro.train.loop import LoopConfig, train_loop

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, tp_target=4,
                  dtype=jnp.float32)


def _tree():
    return {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.ones(5, np.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_sync(str(tmp_path), 3, t, meta={"next_step": 4})
    assert latest_step(str(tmp_path)) == 3
    got, manifest = restore(str(tmp_path), t)
    np.testing.assert_array_equal(got["a"], t["a"])
    np.testing.assert_array_equal(got["b"]["c"], t["b"]["c"])
    assert manifest["meta"]["next_step"] == 4


def test_corruption_detected(tmp_path):
    t = _tree()
    path = save_sync(str(tmp_path), 1, t)
    victim = os.path.join(path, "a.npy")
    arr = np.load(victim)
    arr[0, 0] += 1
    np.save(victim, arr)
    with pytest.raises(FatalError, match="corrupt"):
        restore(str(tmp_path), t)


def test_async_save_signals_synchronizer(tmp_path):
    t = _tree()
    sync = save_async(str(tmp_path), 2, t)
    for _ in range(500):
        if sync.ready:
            break
        time.sleep(0.01)
    assert sync.ready
    ok, payloads = sync.test()
    assert ok and payloads[0].is_done()
    assert latest_step(str(tmp_path)) == 2


def test_async_save_unified_wait(tmp_path):
    """The returned Synchronizer follows the unified comp protocol:
    wait() blocks on the writer thread's signal, no progress driver."""
    t = _tree()
    sync = save_async(str(tmp_path), 7, t)
    (status,) = sync.wait()
    assert status.is_done()
    assert status.get_buffer().endswith("step_00000007")
    assert latest_step(str(tmp_path)) == 7


def test_async_save_failure_is_loud(tmp_path):
    """A crashed writer can never look like a committed checkpoint:
    ready/test/wait re-raise the failure as a FatalError."""
    target = tmp_path / "not-a-dir"
    target.write_text("file where the ckpt dir should go")
    sync = save_async(str(target / "sub"), 3, _tree())
    with pytest.raises(FatalError, match="synchronizer failed"):
        sync.wait()
    with pytest.raises(FatalError):
        _ = sync.ready


def test_commit_graph_partial_order(tmp_path):
    """The commit pipeline is a completion graph: rename fires only after
    every leaf write and the manifest completed."""
    from repro.checkpoint.store import build_commit_graph
    from repro.core.completion import Synchronizer
    t = _tree()
    sync = Synchronizer(1)
    g = build_commit_graph(str(tmp_path), 5, t, None, sync)
    g.execute()
    g.assert_partial_order()
    names = {n.name: n.nid for n in g._nodes}
    pos = {nid: i for i, nid in enumerate(g.fire_order)}
    writes = [nid for name, nid in names.items() if name.startswith("write:")]
    assert len(writes) == 2                      # leaves a, b_c
    assert all(pos[w] < pos[names["manifest"]] for w in writes)
    assert pos[names["manifest"]] < pos[names["commit"]] \
        < pos[names["signal"]]
    assert sync.ready and latest_step(str(tmp_path)) == 5


def test_atomic_commit_no_partial(tmp_path):
    """A tmp dir from a 'crashed' save never becomes LATEST."""
    t = _tree()
    os.makedirs(tmp_path / "step_00000009.tmp")
    save_sync(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    got, _ = restore(str(tmp_path), t)          # ignores the stale tmp
    np.testing.assert_array_equal(got["a"], t["a"])


def test_gc_keeps_last(tmp_path):
    store = CheckpointStore(str(tmp_path), keep_last=2)
    for s in range(5):
        store.save(s, _tree(), blocking=True)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_resume_exactness(tmp_path):
    model = build_model(CFG)
    opt = AdamWConfig(lr=1e-3)
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, specs, opt))
    pipe = SyntheticPipeline(vocab=64, seq_len=16, global_batch=4)
    wrap = lambda b, s: {k: jnp.asarray(v) for k, v in b.items()}

    s_straight, _ = train_loop(
        state, step, pipe, LoopConfig(total_steps=10, log_every=0),
        batch_transform=wrap)
    train_loop(state, step, pipe,
               LoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                          ckpt_every=3, log_every=0),
               batch_transform=wrap)
    s_resumed, _ = train_loop(
        state, step, pipe,
        LoopConfig(total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=100,
                   log_every=0),
        batch_transform=wrap)
    for a, b in zip(jax.tree_util.tree_leaves(s_straight.params),
                    jax.tree_util.tree_leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_elastic_restore_subprocess(helper_runner):
    """Save under a (2,4) mesh, restore + continue under (4,2)."""
    helper_runner("elastic_reshard", devices=8)
