"""The unified telemetry plane (DESIGN.md §15).

Covers the metric registry's sharded-merge guarantee (concurrent adds
never lose counts), stage-span nesting and summaries, the bounded trace
ring's wraparound and Chrome export, the off-level zero-allocation
contract (``span()`` returns one singleton), the ``telemetry`` readonly
attr on every resource type, burst/scalar protocol-accounting equality
through :func:`record_burst_mix`, cross-rank snapshot merging, and the
SPMD hygiene scan benchmarks gate their timing rows on.
"""
import dataclasses
import json
import threading
import time

import numpy as np
import pytest

import repro.core as C
from repro.core import telemetry as T
from repro.core.telemetry import NULL_SPAN


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_concurrent_shard_merge_loses_nothing(self):
        reg = T.MetricRegistry()
        n_threads, per = 4, 10_000

        def worker():
            for _ in range(per):
                reg.add("msgs")
                reg.observe("lat", 7)

        threads = [threading.Thread(target=worker)
                   for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        snap = reg.snapshot()
        assert snap["counters"]["msgs"] == n_threads * per
        assert snap["hists"]["lat"]["count"] == n_threads * per
        assert snap["hists"]["lat"]["sum"] == n_threads * per * 7

    def test_dead_threads_shards_survive(self):
        reg = T.MetricRegistry()
        t = threading.Thread(target=lambda: reg.add("x", 5))
        t.start()
        t.join()
        assert reg.snapshot()["counters"]["x"] == 5

    def test_histogram_log2_buckets_and_quantiles(self):
        h = T.Histogram()
        for v in (0, 1, 2, 3, 1000):
            h.record(v)
        d = h.as_dict()
        assert d["count"] == 5 and d["sum"] == 1006
        # value 1000 has bit_length 10 -> bucket "10"
        assert d["buckets"]["10"] == 1
        assert T.quantile_bound(d["buckets"], 0.99) == 2.0 ** 10

    def test_gauges_sampled_at_snapshot(self):
        reg = T.MetricRegistry()
        state = {"v": 1}
        reg.register_gauge("depth", lambda: state["v"])
        assert reg.snapshot()["counters"]["depth"] == 1
        state["v"] = 9
        assert reg.snapshot()["counters"]["depth"] == 9


# ---------------------------------------------------------------------------
# spans + levels
# ---------------------------------------------------------------------------

class TestSpans:
    def test_off_level_returns_the_null_span_singleton(self):
        tele = T.Telemetry("off")
        assert tele.span("a") is tele.span("b") is NULL_SPAN
        tele.add("x")                         # no-op, no error
        snap = tele.snapshot()
        assert snap["counters"] == {} and snap["spans"] == {}

    def test_level_booleans_compose_upward(self):
        for level, (c, t, tr) in {
            "off": (False, False, False),
            "counters": (True, False, False),
            "timers": (True, True, False),
            "trace": (True, True, True),
        }.items():
            tele = T.Telemetry(level)
            assert (tele.counters_on, tele.timers_on, tele.trace_on) == \
                (c, t, tr), level
        with pytest.raises(ValueError):
            T.Telemetry("loud")

    def test_span_records_and_nests(self):
        tele = T.Telemetry("timers")
        with tele.span("outer"):
            with tele.span("inner"):
                time.sleep(0.001)
        spans = tele.snapshot()["spans"]
        assert spans["outer"]["count"] == spans["inner"]["count"] == 1
        # containment: the outer stage strictly encloses the inner one
        assert spans["outer"]["sum"] >= spans["inner"]["sum"] > 0

    def test_trace_level_events_carry_nesting_depth(self):
        tele = T.Telemetry("trace", trace_capacity=16)
        with tele.span("outer"):
            with tele.span("inner"):
                pass
        by_name = {e["name"]: e for e in tele.trace.events()}
        assert by_name["outer"]["depth"] == 0
        assert by_name["inner"]["depth"] == 1

    def test_summarize_spans_shape(self):
        tele = T.Telemetry("timers")
        with tele.span("s"):
            pass
        out = T.summarize_spans(tele.snapshot()["spans"])
        row = out["s"]
        assert {"count", "total_us", "p50_us", "p99_us",
                "buckets"} <= row.keys()
        assert row["count"] == 1 and row["p99_us"] >= row["p50_us"] > 0


# ---------------------------------------------------------------------------
# trace ring
# ---------------------------------------------------------------------------

class TestTrace:
    def test_wraparound_keeps_the_latest_window(self):
        buf = T.TraceBuffer(capacity=8)
        for i in range(20):
            buf.emit(f"e{i}", t0_ns=i * 10, dur_ns=1)
        events = buf.events()
        assert len(events) == 8
        assert [e["name"] for e in events] == \
            [f"e{i}" for i in range(12, 20)]

    def test_per_thread_lanes_merge_sorted(self):
        buf = T.TraceBuffer(capacity=8)
        buf.emit("main", t0_ns=50, dur_ns=1)
        t = threading.Thread(target=lambda: buf.emit("w", 10, 1),
                             name="lane-w")
        t.start()
        t.join()
        events = buf.events()
        assert [e["name"] for e in events] == ["w", "main"]
        assert {e["lane"] for e in events} == {"lane-w", "MainThread"}

    def test_chrome_trace_document(self, tmp_path):
        buf = T.TraceBuffer(capacity=4)
        buf.emit("stage", t0_ns=2000, dur_ns=1500)
        path = buf.export(str(tmp_path / "trace.json"), pid=3)
        doc = json.load(open(path))
        (ev,) = doc["traceEvents"]
        assert ev == {"name": "stage", "ph": "X", "pid": 3,
                      "tid": "MainThread", "ts": 2.0, "dur": 1.5}


# ---------------------------------------------------------------------------
# snapshot merge (the SPMD fragment aggregation)
# ---------------------------------------------------------------------------

class TestMerge:
    def test_merge_snapshots_sums_elementwise(self):
        a = {"level": "counters", "counters": {"x": 1, "y": 2},
             "spans": {"post": {"count": 1, "sum": 10,
                                "buckets": {"4": 1}}}}
        b = {"level": "timers", "counters": {"x": 5},
             "spans": {"post": {"count": 2, "sum": 30,
                                "buckets": {"4": 1, "5": 1}}}}
        out = T.merge_snapshots([a, b, None])
        assert out["level"] == "timers"       # deepest level wins
        assert out["counters"] == {"x": 6, "y": 2}
        assert out["spans"]["post"] == {"count": 3, "sum": 40,
                                        "buckets": {"4": 2, "5": 1}}

    def test_render_block_sorts_and_summarizes(self):
        out = T.render_block({"level": "timers", "counters": {"b": 1, "a": 2},
                              "spans": {"s": {"count": 1, "sum": 2000,
                                              "buckets": {"11": 1}}}})
        assert list(out["counters"]) == ["a", "b"]
        assert out["spans"]["s"]["total_us"] == 2.0


# ---------------------------------------------------------------------------
# burst/scalar accounting equality (the unified record helper)
# ---------------------------------------------------------------------------

class TestRecordBurstMix:
    def test_matches_per_message_scalar_accounting(self):
        protos = [C.Protocol.INJECT, C.Protocol.INJECT, C.Protocol.BUFCOPY,
                  C.Protocol.ZEROCOPY, C.Protocol.BUFCOPY]
        sizes = [8, 8, 512, 1 << 21, 600]
        a, b = C.ProtocolStats(), C.ProtocolStats()
        T.record_burst_mix(a, protos, sizes, n=4)     # drop the suffix row
        for proto, size in zip(protos[:4], sizes[:4]):
            b.record_many(proto, 1, size)
        assert dataclasses.asdict(a) == dataclasses.asdict(b)

    def test_uniform_fast_path_and_registry_mirror(self):
        reg = T.MetricRegistry()
        stats = C.ProtocolStats()
        T.record_burst_mix(stats, [C.Protocol.INJECT] * 3, 8, 3,
                           registry=reg)
        assert stats.inject_msgs == 3 and stats.inject_bytes == 24
        counters = reg.snapshot()["counters"]
        assert counters["proto.inject.msgs"] == 3
        assert counters["proto.inject.bytes"] == 24
        T.record_burst_mix(stats, [C.Protocol.INJECT], 8, 0, registry=reg)
        assert stats.inject_msgs == 3         # n=0 records nothing


# ---------------------------------------------------------------------------
# the wired runtime: attr control, per-resource blocks, stage coverage
# ---------------------------------------------------------------------------

def _drive(cl, iters=48):
    """Mixed scalar + burst traffic through every instrumented stage."""
    r0, r1 = cl[0], cl[1]
    cq = r1.alloc_cq()
    rc = r1.register_rcomp(cq)
    payload = np.zeros(8, np.uint8)
    descs = [C.CommDesc(C.CommKind.AM, 1, payload, size=8, remote_comp=rc)
             for _ in range(4)]
    for i in range(iters):
        if i % 2:
            C.post_am(r0, 1, payload, remote_comp=rc)
        else:
            r0.post_many(descs)
        r1.progress()
        r0.progress()
        while cq.pop().is_done():
            pass
    cl.quiesce()
    while cq.pop().is_done():
        pass


class TestWiredRuntime:
    def test_telemetry_attr_on_every_resource_type(self):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "counters"})
        rt = cl[0]
        eps = cl.alloc_endpoint(n_devices=1, name="tele")
        resources = {
            "cluster": cl,
            "runtime": rt,
            "device": rt.default_device,
            "endpoint": eps[0],
            "pool": rt.packet_pool,
            "matching": rt.matching,
            "cq": rt.alloc_cq(),
            "tscq": rt.alloc_cq(threadsafe=True),
            "workers": C.ProgressWorkerPool.for_runtime(rt),
            "fabric": cl.fabric,
        }
        for kind, res in resources.items():
            block = res.get_attr("telemetry")
            assert block == res.attrs["telemetry"], kind
            assert block["level"] == "counters", (kind, block)
            assert "counters" in block, kind

    def test_resource_blocks_reflect_traffic(self):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "counters",
                                      "eager_max_bytes": 1})
        _drive(cl, iters=8)
        dev = cl[0].default_device.get_attr("telemetry")["counters"]
        assert dev["device.posts"] > 0 and dev["device.pushes"] > 0
        pool = cl[0].packet_pool.get_attr("telemetry")["counters"]
        assert pool["pool.gets"] > 0
        fab = cl.fabric.get_attr("telemetry")["counters"]
        assert fab["fabric.pushes"] > 0
        assert fab["fabric.in_flight"] == 0    # quiesced

    def test_timers_run_covers_at_least_eight_stages(self):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "timers",
                                      "eager_max_bytes": 1,
                                      "packets_per_lane": 64})
        _drive(cl)
        snap = cl.telemetry_snapshot()
        assert snap["level"] == "timers"
        stages = set(snap["spans"])
        assert {"post", "post_burst", "progress", "progress.drain",
                "transport.push", "transport.drain", "pool.get",
                "cq.pop"} <= stages, stages
        assert len(stages) >= 8
        # the unified counter surface rides the same snapshot
        assert snap["counters"]["device.posts"] > 0
        assert snap["counters"]["engine.passes"] > 0

    def test_off_level_records_no_spans_but_keeps_legacy_counters(self):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "off"})
        _drive(cl, iters=8)
        assert cl[0].tele.span("post") is NULL_SPAN
        snap = cl.telemetry_snapshot()
        assert snap["spans"] == {}
        # legacy counters (always on) still surface through collectors
        assert snap["counters"]["device.posts"] > 0

    def test_worker_pool_spans(self):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "timers"})
        with C.ProgressWorkerPool.for_cluster(cl, n_workers=1):
            time.sleep(0.05)
        spans = cl.telemetry_snapshot()["spans"]
        assert "worker.sweep" in spans
        assert "worker.nap" in spans          # idle fabric -> backoff naps

    def test_trace_level_cluster_export(self, tmp_path):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "trace",
                                      "trace_capacity": 256})
        _drive(cl, iters=4)
        path = cl.export_trace(str(tmp_path / "t.json"))
        doc = json.load(open(path))
        events = doc["traceEvents"]
        assert events and {e["ph"] for e in events} == {"X"}
        assert {"post", "progress"} <= {e["name"] for e in events}

    def test_runtimes_share_the_cluster_hub(self):
        cl = C.LocalCluster(2, attrs={"telemetry_level": "timers"})
        assert cl[0].tele is cl.tele is cl[1].tele   # one hub per cluster
        assert cl[0].tele.timers_on
        assert cl[0].get_attr("telemetry_level") == "timers"
        # merged cluster snapshot dedups the shared hub (no double count)
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        C.post_am(cl[0], 1, np.zeros(8, np.uint8), remote_comp=rc)
        posts = cl.telemetry_snapshot()["counters"]["device.posts"]
        assert posts == sum(d.posts for rt in cl.runtimes
                            for d in rt.devices)

    def test_env_layer_controls_the_level(self, monkeypatch):
        monkeypatch.setenv("REPRO_ATTR_TELEMETRY_LEVEL", "counters")
        cl = C.LocalCluster(2)
        assert cl.tele.counters_on and not cl.tele.timers_on
        assert cl.get_attr("telemetry_level") == "counters"


# ---------------------------------------------------------------------------
# SPMD hygiene (the timing-row gate)
# ---------------------------------------------------------------------------

class TestHygiene:
    def test_fake_stale_session_detected(self, tmp_path):
        from repro.launch import spmd
        (tmp_path / "repro-spmd-dead0").mkdir()
        (tmp_path / "unrelated-dir").mkdir()
        rep = spmd.hygiene_report(roots=[str(tmp_path)])
        assert not rep["clean"]
        assert rep["stale_sessions"] == \
            [str(tmp_path / "repro-spmd-dead0")]
        assert isinstance(rep["orphans"], list)

    def test_preflight_strict_raises_and_env_overrides(self, tmp_path,
                                                       monkeypatch):
        from repro.launch import spmd
        (tmp_path / "repro-spmd-dead1").mkdir()
        monkeypatch.delenv(spmd.ALLOW_DIRTY_ENV, raising=False)
        with pytest.raises(RuntimeError, match="hygiene"):
            spmd.preflight(strict=True, roots=[str(tmp_path)])
        monkeypatch.setenv(spmd.ALLOW_DIRTY_ENV, "1")
        rep = spmd.preflight(strict=True, roots=[str(tmp_path)])
        assert not rep["clean"]               # reported, not fatal

    def test_clean_root_passes(self, tmp_path):
        from repro.launch import spmd
        rep = spmd.preflight(strict=True, roots=[str(tmp_path)])
        assert rep["clean"]
