"""Host runtime end-to-end: the paper's iRPCLib example (Listing 2) as a
test, plus protocol, RMA, back-pressure, and a hypothesis delivery
property."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                    # bare env: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (CommConfig, LocalCluster, MatchingPolicy, Protocol,
                        post_am_x, post_get_x, post_put_x, post_recv_x,
                        post_send_x, select_protocol)

CFG = CommConfig(inject_max_bytes=64, bufcopy_max_bytes=512)


@pytest.fixture()
def pair():
    cl = LocalCluster(2, CFG)
    return cl, cl[0], cl[1]


class TestProtocolSelection:
    def test_thresholds(self):
        assert select_protocol(64, CFG) == Protocol.INJECT
        assert select_protocol(65, CFG) == Protocol.BUFCOPY
        assert select_protocol(512, CFG) == Protocol.BUFCOPY
        assert select_protocol(513, CFG) == Protocol.ZEROCOPY


class TestActiveMessages:
    def test_inject_am_done_immediately(self, pair):
        cl, r0, r1 = pair
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        st = post_am_x(r0, 1, np.arange(8, dtype=np.uint8), None, None,
                       rc).tag(7)()
        assert st.is_done()
        cl.quiesce()
        msg = cq.pop()
        assert msg.is_done() and msg.tag == 7 and msg.rank == 0
        assert np.array_equal(msg.get_buffer(), np.arange(8, dtype=np.uint8))

    def test_bufcopy_am_signals_source(self, pair):
        cl, r0, r1 = pair
        freed = []
        h = r0.alloc_handler(freed.append)
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        st = post_am_x(r0, 1, np.arange(256, dtype=np.uint8), None, h, rc)()
        assert st.is_posted()
        cl.quiesce()
        assert len(freed) == 1 and cq.pop().is_done()
        # bufcopy returns the packet to the pool
        assert r0.packet_pool.free_packets() == r0.packet_pool.n_packets

    def test_zerocopy_am_rendezvous(self, pair):
        cl, r0, r1 = pair
        freed = []
        h = r0.alloc_handler(freed.append)
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        big = np.arange(4096, dtype=np.uint8).astype(np.uint8)
        st = post_am_x(r0, 1, big, None, h, rc)()
        assert st.is_posted()
        cl.quiesce()
        assert len(freed) == 1
        got = cq.pop()
        assert got.is_done() and np.array_equal(got.get_buffer(), big)
        assert r0.stats.handshakes >= 1                  # RTS/CTS happened


class TestSendRecv:
    def test_recv_first_then_send(self, pair):
        cl, r0, r1 = pair
        buf = np.zeros(16, np.uint8)
        assert post_recv_x(r1, 0, buf, 16, 3)().is_posted()
        assert post_send_x(r0, 1, np.full(16, 9, np.uint8), 16, 3)().is_done()
        cl.quiesce()
        assert np.all(buf == 9)

    def test_unexpected_send_matched_done(self, pair):
        cl, r0, r1 = pair
        post_send_x(r0, 1, np.full(16, 5, np.uint8), 16, 4)()
        cl.quiesce()
        buf = np.zeros(16, np.uint8)
        st = post_recv_x(r1, 0, buf, 16, 4)()
        assert st.is_done() and np.all(buf == 5)

    def test_zerocopy_send_recv(self, pair):
        cl, r0, r1 = pair
        data = np.arange(2048, dtype=np.uint8).astype(np.uint8)
        buf = np.zeros(2048, np.uint8)
        got = []
        h = r1.alloc_handler(got.append)
        post_recv_x(r1, 0, buf, 2048, 5).local_comp(h)()
        post_send_x(r0, 1, data, 2048, 5)()
        cl.quiesce()
        assert np.array_equal(buf, data) and len(got) == 1

    def test_rank_only_wildcard(self, pair):
        cl, r0, r1 = pair
        buf = np.zeros(8, np.uint8)
        post_recv_x(r1, 0, buf, 8, 0).matching_policy(
            MatchingPolicy.RANK_ONLY)()
        post_send_x(r0, 1, np.full(8, 3, np.uint8), 8, 99).matching_policy(
            MatchingPolicy.RANK_ONLY)()
        cl.quiesce()
        assert np.all(buf == 3)


class TestRMA:
    def test_put_and_get(self, pair):
        cl, r0, r1 = pair
        target = np.zeros(64, np.uint8)
        region = r1.register_memory(target)
        post_put_x(r0, 1, np.arange(64, dtype=np.uint8), (region.rid, 0),
                   64)()
        cl.quiesce()
        assert np.array_equal(target, np.arange(64, dtype=np.uint8))
        local = np.zeros(32, np.uint8)
        post_get_x(r0, 1, local, (region.rid, 16), 32)()
        cl.quiesce()
        assert np.array_equal(local, target[16:48])

    def test_put_with_signal(self, pair):
        cl, r0, r1 = pair
        target = np.zeros(8, np.uint8)
        region = r1.register_memory(target)
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        post_put_x(r0, 1, np.full(8, 1, np.uint8), (region.rid, 0),
                   8).remote_comp(rc)()
        cl.quiesce()
        assert cq.pop().is_done() and np.all(target == 1)

    def test_get_with_signal_not_implemented(self, pair):
        cl, r0, r1 = pair
        region = r1.register_memory(np.zeros(8, np.uint8))
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        with pytest.raises(NotImplementedError):
            post_get_x(r0, 1, np.zeros(8, np.uint8), (region.rid, 0),
                       8).remote_comp(rc)()


class TestBackPressure:
    def test_fabric_full_retry_then_backlog(self):
        cl = LocalCluster(2, CFG, fabric_depth=1)
        r0 = cl[0]
        assert post_send_x(r0, 1, np.zeros(8, np.uint8), 8, 0)().is_done()
        st = post_send_x(r0, 1, np.zeros(8, np.uint8), 8, 0)()
        assert st.is_retry()
        st = post_send_x(r0, 1, np.zeros(8, np.uint8), 8,
                         0).allow_retry(False)()
        assert st.is_posted() and st.code.name == "POSTED_BACKLOG"
        cl.quiesce()
        assert cl.fabric.pending_to(1) == 0

    def test_packet_exhaustion_retry(self):
        cfg = CommConfig(inject_max_bytes=4, bufcopy_max_bytes=512,
                         packets_per_lane=1, n_channels=1)
        cl = LocalCluster(2, cfg)
        r0 = cl[0]
        st1 = post_send_x(r0, 1, np.zeros(64, np.uint8), 64, 0)()
        assert st1.is_posted()
        st2 = post_send_x(r0, 1, np.zeros(64, np.uint8), 64, 1)()
        assert st2.is_retry() and st2.code.name == "RETRY_NOPACKET"
        cl.quiesce()                      # progress returns the packet
        st3 = post_send_x(r0, 1, np.zeros(64, np.uint8), 64, 2)()
        assert st3.is_posted()


class TestDedicatedDevices:
    def test_per_lane_devices_do_not_interfere(self):
        cl = LocalCluster(2, CFG)
        r0, r1 = cl[0], cl[1]
        devs0 = [r0.alloc_device() for _ in range(3)]
        devs1 = [r1.alloc_device() for _ in range(3)]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        for i, d in enumerate(devs0):
            st = post_am_x(r0, 1, np.full(8, i, np.uint8), None, None,
                           rc).device(d)()
            assert st.is_done()
        cl.quiesce()
        seen = sorted(int(cq.pop().get_buffer()[0]) for _ in range(3))
        assert seen == [0, 1, 2]


@given(st.lists(st.tuples(st.integers(0, 3),      # tag
                          st.integers(1, 600)),   # size (all 3 protocols)
                min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_delivery_property(msgs):
    """Every posted message is delivered exactly once, bytes intact,
    matched by (rank, tag), across all three protocols."""
    cl = LocalCluster(2, CFG)
    r0, r1 = cl[0], cl[1]
    cq = r1.alloc_cq()
    rc = r1.register_rcomp(cq)
    sent = []
    for i, (tag, size) in enumerate(msgs):
        payload = np.full(size, (i * 37 + tag) % 251, np.uint8)
        st = post_am_x(r0, 1, payload, None, None, rc).tag(tag)()
        while st.is_retry():
            cl.progress_all()
            st = post_am_x(r0, 1, payload, None, None, rc).tag(tag)()
        sent.append((tag, payload))
    cl.quiesce()
    got = []
    while True:
        msg = cq.pop()
        if msg.is_retry():
            break
        got.append((msg.tag, np.asarray(msg.get_buffer())))
    assert len(got) == len(sent)
    for (t1, p1), (t2, p2) in zip(sorted(sent, key=lambda x: (x[0], x[1].tobytes())),
                                  sorted(got, key=lambda x: (x[0], x[1].tobytes()))):
        assert t1 == t2 and np.array_equal(p1, p2[:len(p1)])
