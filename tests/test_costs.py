"""The jaxpr cost walker (roofline foundation) against analytic oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.costs import Costs, count_costs

AX = {"model": 4, "data": 2}


def _costs(fn, *args):
    return count_costs(jax.make_jaxpr(fn)(*args), AX)


class TestFlops:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((16, 4), jnp.float32)
        c = _costs(lambda x, y: x @ y, a, b)
        assert c.flops == 2 * 8 * 16 * 4
        assert c.dot_bytes == (8 * 16 + 16 * 4 + 8 * 4) * 4

    def test_batched_einsum(self):
        a = jax.ShapeDtypeStruct((3, 8, 16), jnp.bfloat16)
        b = jax.ShapeDtypeStruct((3, 16, 4), jnp.bfloat16)
        c = _costs(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        assert c.flops == 2 * 3 * 8 * 16 * 4

    def test_scan_multiplies_by_length(self):
        a = jax.ShapeDtypeStruct((8, 8), jnp.float32)

        def fn(x):
            def body(c, _):
                return c @ x, ()
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        c = _costs(fn, a)
        assert c.flops == 7 * 2 * 8 * 8 * 8

    def test_nested_scan(self):
        a = jax.ShapeDtypeStruct((4, 4), jnp.float32)

        def fn(x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ x, ()
                c2, _ = jax.lax.scan(inner, c, None, length=3)
                return c2, ()
            out, _ = jax.lax.scan(outer, x, None, length=5)
            return out

        c = _costs(fn, a)
        assert c.flops == 5 * 3 * 2 * 4 ** 3

    def test_remat_body_counted(self):
        a = jax.ShapeDtypeStruct((8, 8), jnp.float32)

        def fn(x):
            f = jax.checkpoint(lambda y: (y @ y).sum())
            return jax.grad(f)(x)

        c = _costs(fn, a)
        # fwd + remat-replayed fwd + two bwd matmuls >= 3x a single matmul
        assert c.flops >= 3 * 2 * 8 ** 3


class TestCollectives:
    def test_ppermute_direction_split(self):
        import os
        # shapes only — no devices needed for make_jaxpr outside shard_map?
        # collectives need axis binding: wrap in shard_map-free jaxpr via
        # jax.make_jaxpr with abstract mesh is complex; approximate with a
        # hand-built check through the public dryrun path instead.
        pytest.skip("covered by dryrun artifacts (fwd/bwd step counts)")

    def test_link_bytes_takes_busier_direction(self):
        c = Costs()
        c.coll_bytes["ppermute"] = 100.0
        c.ppermute_fwd_bytes = 60.0
        c.ppermute_bwd_bytes = 40.0
        assert c.link_bytes == 60.0
        c.coll_bytes["psum"] = 10.0
        assert c.link_bytes == 70.0          # non-split adds on top


class TestArtifacts:
    def test_dryrun_artifacts_complete(self):
        """Every non-skipped single-pod artifact carries roofline terms."""
        import glob
        import json
        import os
        art = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                           "artifacts", "dryrun")
        files = [f for f in glob.glob(os.path.join(art, "*__single__"
                                                   "lci_dedicated.json"))]
        if not files:
            pytest.skip("dry-run artifacts not generated yet")
        assert len(files) == 40
        n_ok = 0
        for f in files:
            a = json.load(open(f))
            if a["status"] == "skipped":
                continue
            n_ok += 1
            r = a["roofline"]
            for k in ("compute_s", "memory_s", "collective_s", "dominant",
                      "useful_flop_ratio", "roofline_fraction"):
                assert k in r, (f, k)
            assert r["compute_s"] > 0
            assert a["analytic"]["flops"] > 0
            assert a["analytic"]["unknown_while"] == 0
        assert n_ok == 33
