"""Public-API drift guard: every package ``__init__`` exports exactly
what it imports (satellite of the attribute-system PR).

Rules per ``repro`` package ``__init__.py`` (skipping empty ones):

* it declares ``__all__``;
* every symbol it re-exports with a *relative* ``from .x import y`` is
  listed in ``__all__`` (an import without an export is drift one way);
* every name in ``__all__`` resolves to a real module attribute (an
  export without an import/definition is drift the other way).
"""
import ast
import glob
import importlib
import os

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_INITS = sorted(
    p for p in glob.glob(os.path.join(SRC, "repro", "**", "__init__.py"),
                         recursive=True)
    if open(p).read().strip()
    and not open(p).read().lstrip().startswith("#")   # comment-only stub
)


def _module_name(path: str) -> str:
    rel = os.path.relpath(os.path.dirname(path), SRC)
    return rel.replace(os.sep, ".")


def _parse(path: str):
    tree = ast.parse(open(path).read())
    imported = set()
    for node in tree.body:
        if isinstance(node, ast.ImportFrom) and node.level > 0:
            for alias in node.names:
                imported.add(alias.asname or alias.name)
    exported = None
    for node in tree.body:
        if isinstance(node, ast.Assign) and any(
                getattr(t, "id", "") == "__all__" for t in node.targets):
            exported = {ast.literal_eval(e) for e in node.value.elts}
    return imported, exported


@pytest.mark.parametrize("path", _INITS, ids=_module_name)
def test_all_matches_imports(path):
    imported, exported = _parse(path)
    assert exported is not None, \
        f"{_module_name(path)} has no __all__ declaration"
    missing = imported - exported
    assert not missing, (
        f"{_module_name(path)} imports {sorted(missing)} without "
        f"exporting them in __all__")


@pytest.mark.parametrize("path", _INITS, ids=_module_name)
def test_all_names_resolve(path):
    _, exported = _parse(path)
    mod = importlib.import_module(_module_name(path))
    dangling = [n for n in sorted(exported or ()) if not hasattr(mod, n)]
    assert not dangling, (
        f"{_module_name(path)} exports {dangling} in __all__ but the "
        f"module has no such attributes")


def test_core_all_is_sorted_within_groups():
    """Cheap hygiene: no duplicates anywhere in repro.core.__all__."""
    import repro.core as core
    assert len(core.__all__) == len(set(core.__all__))


def test_serving_exports_the_batching_surface():
    """The serving subsystem's continuous-batching surface is public API:
    removing a name from ``repro.serving.__all__`` is drift, not cleanup
    (DESIGN.md §17)."""
    serving = importlib.import_module("repro.serving")
    for name in ("ContinuousBatcher", "ServePlane", "TokenClient",
                 "SyntheticModel", "ResultTokens", "SlotData",
                 "SlotAllocator", "SERVING_ATTRS", "ResultDrain",
                 "encode_token_row", "decode_token_row"):
        assert name in serving.__all__, name
        assert hasattr(serving, name), name
