"""The unified attribute system (DESIGN.md §12).

Covers the four-layer resolution chain (defaults → REPRO_ATTR_* env →
runtime config → per-resource overrides) as a hypothesis property, the
``get_attr``/``attrs`` surface on every resource type, alloc-time
validation errors that name the attribute, the CommConfig/EndpointSpec
deprecation shims, and — in a subprocess — that an env override really
changes protocol selection.
"""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as C
from repro.core import attrs as A

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True)
def _no_ambient_attr_env(monkeypatch):
    """These tests assert exact layer outcomes; ambient REPRO_ATTR_*
    (e.g. the CI attr-override smoke leg) must not leak in."""
    for key in list(os.environ):
        if key.startswith(A.ENV_PREFIX):
            monkeypatch.delenv(key, raising=False)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_every_tunable_has_type_default_mutability(self):
        assert A.REGISTRY, "registry must not be empty"
        for name, spec in A.REGISTRY.items():
            assert spec.name == name
            assert spec.type in (int, float, bool, str, dict)
            assert spec.mutability in ("alloc", "env", "readonly")
            if spec.mutability != "readonly":
                # defaults must validate against their own spec
                assert spec.validate(spec.default) == spec.default

    def test_core_knobs_registered(self):
        for name in ("eager_max_bytes", "rdv_threshold", "packets_per_lane",
                     "packet_bytes", "pool_lanes", "backlog_capacity",
                     "cq_capacity", "worker_burst", "n_workers", "stripe",
                     "progress", "n_devices", "fabric_depth", "link_latency",
                     "matching_buckets", "lock_spin_count"):
            assert name in A.REGISTRY, name

    def test_registry_table_renders_every_attr(self):
        table = A.registry_table()
        for name in A.REGISTRY:
            assert f"`{name}`" in table

    def test_unknown_name_error_lists_known(self):
        with pytest.raises(ValueError, match="unknown attribute"):
            A.get_spec("rdv_treshold")           # typo

    def test_env_var_spelling(self):
        assert A.get_spec("rdv_threshold").env_var == \
            "REPRO_ATTR_RDV_THRESHOLD"


# ---------------------------------------------------------------------------
# the resolution chain
# ---------------------------------------------------------------------------

class TestResolutionChain:
    def test_default_layer(self):
        r = A.resolve(["rdv_threshold"], env={})
        assert r["rdv_threshold"] == 2 * 1024 * 1024
        assert r.source("rdv_threshold") == "default"

    def test_env_beats_default(self):
        r = A.resolve(["rdv_threshold"],
                      env={"REPRO_ATTR_RDV_THRESHOLD": "4096"})
        assert r["rdv_threshold"] == 4096
        assert r.source("rdv_threshold") == "env"

    def test_runtime_beats_env(self):
        r = A.resolve(["rdv_threshold"], runtime={"rdv_threshold": 512},
                      env={"REPRO_ATTR_RDV_THRESHOLD": "4096"})
        assert r["rdv_threshold"] == 512
        assert r.source("rdv_threshold") == "runtime"

    def test_resource_beats_runtime(self):
        r = A.resolve(["rdv_threshold"], runtime={"rdv_threshold": 512},
                      overrides={"rdv_threshold": 64},
                      env={"REPRO_ATTR_RDV_THRESHOLD": "4096"})
        assert r["rdv_threshold"] == 64
        assert r.source("rdv_threshold") == "resource"

    @given(st.booleans(), st.booleans(), st.booleans(),
           st.integers(min_value=1, max_value=1 << 20),
           st.integers(min_value=1, max_value=1 << 20),
           st.integers(min_value=1, max_value=1 << 20))
    @settings(max_examples=60, deadline=None)
    def test_property_highest_present_layer_wins(self, has_env, has_rt,
                                                 has_over, v_env, v_rt,
                                                 v_over):
        """Per-resource overrides beat runtime config beat REPRO_ATTR_*
        env beats library defaults — for every presence combination."""
        env = ({"REPRO_ATTR_EAGER_MAX_BYTES": str(v_env)}
               if has_env else {})
        rt = {"eager_max_bytes": v_rt} if has_rt else {}
        over = {"eager_max_bytes": v_over} if has_over else {}
        r = A.resolve(["eager_max_bytes"], runtime=rt, overrides=over,
                      env=env)
        if has_over:
            expect, source = v_over, "resource"
        elif has_rt:
            expect, source = v_rt, "runtime"
        elif has_env:
            expect, source = v_env, "env"
        else:
            expect, source = A.get_spec("eager_max_bytes").default, "default"
        assert r["eager_max_bytes"] == expect
        assert r.source("eager_max_bytes") == source

    def test_full_chain_through_alloc_cq(self, monkeypatch):
        monkeypatch.setenv("REPRO_ATTR_CQ_CAPACITY", "5")
        assert C.LocalCluster(1)[0].alloc_cq().capacity == 5
        cl = C.LocalCluster(1, attrs={"cq_capacity": 7})
        assert cl[0].alloc_cq().capacity == 7
        cq = cl[0].alloc_cq(capacity=9)
        assert cq.capacity == 9
        assert cq.attr_source("cq_capacity") == "resource"

    def test_env_override_reaches_config(self, monkeypatch):
        monkeypatch.setenv("REPRO_ATTR_EAGER_MAX_BYTES", "16")
        cl = C.LocalCluster(1)
        assert cl.config.inject_max_bytes == 16
        assert cl[0].get_attr("eager_max_bytes") == 16
        assert cl[0].attr_source("eager_max_bytes") == "env"

    def test_cluster_attrs_beat_explicit_config_fields(self):
        cl = C.LocalCluster(1, C.CommConfig(inject_max_bytes=128),
                            attrs={"eager_max_bytes": 32})
        assert cl.config.inject_max_bytes == 32

    def test_spec_path_honors_runtime_layer(self):
        """alloc_endpoint(spec=...) re-resolves the spec's non-explicit
        fields through the cluster's attrs layer; fields the spec's
        caller pinned stay pinned."""
        cl = C.LocalCluster(1, attrs={"stripe": "by_peer"})
        ambient = cl[0].alloc_endpoint(spec=C.EndpointSpec(name="a"))
        assert ambient.spec.stripe == "by_peer"
        pinned = cl[0].alloc_endpoint(
            spec=C.EndpointSpec(name="p", stripe="round_robin"))
        assert pinned.spec.stripe == "round_robin"

    def test_collapsed_device_width_agrees_with_introspection(self):
        """BSP collapses channels to 1; the stored resolution must say
        so (what the device runs with, not the raw knob)."""
        cl = C.LocalCluster(1, attrs={"mode": "bsp", "n_channels": 4})
        dev = cl[0].default_device
        assert dev.get_attr("n_channels") == dev.get_attr("width") == 1

    def test_echo_block_shape(self):
        echo = C.LocalCluster(1, attrs={"rdv_threshold": 4096}).attrs_echo()
        assert set(echo) == {"values", "sources"}
        assert echo["values"]["rdv_threshold"] == 4096
        assert echo["sources"]["rdv_threshold"] == "runtime"
        assert echo["sources"]["rank_n"] == "discovered"
        import json
        json.dumps(echo)                        # must be JSON-serializable


# ---------------------------------------------------------------------------
# get_attr on every resource type
# ---------------------------------------------------------------------------

class TestEveryResourceQueryable:
    def test_all_eight_resource_types(self):
        cl = C.LocalCluster(2, attrs={"rdv_threshold": 4096})
        rt = cl[0]
        # 1. cluster
        assert cl.get_attr("fabric_depth") == 4096
        assert cl.get_attr("rank_n") == 2
        # 2. runtime
        assert rt.get_attr("rdv_threshold") == 4096
        assert rt.get_attr("rank_me") == 0
        assert rt.get_attr("free_packets") > 0
        # 3. device
        dev = rt.default_device
        assert dev.get_attr("width") == dev.n_channels
        assert dev.get_attr("backlog_capacity") == 0
        # 4. endpoint
        ep = rt.alloc_endpoint(2, "by_peer", name="q")
        assert ep.get_attr("stripe") == "by_peer"
        assert ep.get_attr("width") == 2
        assert "contentions" in ep.get_attr("contention")
        # 5. packet pool
        pool = rt.packet_pool
        assert pool.get_attr("packets_per_lane") == \
            rt.get_attr("packets_per_lane")
        assert pool.get_attr("free_packets") == pool.free_packets()
        # 6. matching engine
        assert rt.matching.get_attr("matching_buckets") == 65536
        assert rt.matching.get_attr("inserts") == 0
        # 7. completion objects — all five kinds
        assert rt.alloc_cq(capacity=3).get_attr("cq_capacity") == 3
        assert rt.alloc_cq(threadsafe=True).get_attr("threadsafe") is True
        assert rt.alloc_sync(expected=2).get_attr("expected") == 2
        h = rt.alloc_handler(lambda st: None)
        assert h.get_attr("signals") == 0
        g = rt.alloc_graph("g")
        assert g.get_attr("n_nodes") == 0
        # 8. worker pool + fabric
        pool8 = rt.alloc_workers(2, burst=16)
        assert pool8.get_attr("worker_burst") == 16
        assert pool8.get_attr("n_workers") == 2
        assert cl.fabric.get_attr("fabric_depth") == 4096
        assert cl.fabric.get_attr("in_flight") == 0

    def test_attrs_snapshot_includes_discovered(self):
        rt = C.LocalCluster(1)[0]
        snap = rt.attrs
        assert snap["rank_me"] == 0
        assert "rdv_threshold" in snap

    def test_unknown_attr_names_resource_and_lists_available(self):
        rt = C.LocalCluster(1)[0]
        with pytest.raises(ValueError, match="Runtime.*no attribute"):
            rt.get_attr("does_not_exist")


# ---------------------------------------------------------------------------
# alloc-time validation (satellite: clear ValueErrors naming the attr)
# ---------------------------------------------------------------------------

class TestAllocValidation:
    def test_unknown_stripe_policy(self):
        with pytest.raises(ValueError, match="'stripe'.*hash"):
            C.EndpointSpec(stripe="hash")

    def test_unknown_progress_policy(self):
        with pytest.raises(ValueError, match="'progress'"):
            C.EndpointSpec(progress="thread")

    def test_nonpositive_devices(self):
        with pytest.raises(ValueError, match="'n_devices'"):
            C.EndpointSpec(n_devices=0)

    def test_negative_workers(self):
        with pytest.raises(ValueError, match="'n_workers'"):
            C.EndpointSpec(progress="workers", n_workers=-1)

    def test_worker_pool_rejects_nonpositive_workers(self):
        rt = C.LocalCluster(1)[0]
        with pytest.raises(ValueError, match="'n_workers'"):
            C.ProgressWorkerPool([(rt.engine, rt.default_device)],
                                 n_workers=0)

    def test_negative_capacity(self):
        rt = C.LocalCluster(1)[0]
        with pytest.raises(ValueError, match="'cq_capacity'"):
            rt.alloc_cq(capacity=-1)
        with pytest.raises(ValueError, match="'backlog_capacity'"):
            rt.alloc_device(backlog_capacity=-2)

    def test_negative_size_boundary(self):
        with pytest.raises(ValueError, match="'size_boundaries'"):
            C.EndpointSpec(n_devices=2, stripe="by_size",
                           size_boundaries=(-1, 64))

    def test_unknown_cluster_attr(self):
        with pytest.raises(ValueError, match="unknown attribute"):
            C.LocalCluster(1, attrs={"not_an_attr": 1})

    def test_unknown_alloc_override(self):
        rt = C.LocalCluster(1)[0]
        with pytest.raises(ValueError, match="unknown attribute override"):
            rt.alloc_device(stripe="by_peer")   # endpoint attr, not device

    def test_wrong_type(self):
        with pytest.raises(ValueError, match="'fabric_depth'.*int"):
            C.LocalCluster(1, attrs={"fabric_depth": "deep"})

    def test_explicit_workers_on_shared_endpoint_still_errors(self):
        with pytest.raises(ValueError, match="'n_workers'"):
            C.EndpointSpec(progress="shared", n_workers=3)

    def test_errors_are_fatal_errors_too(self):
        # the deprecation-shim contract: historical call sites catch
        # FatalError; AttrError must satisfy both spellings
        with pytest.raises(C.FatalError):
            C.EndpointSpec(stripe="hash")

    def test_readonly_attr_cannot_be_set(self):
        with pytest.raises(ValueError, match="read-only|readonly"):
            C.LocalCluster(1, attrs={"rank_n": 4})


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

class TestShims:
    def test_commconfig_old_kwargs_still_work(self):
        cfg = C.CommConfig(inject_max_bytes=256, bufcopy_max_bytes=1024)
        assert cfg.inject_max_bytes == 256
        assert cfg.bufcopy_max_bytes == 1024
        assert cfg.get_attr("eager_max_bytes") == 256
        assert cfg.get_attr("rdv_threshold") == 1024

    def test_commconfig_replace_roundtrip(self):
        import dataclasses
        cfg = dataclasses.replace(C.CommConfig(), n_channels=2)
        assert cfg.n_channels == 2
        assert cfg.resolved_channels() == 2

    def test_alias_spellings_resolve_with_warning(self):
        with pytest.warns(DeprecationWarning, match="inject_max_bytes"):
            cl = C.LocalCluster(1, attrs={"inject_max_bytes": 99})
        assert cl.config.inject_max_bytes == 99

    def test_get_attr_accepts_alias(self):
        cfg = C.CommConfig(bufcopy_max_bytes=2048)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            assert cfg.get_attr("bufcopy_max_bytes") == 2048

    def test_endpointspec_positional_compat(self):
        spec = C.EndpointSpec("ep", 2, "by_size", "dedicated")
        assert (spec.name, spec.n_devices, spec.stripe, spec.progress) == \
            ("ep", 2, "by_size", "dedicated")

    def test_spec_for_mode_roundtrip(self):
        spec = C.EndpointSpec.for_mode(C.CommMode.LCI_DEDICATED, 4)
        assert spec.progress == "dedicated" and spec.n_devices == 4


# ---------------------------------------------------------------------------
# env overrides really change behaviour (subprocess: fresh import + env)
# ---------------------------------------------------------------------------

_PROTO_SCRIPT = """
import numpy as np
import repro.core as C

cl = C.LocalCluster(2)
r0, r1 = cl[0], cl[1]
landed = []
h = r1.alloc_handler(landed.append)
buf = np.zeros(64, np.uint8)
C.post_recv_x(r1, 0, buf, 64, 7).local_comp(h)()
C.post_send_x(r0, 1, np.arange(64, dtype=np.uint8), 64, 7)()
for _ in range(10_000):
    if landed:
        break
    cl.progress_all()
assert landed, "message never delivered"
assert buf[13] == 13
s = r0.stats
print(f"inject={s.inject_msgs} bufcopy={s.bufcopy_msgs} "
      f"zerocopy={s.zerocopy_msgs} handshakes={s.handshakes} "
      f"rdv_threshold={r0.get_attr('rdv_threshold')}")
"""


def _run_proto_subprocess(extra_env):
    env = {k: v for k, v in os.environ.items()
           if not k.startswith(A.ENV_PREFIX)}
    env.update(extra_env)
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", _PROTO_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    return dict(kv.split("=") for kv in r.stdout.split())


class TestEnvOverrideSubprocess:
    def test_default_is_inject(self):
        out = _run_proto_subprocess({})
        assert out["inject"] == "1" and out["zerocopy"] == "0"
        assert out["rdv_threshold"] == str(2 * 1024 * 1024)

    def test_tiny_rdv_threshold_switches_to_rendezvous(self):
        # a 64-byte send with eager_max 8 / rdv_threshold 16 must take
        # the zero-copy rendezvous path (RTS/CTS handshake) — the env
        # layer really reaches protocol selection
        out = _run_proto_subprocess({
            "REPRO_ATTR_EAGER_MAX_BYTES": "8",
            "REPRO_ATTR_RDV_THRESHOLD": "16",
        })
        assert out["zerocopy"] == "1" and out["inject"] == "0"
        assert int(out["handshakes"]) >= 1
        assert out["rdv_threshold"] == "16"
