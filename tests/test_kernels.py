"""Pallas kernel sweeps: shapes × dtypes, assert_allclose vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_tpu
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm_tpu
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_tpu
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.moe_gmm.kernel import moe_gmm_tpu
from repro.kernels.moe_gmm.ref import moe_gmm_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 5e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,skv,dh,causal,window,q_offset", [
    (2, 4, 2, 64, 64, 16, True, 0, 0),
    (1, 4, 1, 128, 128, 32, True, 32, 0),
    (2, 2, 2, 64, 128, 16, True, 0, 64),      # SP: local q, longer kv
    (1, 6, 3, 96, 96, 16, False, 0, 0),       # encoder (bidirectional)
    (1, 8, 8, 32, 32, 64, True, 8, 0),        # MHA + window
])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, dh, causal, window,
                               q_offset, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, dh), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, dh), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, dh), dtype)
    got = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, block_q=32, block_k=32,
                              interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d,block", [(8, 64, 4), (64, 128, 16),
                                          (100, 96, 32), (1, 256, 8)])
def test_rmsnorm_sweep(rows, d, block, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, d), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,), dtype)
    got = rmsnorm_tpu(x, w, block_rows=block, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bs,h,s,p,g,n,chunk", [
    (2, 4, 64, 16, 2, 8, 16),
    (1, 4, 128, 32, 1, 16, 32),
    (3, 6, 48, 8, 3, 4, 16),
])
def test_ssd_sweep(bs, h, s, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (bs, h, s, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, h, s))).astype(dtype)
    a_log = (jax.random.normal(ks[2], (h,)) * 0.5).astype(jnp.float32)
    b = (jax.random.normal(ks[3], (bs, g, s, n)) * 0.3).astype(dtype)
    c = (jax.random.normal(ks[4], (bs, g, s, n)) * 0.3).astype(dtype)
    d = jax.random.normal(ks[5], (h,)).astype(jnp.float32)
    got = ssd_scan_tpu(x, dt, a_log, b, c, d, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x, dt, a_log, b, c, d)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["swiglu", "geglu", "gelu", "relu2"])
@pytest.mark.parametrize("e,cap,d,f,block", [(4, 32, 48, 24, 8),
                                             (2, 64, 32, 64, 32)])
def test_moe_gmm_sweep(e, cap, d, f, block, act, dtype):
    mult = 2 if act in ("swiglu", "geglu") else 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (e, cap, d), dtype)
    w1 = (jax.random.normal(ks[1], (e, d, mult * f)) * 0.2).astype(dtype)
    w2 = (jax.random.normal(ks[2], (e, f, d)) * 0.2).astype(dtype)
    got = moe_gmm_tpu(x, w1, w2, act=act, block_c=block, interpret=True)
    ref = moe_gmm_ref(x, w1, w2, act=act)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_attention():
    """The kernel and the model stack's scan-flash agree (same oracle)."""
    from repro.models.attention import flash_attention as model_flash
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (64, 2, 4, 16))      # (s, b, h, dh)
    k = jax.random.normal(ks[1], (64, 2, 2, 16))
    v = jax.random.normal(ks[2], (64, 2, 2, 16))
    a = model_flash(q, k, v, causal=True, block_q=16, block_k=16)
    b = flash_attention_tpu(q.transpose(1, 2, 0, 3), k.transpose(1, 2, 0, 3),
                            v.transpose(1, 2, 0, 3), causal=True,
                            block_q=16, block_k=16,
                            interpret=True).transpose(2, 0, 1, 3)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
