"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each family and run one forward + one train step on CPU,
asserting output shapes and no NaNs.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke, SHAPES, cells
from repro.models.registry import build_model
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_state_init

S, B = 32, 2


def _batch(cfg, key=0):
    rng = np.random.default_rng(key)
    tokens = rng.integers(0, cfg.vocab, size=(S, B)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((max(cfg.n_image_tokens, 4), B,
                                 cfg.d_model)), cfg.dtype)
    if cfg.is_encdec:
        t = max(cfg.n_audio_frames, 16)
        batch["frames"] = jnp.asarray(
            rng.standard_normal((t, B, cfg.d_model)), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    x, aux = jax.jit(lambda p, b: model.forward(p, b))(params, _batch(cfg))
    assert x.shape == (S, B, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()
    # specs pytree mirrors params exactly
    assert (jax.tree_util.tree_structure(params).num_leaves
            == len(jax.tree_util.tree_leaves(
                specs, is_leaf=lambda s: hasattr(s, "tp_axis"))))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3)
    state, specs = train_state_init(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, specs, opt))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # a second step must also be finite (optimizer state exercised)
    state, metrics = step(state, _batch(cfg, key=1))
    assert np.isfinite(float(metrics["loss"]))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch


def test_moe_extras():
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_experts, c.top_k) == (64, 6)
    c = get_config("olmoe-1b-7b")
    assert (c.n_experts, c.top_k) == (64, 8)


def test_ssm_extras():
    c = get_config("mamba2-370m")
    assert c.ssm_state == 128
    c = get_config("hymba-1.5b")
    assert c.ssm_state == 16


def test_cell_grid_is_40_with_documented_skips():
    grid = cells()
    assert len(grid) == 40
    skips = [(a, s) for a, s, ok, _ in grid if not ok]
    assert all(s == "long_500k" for _, s in skips)
    runs_long = {a for a, s, ok, _ in grid if s == "long_500k" and ok}
    assert runs_long == {"mamba2-370m", "hymba-1.5b", "gemma3-1b"}
    assert len(skips) == 7


def test_param_counts_sane():
    """Analytic parameter counts are in the advertised ballpark."""
    assert 90e9 < get_config("command-r-plus-104b").param_count() < 120e9
    assert 0.9e9 < get_config("olmo-1b").param_count() < 1.6e9
    assert 75e9 < get_config("llama-3.2-vision-90b").param_count() < 105e9
    # the assignment's dims (48L x 64e x d_ff 1408) give ~29B total / ~5B
    # active — we implement the assignment verbatim, not the HF card
    moe = get_config("moonshot-v1-16b-a3b")
    assert 20e9 < moe.param_count() < 35e9
    assert 2e9 < moe.active_param_count() < 6e9
    assert 0.3e9 < get_config("mamba2-370m").param_count() < 0.6e9
