"""Perf gate — diff a fresh BENCH-JSON against its committed baseline.

CI runs each benchmark at smoke scale, then calls this gate to compare
the fresh ``us_per_call`` numbers against the repo-tracked baselines
(BENCH_message_rate.json / BENCH_mt_message_rate.json, full-scale runs):
any matched case whose per-call cost regresses by more than
``--max-regression`` (default 25%) fails the job.  Serving rows carry
extra directional metrics: ``ttft_p50_ms`` gates like a latency (fail on
increase) and ``goodput_tok_s`` gates as a throughput (fail on
*decrease*); each is checked only when present on both sides, so
non-serving baselines are unaffected.  Tail (p99) fields are reported in
the rows but deliberately not gated — CI smoke cells are too short for
stable tails.  Cases are matched by
``(case, backend)`` — rows without a ``backend`` field are ``sim``, so
pre-transport baselines keep matching — and cases present on only one
side are reported and skipped (sweep shapes legitimately differ between
smoke and full runs).

    python benchmarks/compare.py BENCH_message_rate.json fresh.json
    python benchmarks/compare.py base.json fresh.json --max-regression 0.25
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple


#: (metric, lower_is_better) — gated only when both sides carry the
#: field, so pre-serving baselines are untouched
GATED_METRICS = (
    ("us_per_call", True),
    ("ttft_p50_ms", True),
    ("goodput_tok_s", False),
)


def load_rows(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("rows", []):
        if "case" in row and "us_per_call" in row:
            # backend-tagged rows (shm/socket cross-process sweeps) gate
            # separately from the sim rows sharing a case prefix
            rows[(row["case"], row.get("backend", "sim"))] = row
    return rows


def compare(baseline_path: str, fresh_path: str,
            max_regression: float) -> Tuple[List[str], List[str]]:
    """Returns (report_lines, failure_lines)."""
    base = load_rows(baseline_path)
    fresh = load_rows(fresh_path)
    report, failures = [], []
    matched = sorted(set(base) & set(fresh))
    if not matched:
        failures.append(f"no common cases between {baseline_path} and "
                        f"{fresh_path} — the gate compared nothing")
        return report, failures
    for key in matched:
        case, backend = key
        label = case if backend == "sim" else f"{case}[{backend}]"
        for metric, lower_is_better in GATED_METRICS:
            if metric not in base[key] or metric not in fresh[key]:
                continue
            b, f = base[key][metric], fresh[key][metric]
            if lower_is_better:
                ratio = f / b if b else float("inf")
            else:                       # throughput: gate the decrease
                ratio = b / f if f else float("inf")
            verdict = "ok"
            if ratio > 1.0 + max_regression:
                verdict = "REGRESSION"
                direction = "slower" if lower_is_better else "lower"
                failures.append(
                    f"{label}: {metric} {f:.3f} vs baseline {b:.3f} "
                    f"({ratio:.2f}x {direction}, limit "
                    f"{1.0 + max_regression:.2f}x)")
            tag = label if metric == "us_per_call" \
                else f"{label}:{metric}"
            report.append(f"{tag:32s} base={b:9.3f}  fresh={f:9.3f}  "
                          f"{ratio:5.2f}x  {verdict}")
    for key in sorted(set(base) ^ set(fresh)):
        case, backend = key
        label = case if backend == "sim" else f"{case}[{backend}]"
        side = "baseline" if key in base else "fresh"
        report.append(f"{label:32s} ({side} only — skipped)")
    return report, failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline BENCH-JSON")
    ap.add_argument("fresh", help="freshly generated BENCH-JSON")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="allowed fractional us_per_call increase "
                         "(0.25 = fail on >25%% slower)")
    args = ap.parse_args()

    report, failures = compare(args.baseline, args.fresh,
                               args.max_regression)
    for line in report:
        print(line)
    if failures:
        print(f"\nPERF GATE FAILED ({len(failures)}):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"perf gate OK (max regression {args.max_regression:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
