"""Open-loop serving traffic on the continuous-batching engine.

The paper's "new possibilities" workload (HPX/LCI communication-needs
profile: many small latency-critical messages drained by worker threads)
driven to production shape: thousands of simulated clients submit
prompts on a Poisson arrival process with heavy-tailed prompt/output
lengths, the :class:`~repro.serving.ContinuousBatcher` serves them over
isolated prefill/decode endpoints, and every generated token rides a
``post_am_many`` burst back to stamped :class:`ResultDrain` workers.

Open loop means arrival times come from the schedule, not from request
completion — the engine is never protected from a burst by its own
slowness.  Per cell the harness verifies the exactly-once contract
(every client's full stream, no loss/dup/reorder — the run *fails*
otherwise, including the ``chaos_drop`` cell) and reports:

* TTFT p50/p99 (submit -> first token at a drain worker), ms
* per-token latency p50/p99 (inter-token gap at the drain), us
* goodput (delivered tokens / wall clock), tok/s
* decode-slot occupancy (mean + peak of ``SlotAllocator.occupancy``)

``--fabric shm`` adds a cross-process cell: rank 0 runs the client, rank
1 the server, over shm rings under ``launch/spmd.py``; the client sends
the end-of-traffic control message only after its drains account for
every expected token, then both ranks publish fragments the parent
merges into one row.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

if __package__ in (None, ""):                 # `python benchmarks/...py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _xproc():
    """The cross-process plumbing, importable both as a package module
    and as a bare script."""
    try:
        from . import _xproc as mod
    except ImportError:                          # script mode
        import _xproc as mod
    return mod


VOCAB = 32000
PROMPT_CLIP = (4, 256)
OUTPUT_CLIP = (1, 64)
SUBMIT_DEADLINE_S = 60.0
DRAIN_DEADLINE_S = 120.0


def make_workload(n_clients: int, duration: float, seed: int):
    """Deterministic open-loop schedule: Poisson arrivals (uniform order
    statistics conditioned on N) with lognormal heavy-tailed prompt and
    output lengths."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.uniform(0.0, duration, n_clients))
    plens = np.clip(rng.lognormal(2.8, 1.0, n_clients),
                    *PROMPT_CLIP).astype(int)
    outs = np.clip(rng.lognormal(1.4, 0.9, n_clients),
                   *OUTPUT_CLIP).astype(int)
    prompts = [rng.integers(0, VOCAB, p).astype(np.int32) for p in plens]
    return arrivals, prompts, outs


def server_overrides(n_clients: int) -> Dict[str, int]:
    """Engine geometry scaled to the cell (per-alloc attr overrides)."""
    slots = max(8, min(64, n_clients // 8))
    return {"kv_slots": slots, "kv_page_tokens": 16,
            "kv_pages": 16 * slots, "prefill_chunk": 32}


def _percentiles(xs, scale: float) -> Tuple[float, float]:
    if not len(xs):
        return 0.0, 0.0
    arr = np.asarray(xs) * scale
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def _metrics_row(case: str, backend: str, n_clients: int, duration: float,
                 report: dict, wall: float, occupancy: dict,
                 counters: dict, chaos_drop: float = 0.0) -> dict:
    if report["completed"] != report["submitted"] or report["lost"] or \
            report["duplicated"] or report["mismatched"] or \
            report["out_of_order"]:
        bad = {k: report[k] for k in ("submitted", "completed", "lost",
                                      "duplicated", "mismatched",
                                      "out_of_order")}
        raise RuntimeError(
            f"{case}: exactly-once contract violated: {bad}")
    ttft_p50, ttft_p99 = _percentiles(report["ttft_s"], 1e3)
    tok_p50, tok_p99 = _percentiles(report["gap_s"], 1e6)
    goodput = report["tokens"] / wall if wall > 0 else 0.0
    return {
        "bench": "serve_traffic",
        "case": case,
        "backend": backend,
        "clients": n_clients,
        "duration_s": duration,
        "us_per_call": tok_p50,
        "derived": f"{goodput:,.0f} tok/s goodput, "
                   f"TTFT p50 {ttft_p50:.2f} ms",
        "ttft_p50_ms": ttft_p50,
        "ttft_p99_ms": ttft_p99,
        "tok_p50_us": tok_p50,
        "tok_p99_us": tok_p99,
        "goodput_tok_s": goodput,
        "slot_occupancy_mean": occupancy["mean"],
        "slot_occupancy_peak": occupancy["peak"],
        "tokens": report["tokens"],
        "completed": report["completed"],
        "lost": report["lost"],
        "duplicated": report["duplicated"],
        "submit_retries": report["submit_retries"],
        "preemptions": counters.get("preemptions", 0),
        "chaos_drop": chaos_drop,
    }


class _OccupancySampler:
    """Time-throttled samples of the slot allocator's occupancy."""

    def __init__(self, slots, period_s: float = 2e-3):
        self.slots = slots
        self.period = period_s
        self.samples: List[float] = []
        self._last = 0.0

    def tick(self) -> None:
        now = time.perf_counter()
        if now - self._last >= self.period:
            self.samples.append(self.slots.occupancy())
            self._last = now

    def result(self) -> Dict[str, float]:
        if not self.samples:
            return {"mean": 0.0, "peak": 0.0}
        return {"mean": float(np.mean(self.samples)),
                "peak": float(np.max(self.samples))}


# ---------------------------------------------------------------------------
# single-process cell: both roles on one LocalCluster
# ---------------------------------------------------------------------------

def run_cell_local(n_clients: int, duration: float, *, seed: int = 0,
                   chaos_drop: float = 0.0, telemetry_level: str = "off",
                   snaps: Optional[list] = None) -> dict:
    from repro.core.runtime import LocalCluster
    from repro.serving import (ContinuousBatcher, ServePlane,
                               SyntheticModel, TokenClient)

    attrs = {"telemetry_level": telemetry_level}
    if chaos_drop:
        attrs.update({"chaos_drop": chaos_drop, "chaos_seed": seed + 1})
    cluster = LocalCluster(2, attrs=attrs, fabric_depth=1 << 15)
    try:
        plane = ServePlane(cluster)
        model = SyntheticModel(seed=seed)
        server = ContinuousBatcher(plane, model,
                                   **server_overrides(n_clients))
        client = TokenClient(plane, model, drain_workers=2)
        occ = _OccupancySampler(server.slots)
        arrivals, prompts, outs = make_workload(n_clients, duration, seed)

        t0 = time.perf_counter()
        for i in range(n_clients):
            while time.perf_counter() - t0 < arrivals[i]:
                server.step()
                occ.tick()
            rid, st = client.submit(prompts[i], int(outs[i]))
            deadline = time.monotonic() + SUBMIT_DEADLINE_S
            while st.is_retry():
                server.step()
                occ.tick()
                if time.monotonic() > deadline:
                    raise RuntimeError(f"submit wedged at client {i}")
                rid, st = client.submit(prompts[i], int(outs[i]), rid=rid)
        # drain: accepted prompts may still be in (retransmit) flight —
        # the server steps until it has finished every submitted request
        deadline = time.monotonic() + DRAIN_DEADLINE_S
        while not (server.completed >= n_clients and server.idle):
            server.step()
            occ.tick()
            if time.monotonic() > deadline:
                raise RuntimeError(
                    f"server stalled: {server.counters()}")
        while client.drain.drained < client.expected_tokens:
            client.pump()
            if time.monotonic() > deadline:
                break
        wall = time.perf_counter() - t0
        report = client.collect()
        counters = server.counters()
        if snaps is not None:
            snaps.append(cluster.telemetry_snapshot())
        echo = cluster.attrs_echo()
        serve_echo = server.attrs_echo()
        resolved = {"values": {**echo["values"], **serve_echo["values"]},
                    "sources": {**echo["sources"],
                                **serve_echo["sources"]}}
        return {"report": report, "wall": wall, "counters": counters,
                "occupancy": occ.result(), "resolved_attrs": resolved}
    finally:
        cluster.close()


# ---------------------------------------------------------------------------
# cross-process cell (--fabric shm|socket): rank 0 client, rank 1 server
# ---------------------------------------------------------------------------

def _xproc_child(args) -> int:
    from repro.core import ProcessCluster
    from repro.launch.spmd import bootstrap
    from repro.serving import (ContinuousBatcher, ServePlane,
                               SyntheticModel, TokenClient)

    ctx = bootstrap()
    n_clients = args.xproc_clients
    duration = args.xproc_duration
    cl = ProcessCluster(ctx.n_ranks, ctx.rank, fabric_depth=1 << 15,
                        fabric_backend=args.fabric,
                        session=os.path.join(ctx.session, "serve"))
    plane = ServePlane(cl, client_rank=0, server_rank=1)
    model = SyntheticModel(seed=args.seed)
    ctx.barrier(timeout=60)
    ok = True
    if ctx.rank == 1:
        server = ContinuousBatcher(plane, model,
                                   **server_overrides(n_clients))
        occ = _OccupancySampler(server.slots)
        deadline = time.monotonic() + duration + DRAIN_DEADLINE_S
        # serve until the client declares end-of-traffic (which it only
        # does after draining every expected token) and nothing resident
        while not (server.eot_seen and server.idle):
            server.step()
            occ.tick()
            if time.monotonic() > deadline:
                ok = False
                break
        _xproc().write_fragment({
            "rank": 1, "role": "server", "ok": ok,
            "counters": server.counters(),
            "occupancy": occ.result(),
            "resolved_attrs": server.attrs_echo(),
            "telemetry": cl.telemetry_snapshot()})
    else:
        client = TokenClient(plane, model, drain_workers=2)
        arrivals, prompts, outs = make_workload(n_clients, duration,
                                                args.seed)
        t0 = time.perf_counter()
        for i in range(n_clients):
            while time.perf_counter() - t0 < arrivals[i]:
                client.pump()
            rid, st = client.submit(prompts[i], int(outs[i]))
            deadline = time.monotonic() + SUBMIT_DEADLINE_S
            while st.is_retry():
                client.pump()
                if time.monotonic() > deadline:
                    ok = False
                    break
                rid, st = client.submit(prompts[i], int(outs[i]), rid=rid)
        deadline = time.monotonic() + DRAIN_DEADLINE_S
        while client.drain.drained < client.expected_tokens:
            client.pump()
            if time.monotonic() > deadline:
                ok = False
                break
        wall = time.perf_counter() - t0
        client.send_eot()
        for _ in range(200):                  # flush the EOT + acks
            client.pump()
        report = client.collect()
        ok = ok and not (report["lost"] or report["duplicated"]
                         or report["mismatched"] or report["out_of_order"])
        _xproc().write_fragment({
            "rank": 0, "role": "client", "ok": ok,
            "report": report, "wall": wall,
            "resolved_attrs": cl.attrs_echo(),
            "telemetry": cl.telemetry_snapshot()})
    ctx.barrier(timeout=60)
    cl.close()
    ctx.close()
    return 0 if ok else 1


def run_cell_xproc(args, snaps: Optional[list] = None) -> dict:
    frags = _xproc().launch_self(sys.argv[1:], args.fabric, 2,
                                 timeout=args.xproc_timeout)
    by_role = {f["role"]: f for f in frags}
    client, server = by_role["client"], by_role["server"]
    if snaps is not None:
        snaps += [f.get("telemetry") for f in frags]
    return {"report": client["report"], "wall": client["wall"],
            "counters": server["counters"],
            "occupancy": server["occupancy"],
            "resolved_attrs": {"client": client["resolved_attrs"],
                               "server": server["resolved_attrs"]}}


# ---------------------------------------------------------------------------
# sweep + entry points
# ---------------------------------------------------------------------------

def _serve_demo_snapshot() -> dict:
    """A small timers-level serve cell so the committed BENCH carries
    real ``serve.*`` stage spans (timed cells run at ``off``)."""
    cell = run_cell_local(8, 0.2, seed=42, telemetry_level="timers",
                          snaps=(demo := []))
    del cell
    return demo[0]


def sweep(args) -> Tuple[List[dict], dict, list]:
    rows: List[dict] = []
    snaps: list = []
    resolved: dict = {}

    cells = [(64, 2.0)]
    if args.clients > 64:
        cells.append((min(256, args.clients), min(4.0, args.duration)))
    if args.clients > 256:
        cells.append((args.clients, args.duration))
    for n, dur in cells:
        cell = run_cell_local(n, dur, seed=args.seed, snaps=snaps)
        resolved = cell["resolved_attrs"]
        rows.append(_metrics_row(f"c{n}/d{dur:g}", "sim", n, dur,
                                 cell["report"], cell["wall"],
                                 cell["occupancy"], cell["counters"]))
        print(f"  {rows[-1]['case']:24s} {rows[-1]['derived']}")

    n = min(128, args.clients)
    cell = run_cell_local(n, 2.0, seed=args.seed, chaos_drop=0.05,
                          snaps=snaps)
    row = _metrics_row(f"c{n}/d2/chaos_drop", "sim", n, 2.0,
                       cell["report"], cell["wall"], cell["occupancy"],
                       cell["counters"], chaos_drop=0.05)
    rows.append(row)
    print(f"  {row['case']:24s} {row['derived']}  "
          f"lost={row['lost']} dup={row['duplicated']}")

    if args.fabric != "sim":
        cell = run_cell_xproc(args, snaps=snaps)
        resolved = {**resolved, "xproc": cell["resolved_attrs"]}
        row = _metrics_row(
            f"c{args.xproc_clients}/d{args.xproc_duration:g}"
            f"/xproc/{args.fabric}",
            args.fabric, args.xproc_clients, args.xproc_duration,
            cell["report"], cell["wall"], cell["occupancy"],
            cell["counters"])
        rows.append(row)
        print(f"  {row['case']:24s} {row['derived']}")

    snaps.append(_serve_demo_snapshot())
    return rows, resolved, snaps


def run(quick: bool = True) -> List[dict]:
    """Aggregator entry (benchmarks.run): one quick local cell plus the
    chaos leg — the full sweep is the script's ``main``."""
    ns = argparse.Namespace(clients=64 if quick else 1024,
                            duration=2.0 if quick else 4.0,
                            seed=0, fabric="sim", xproc_clients=128,
                            xproc_duration=2.0, xproc_timeout=300.0)
    rows, _, _ = sweep(ns)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--clients", type=int, default=1024,
                    help="simulated clients in the top open-loop cell")
    ap.add_argument("--duration", type=float, default=4.0,
                    help="arrival-window seconds for the top cell")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (arrivals, lengths, prompts)")
    ap.add_argument("--fabric", default="sim",
                    choices=("sim", "shm", "socket"),
                    help="non-sim adds a cross-process client/server "
                         "cell under launch/spmd.py")
    ap.add_argument("--xproc-clients", type=int, default=128,
                    help="clients in the cross-process cell")
    ap.add_argument("--xproc-duration", type=float, default=2.0,
                    help="arrival-window seconds, cross-process cell")
    ap.add_argument("--xproc-timeout", type=float, default=300.0,
                    help="launcher wall-clock bound")
    ap.add_argument("--json", default="BENCH_serve_traffic.json",
                    help="output JSON path ('' disables)")
    args = ap.parse_args()

    if args.fabric != "sim" and _xproc().in_child():
        sys.exit(_xproc_child(args))

    _xproc().assert_clean_host()     # leftover SPMD jobs skew timing
    rows, resolved_attrs, snaps = sweep(args)
    for r in rows:
        print(f"{r['case']:28s} TTFT p50/p99 {r['ttft_p50_ms']:8.2f}/"
              f"{r['ttft_p99_ms']:8.2f} ms  tok p50/p99 "
              f"{r['tok_p50_us']:8.1f}/{r['tok_p99_us']:8.1f} us  "
              f"{r['goodput_tok_s']:10,.0f} tok/s  occ "
              f"{r['slot_occupancy_mean']:.2f}/"
              f"{r['slot_occupancy_peak']:.2f}  lost={r['lost']} "
              f"dup={r['duplicated']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "serve_traffic",
                       "clients": args.clients,
                       "duration_s": args.duration,
                       "seed": args.seed,
                       "resolved_attrs": resolved_attrs,
                       "telemetry": _xproc().telemetry_block(snaps),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
