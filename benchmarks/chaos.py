"""What faults cost: message rate under injected loss, and rank-death
recovery latency (DESIGN.md §16).

Two cell families:

* ``drop_sweep`` — the message-rate kernel (tagged eager AMs rank 0 →
  rank 1, quiesced) at drop = dup = reorder = {0, 2, 5, 10}%.  The 0%
  row runs chaos-free (no wrapper, no reliability layer) and is the
  baseline; every faulted row reports its slowdown against it plus the
  retransmit/dup/resequence work the reliability plane did to keep
  delivery exactly-once and in order.
* ``rank_death`` — a stream toward a peer that dies mid-run: measures
  the time from ``mark_peer_dead`` until every outstanding post has
  completed as ``ERR_PEER_DEAD`` (the no-hang guarantee's latency).

Emits ``BENCH_chaos.json`` (same row schema as the other benchmarks).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

if __package__ in (None, ""):                 # `python benchmarks/...py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ErrorCode, LocalCluster, post_am


def _xproc():
    try:
        from . import _xproc as mod
    except ImportError:
        import _xproc as mod
    return mod

_ATTRS = {"eager_max_bytes": 64, "packets_per_lane": 64}
_DEPTH = 1 << 14
_SEED = 42


def _cluster(fault: float) -> LocalCluster:
    attrs = dict(_ATTRS)
    if fault > 0:
        attrs.update({"chaos_drop": fault, "chaos_dup": fault,
                      "chaos_reorder": fault, "chaos_seed": _SEED})
    return LocalCluster(2, attrs=attrs, fabric_depth=_DEPTH)


def _attrs_echo() -> dict:
    from repro.core import attrs as A
    from repro.core.progress.reliability import RELIABILITY_ATTRS
    from repro.core.runtime import RUNTIME_ATTRS
    from repro.core.transport.chaos import CHAOS_ATTRS
    return A.resolve((*RUNTIME_ATTRS, *CHAOS_ATTRS, *RELIABILITY_ATTRS,
                      "fabric_depth"),
                     runtime=_ATTRS,
                     overrides={"fabric_depth": _DEPTH}).echo()


def run_drop_cell(fault: float, n_msgs: int, size: int,
                  snaps=None) -> dict:
    """Message rate at one fault level; asserts exactly-once delivery."""
    cl = _cluster(fault)
    try:
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        buf = np.zeros(size, np.uint8)
        got = 0
        t0 = time.perf_counter()
        for i in range(n_msgs):
            st = post_am(r0, 1, buf, remote_comp=rc, tag=i)
            while st.is_retry():
                r0.progress()
                r1.progress()
                while cq.pop().is_done():
                    got += 1
                st = post_am(r0, 1, buf, remote_comp=rc, tag=i)
        cl.quiesce()
        while cq.pop().is_done():
            got += 1
        elapsed = time.perf_counter() - t0
        if got != n_msgs:
            raise RuntimeError(
                f"drop_sweep fault={fault}: delivered {got}/{n_msgs} — "
                f"the reliability plane failed its exactly-once contract")
        # sender holds the retransmit counters, receiver the dedup /
        # resequence ones — merge both ranks' views
        rel: dict = {}
        for rt in (r0, r1):
            if rt.rel is not None:
                for k, v in rt.rel.counters().items():
                    rel[k] = rel.get(k, 0) + v
        fab = (cl.fabric.fault_counters()
               if hasattr(cl.fabric, "fault_counters") else {})
        if snaps is not None:
            snaps.append(cl.telemetry_snapshot())
        return {"rate": n_msgs / elapsed,
                "us": elapsed / n_msgs * 1e6,
                "retransmits": rel.get("retransmits", 0),
                "dups_dropped": rel.get("dups_dropped", 0),
                "resequenced": rel.get("resequenced", 0),
                "faults": {k: v for k, v in fab.items()
                           if k != "dead_ranks"}}
    finally:
        cl.close()


def run_rank_death(n_outstanding: int, size: int, snaps=None) -> dict:
    """Time from peer-death declaration to every outstanding post
    completing ERR_PEER_DEAD (eager_max_bytes=0: every send is
    bufcopy-class so its completion is observable)."""
    cl = LocalCluster(2, attrs={**_ATTRS, "eager_max_bytes": 0,
                                "chaos_drop": 1.0, "chaos_seed": _SEED,
                                "retry_limit": 1_000_000})
    try:
        r0 = cl[0]
        scq = r0.alloc_cq()
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        buf = np.zeros(size, np.uint8)
        for i in range(n_outstanding):
            st = post_am(r0, 1, buf, local_comp=scq, remote_comp=rc, tag=i)
            while st.is_retry():
                r0.progress()
                st = post_am(r0, 1, buf, local_comp=scq, remote_comp=rc,
                             tag=i)
        assert r0.pending_ops
        t0 = time.perf_counter()
        r0.mark_peer_dead(1)
        dead = 0
        deadline = time.monotonic() + 30.0
        while dead < n_outstanding and time.monotonic() < deadline:
            r0.progress()
            st = scq.pop()
            if not st.is_retry():
                if st.code != ErrorCode.ERR_PEER_DEAD:
                    raise RuntimeError(f"unexpected completion {st.code!r}")
                dead += 1
        ms = (time.perf_counter() - t0) * 1e3
        if dead != n_outstanding or r0.pending_ops:
            raise RuntimeError(
                f"rank_death: {dead}/{n_outstanding} completed, "
                f"{len(r0.pending_ops)} ops leaked — the no-hang "
                f"guarantee broke")
        if snaps is not None:
            snaps.append(cl.telemetry_snapshot())
        return {"ms": ms, "n": n_outstanding}
    finally:
        cl.close()


def run(quick: bool = True, n_msgs: int = 0, size: int = 32,
        snaps=None) -> List[dict]:
    n_msgs = n_msgs or (400 if quick else 2000)
    rows = []
    base_rate = None
    for fault in (0.0, 0.02, 0.05, 0.10):
        cell = run_drop_cell(fault, n_msgs, size, snaps=snaps)
        if base_rate is None:
            base_rate = cell["rate"]
            derived = f"{cell['rate']:,.0f} msg/s chaos-free baseline"
        else:
            derived = (f"{cell['rate']:,.0f} msg/s "
                       f"({cell['rate'] / base_rate:.2f}x baseline), "
                       f"{cell['retransmits']} retransmits, "
                       f"{cell['dups_dropped']} dups dropped, "
                       f"{cell['resequenced']} resequenced")
        rows.append({"bench": "chaos",
                     "case": f"drop_sweep/{fault:.2f}/{n_msgs}x{size}B",
                     "us_per_call": cell["us"],
                     "derived": derived,
                     "reliability": {k: cell[k] for k in
                                     ("retransmits", "dups_dropped",
                                      "resequenced")},
                     "faults": cell["faults"]})
    death = run_rank_death(64 if quick else 256, size, snaps=snaps)
    rows.append({"bench": "chaos",
                 "case": f"rank_death/{death['n']}outstanding",
                 "us_per_call": death["ms"] * 1e3 / death["n"],
                 "derived": f"{death['ms']:.2f} ms to fail "
                            f"{death['n']} posts ERR_PEER_DEAD (no hang)"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--msgs", type=int, default=400,
                    help="messages per drop-sweep cell")
    ap.add_argument("--size", type=int, default=32,
                    help="payload bytes per message")
    ap.add_argument("--json", default="BENCH_chaos.json",
                    help="output JSON path ('' disables)")
    args = ap.parse_args()

    _xproc().assert_clean_host()     # leftover SPMD jobs skew timing
    snaps: list = []
    rows = run(n_msgs=args.msgs, size=args.size, snaps=snaps)
    for r in rows:
        print(f"{r['case']:36s} {r['us_per_call']:9.3f} us  {r['derived']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "chaos", "msgs": args.msgs,
                       "size": args.size,
                       "resolved_attrs": _attrs_echo(),
                       "telemetry": _xproc().telemetry_block(snaps),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
