"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/artifacts/dryrun/*.json and prints, per (arch × shape ×
mesh × mode): the three roofline terms (compute / memory / collective
seconds on TPU v5e constants), the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs, and the roofline fraction.  ``python -m benchmarks.roofline``.

The **message-rate roofline** (fused-doorbell PR, DESIGN.md §13) places
the measured ``BENCH_message_rate`` result against the *simulated wire
bound* — the per-message cost of the bare fabric (descriptor + queue
ops, no posting/matching/completion software) — and reports what
fraction of that bound the fused data plane reaches.  ``--json`` writes
the row(s) to a BENCH document.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional

if __package__ in (None, ""):                 # `python benchmarks/...py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def _xproc():
    """Shared benchmark plumbing (hygiene preflight, telemetry block),
    importable as a package module and as a bare script."""
    try:
        from . import _xproc as mod
    except ImportError:
        import _xproc as mod
    return mod


def load_cells(mesh: Optional[str] = None, mode: Optional[str] = None
               ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        if path.endswith(".ops.json"):
            continue
        art = json.load(open(path))
        if mesh and art.get("mesh") != mesh:
            continue
        if mode and art.get("mode") != mode:
            continue
        rows.append(art)
    return rows


def table(mesh: str = "single", mode: str = "lci_dedicated") -> str:
    rows = load_cells(mesh, mode)
    out = [f"{'arch':22s} {'shape':12s} {'comp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s}"]
    for art in rows:
        if art.get("status") == "skipped":
            out.append(f"{art['cell'].split('__')[0]:22s} "
                       f"{art['cell'].split('__')[1]:12s} "
                       f"{'—':>8s} {'—':>8s} {'—':>8s} {'skipped':>10s}")
            continue
        if art.get("status") != "ok":
            continue
        r = art["roofline"]
        out.append(
            f"{art['arch']:22s} {art['shape']:12s} "
            f"{r['compute_s'] * 1e3:8.2f} {r['memory_s'] * 1e3:8.2f} "
            f"{r['collective_s'] * 1e3:8.2f} {r['dominant']:>10s} "
            f"{r['useful_flop_ratio']:7.2f} "
            f"{r['roofline_fraction'] * 100:6.1f}%")
    return "\n".join(out)


def run(quick: bool = True) -> List[dict]:
    rows = []
    for art in load_cells("single", "lci_dedicated"):
        if art.get("status") != "ok":
            continue
        r = art["roofline"]
        rows.append({
            "bench": "roofline",
            "case": f"{art['arch']}/{art['shape']}",
            "us_per_call": r["bound_s"] * 1e6,
            "derived": (f"{r['dominant']}-bound "
                        f"{r['roofline_fraction'] * 100:.0f}% "
                        f"useful={r['useful_flop_ratio']:.2f}"),
        })
    return rows


def simulated_wire_bound(iters: int = 30000, payload_bytes: int = 8,
                         burst: int = 1) -> float:
    """us/msg through the bare simulated wire — descriptor construction
    + fabric push + drain, nothing else.  ``burst=1`` is the scalar
    plane's floor (one WireMsg per message); ``burst=K`` the fused
    plane's (one packed descriptor per K-row doorbell, DESIGN.md §13).
    The posting software can approach these but not beat them."""
    import numpy as np
    from repro.core.progress.fabric import (Fabric, PackedBurst, WireKind,
                                            WireMsg)

    fab = Fabric(2, depth=1 << 16)
    payload = np.zeros(payload_bytes, np.uint8)
    data = np.broadcast_to(payload, (burst, payload_bytes))
    sizes = np.full(burst, payload_bytes, np.int64)
    tags = [0] * burst
    pushed = 0
    t0 = time.perf_counter()
    while pushed < iters:
        if burst == 1:
            for _ in range(64):
                fab.try_push(WireMsg(WireKind.EAGER_AM, src=0, dst=1,
                                     payload=payload, size=payload_bytes,
                                     rcomp=0))
            fab.drain(1, 0)
            pushed += 64
        else:
            pb = PackedBurst(data, sizes, tags, burst)
            fab.push_packed(WireMsg(WireKind.EAGER_PACKED_AM, src=0,
                                    dst=1, payload=pb,
                                    size=int(data.nbytes), rcomp=0))
            fab.drain(1, 0)
            pushed += burst
    return (time.perf_counter() - t0) / pushed * 1e6


def message_rate_vs_wire(bench_path: str = "BENCH_message_rate.json"
                         ) -> Optional[dict]:
    """The fused data plane's fraction of the simulated wire bound,
    taken from the committed (or freshly written) message-rate BENCH
    document's widest plain cell."""
    if not os.path.exists(bench_path):
        return None
    doc = json.load(open(bench_path))
    plain = [r for r in doc.get("rows", [])
             if not r["case"].endswith("/bf16")]
    if not plain:
        return None
    fused = plain[-1]                         # widest endpoint cell
    burst = int(doc.get("burst", 1))
    bound = simulated_wire_bound(burst=max(1, burst))
    scalar_bound = simulated_wire_bound(burst=1)
    frac = bound / fused["us_per_call"] if fused["us_per_call"] else 0.0
    return {
        "bench": "roofline",
        "case": f"message_rate/{fused['case']}",
        "us_per_call": fused["us_per_call"],
        "wire_bound_us": bound,
        "scalar_wire_bound_us": scalar_bound,
        "fraction_of_wire_bound": frac,
        "derived": f"packed wire bound {bound:.3f} us/msg -> "
                   f"{frac * 100:.0f}% of bound "
                   f"(scalar wire floor {scalar_bound:.3f})",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="BENCH_message_rate.json",
                    help="message-rate BENCH document to place against "
                         "the wire bound")
    ap.add_argument("--json", default="",
                    help="write the roofline rows to this BENCH-JSON "
                         "('' prints only)")
    args = ap.parse_args()
    _xproc().assert_clean_host()     # the wire bound is a timed cell too
    print(table())
    row = message_rate_vs_wire(args.bench)
    if row is not None:
        print(f"\n{row['case']}: {row['us_per_call']:.3f} us/msg, "
              f"{row['derived']}")
    if args.json:
        rows = ([row] if row is not None else []) + run()
        # the wire-bound cells run on a bare Fabric (no cluster), so the
        # stage summaries come from the shared timers-level demo cell
        with open(args.json, "w") as f:
            json.dump({"bench": "roofline",
                       "telemetry": _xproc().telemetry_block([]),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
