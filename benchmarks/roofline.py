"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads benchmarks/artifacts/dryrun/*.json and prints, per (arch × shape ×
mesh × mode): the three roofline terms (compute / memory / collective
seconds on TPU v5e constants), the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs, and the roofline fraction.  ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_cells(mesh: Optional[str] = None, mode: Optional[str] = None
               ) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        if path.endswith(".ops.json"):
            continue
        art = json.load(open(path))
        if mesh and art.get("mesh") != mesh:
            continue
        if mode and art.get("mode") != mode:
            continue
        rows.append(art)
    return rows


def table(mesh: str = "single", mode: str = "lci_dedicated") -> str:
    rows = load_cells(mesh, mode)
    out = [f"{'arch':22s} {'shape':12s} {'comp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s}"]
    for art in rows:
        if art.get("status") == "skipped":
            out.append(f"{art['cell'].split('__')[0]:22s} "
                       f"{art['cell'].split('__')[1]:12s} "
                       f"{'—':>8s} {'—':>8s} {'—':>8s} {'skipped':>10s}")
            continue
        if art.get("status") != "ok":
            continue
        r = art["roofline"]
        out.append(
            f"{art['arch']:22s} {art['shape']:12s} "
            f"{r['compute_s'] * 1e3:8.2f} {r['memory_s'] * 1e3:8.2f} "
            f"{r['collective_s'] * 1e3:8.2f} {r['dominant']:>10s} "
            f"{r['useful_flop_ratio']:7.2f} "
            f"{r['roofline_fraction'] * 100:6.1f}%")
    return "\n".join(out)


def run(quick: bool = True) -> List[dict]:
    rows = []
    for art in load_cells("single", "lci_dedicated"):
        if art.get("status") != "ok":
            continue
        r = art["roofline"]
        rows.append({
            "bench": "roofline",
            "case": f"{art['arch']}/{art['shape']}",
            "us_per_call": r["bound_s"] * 1e6,
            "derived": (f"{r['dominant']}-bound "
                        f"{r['roofline_fraction'] * 100:.0f}% "
                        f"useful={r['useful_flop_ratio']:.2f}"),
        })
    return rows


if __name__ == "__main__":
    print(table())
