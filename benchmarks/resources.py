"""Fig 5 analogue — throughput of the individual LCI resources.

Paper: "All threads perform 100k of key resource methods that are used
in the communication critical path (a pair of completion queue push/pop,
matching engine inserts, or packet pool get/put)."  Host variants measure
the Python data structures (relative scaling across lane counts); the
functional (jit) variants measure the in-graph structures the jitted
programs actually use.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper import PAPER
from repro.core import (CompletionQueue, HostMatchingEngine, HostPacketPool,
                        MatchKind, done, encode_key, init_pool, init_ring,
                        init_table, insert_batch, make_key, pool_get,
                        pool_put, ring_pop, ring_push)


def _host_cq(iters: int, lanes: int) -> float:
    cqs = [CompletionQueue() for _ in range(lanes)]
    t0 = time.perf_counter()
    for i in range(iters):
        cq = cqs[i % lanes]
        cq.signal(done(i))
        cq.pop()
    return iters / (time.perf_counter() - t0)


def _host_matching(iters: int, lanes: int) -> float:
    mes = [HostMatchingEngine() for _ in range(lanes)]
    t0 = time.perf_counter()
    for i in range(iters):
        me = mes[i % lanes]
        kind = MatchKind.SEND if i % 2 else MatchKind.RECV
        me.insert(make_key(i % 7, i % 13), kind, i)
    return iters / (time.perf_counter() - t0)


def _host_pool(iters: int, lanes: int) -> float:
    pool = HostPacketPool(n_lanes=lanes, packets_per_lane=32)
    t0 = time.perf_counter()
    for i in range(iters):
        lane = i % lanes
        pid, st = pool.get(lane)
        if st.is_done():
            pool.put(lane, pid)
    return iters / (time.perf_counter() - t0)


def _functional_ring(iters: int) -> float:
    ring = init_ring(cap=1024, width=2)

    @jax.jit
    def pushpop(r, i):
        r, _ = ring_push(r, jnp.stack([i, i + 1]))
        r, rec, _ = ring_pop(r)
        return r, rec

    ring, _ = pushpop(ring, jnp.int32(0))          # compile
    t0 = time.perf_counter()
    for i in range(iters):
        ring, _ = pushpop(ring, jnp.int32(i))
    jax.block_until_ready(ring.buf)
    return iters / (time.perf_counter() - t0)


def _functional_matching(iters: int) -> float:
    table = init_table(n_buckets=4096, bucket_cap=4)
    n = 256
    keys = encode_key(jnp.arange(n) % 7, jnp.arange(n) % 13)
    kinds = (jnp.arange(n) % 2 + 1).astype(jnp.int32)
    vals = jnp.arange(n, dtype=jnp.int32)
    batched = jax.jit(insert_batch)
    table, _, _ = batched(table, keys, kinds, vals)   # compile
    t0 = time.perf_counter()
    reps = max(iters // n, 1)
    for _ in range(reps):
        table, _, _ = batched(table, keys, kinds, vals)
    jax.block_until_ready(table.keys)
    return reps * n / (time.perf_counter() - t0)


def run(quick: bool = True) -> List[dict]:
    iters = PAPER.resource_iters // (5 if quick else 1)
    lanes_list = (1, 16) if quick else PAPER.resource_lanes
    rows = []
    for lanes in lanes_list:
        for name, fn in (("cq_pushpop", _host_cq),
                         ("matching_insert", _host_matching),
                         ("pool_getput", _host_pool)):
            rate = fn(iters, lanes)
            rows.append({"bench": "resources",
                         "case": f"{name}/lanes={lanes}",
                         "us_per_call": 1e6 / rate,
                         "derived": f"{rate / 1e6:.2f} Mops"})
    rows.append({"bench": "resources", "case": "functional_ring/jit",
                 "us_per_call": 1e6 / _functional_ring(iters),
                 "derived": "in-graph CQ"})
    rate = _functional_matching(iters)
    rows.append({"bench": "resources", "case": "functional_matching/jit",
                 "us_per_call": 1e6 / rate,
                 "derived": f"{rate / 1e6:.2f} Mops (batched)"})
    return rows
