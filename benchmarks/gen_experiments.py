"""Generate the data-driven sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python benchmarks/gen_experiments.py > EXPERIMENTS_tables.md

Emits: §Dry-run summary (both meshes), §Roofline full table (single-pod
baselines), and the variant rows for §Perf.
"""
from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh, mode_suffix="lci_dedicated"):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if p.endswith(".ops.json"):
            continue
        a = json.load(open(p))
        parts = os.path.basename(p)[:-5].split("__")
        if len(parts) != 4:
            continue
        arch, shape, m, mode = parts
        if m == mesh and mode == mode_suffix:
            out[(arch, shape)] = a
    return out


def dryrun_section():
    print("### §Dry-run\n")
    for mesh, chips in (("single", 256), ("multi", 512)):
        cells = load(mesh)
        ok = [a for a in cells.values() if a.get("status") == "ok"]
        sk = [a for a in cells.values() if a.get("status") == "skipped"]
        print(f"**{mesh}-pod mesh ({chips} chips)**: "
              f"{len(ok)} cells lower+compile OK, {len(sk)} documented "
              f"skips, 0 failures.\n")
    print("Per-cell artifacts (memory_analysis, cost_analysis, HLO "
          "collective table, jaxpr-exact per-device costs): "
          "`benchmarks/artifacts/dryrun/*.json`.\n")
    # memory residency for the heaviest cells.  `argument_size` is the
    # exact sharded at-rest state per device (params + optimizer + cache —
    # backend-independent).  `temp_size` comes from XLA *CPU*
    # BufferAssignment: a loose upper bound (no TPU memory-aware
    # scheduling, no while-loop buffer reuse) — reported for completeness,
    # with the analytic activation estimate that governs the TPU fit.
    cells = load("single")
    print("At-rest + activation residency for the heaviest cells "
          "(16 GB HBM/chip):\n")
    print("| cell | at-rest args GB (exact) | activations GB (analytic) "
          "| fits | CPU-temp GB (upper bd) |")
    print("|---|---|---|---|---|")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    from repro.configs import SHAPES, get_config
    biggest = sorted(
        (a for a in cells.values() if a.get("status") == "ok"),
        key=lambda a: -(a.get("argument_size_in_bytes", 0)))[:8]
    for a in biggest:
        arg = a.get("argument_size_in_bytes", 0) / 1e9
        tmp = a.get("temp_size_in_bytes", 0) / 1e9
        cfg = get_config(a["arch"])
        shape = SHAPES[a["shape"]]
        if shape.kind == "train":
            # remat: residual stream per layer + one layer's working set
            d = cfg.d_model
            tok_loc = shape.seq_len * shape.global_batch / 256
            resid = cfg.n_layers * tok_loc * d * 2 / 1e9
            work = 4 * shape.seq_len * max(shape.global_batch // 16, 1) \
                * d * 2 / 1e9
            act = resid + work
        else:
            act = 1.0                      # decode/prefill working sets
        tot = arg + act
        print(f"| {a['arch']}/{a['shape']} | {arg:.2f} | {act:.2f} | "
              f"{'yes' if tot < 16 else 'NO'} ({tot:.1f}) | {tmp:.1f} |")
    print()


def roofline_section():
    print("### §Roofline — per (arch × shape), single-pod (16,16), "
          "LCI_DEDICATED baseline\n")
    print("Terms per device from the jaxpr-exact cost walker "
          "(scan-trip-count-aware); v5e constants: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s/link ICI.\n")
    print("| arch | shape | compute s | memory s | collective s | "
          "dominant | BSP bound | LCI bound | overlap× | useful | roofl% |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    cells = load("single")
    for (arch, shape), a in sorted(cells.items()):
        if a.get("status") == "skipped":
            print(f"| {arch} | {shape} | — | — | — | *skipped* "
                  f"(full attention @500k) | | | | | |")
            continue
        if a.get("status") != "ok":
            continue
        r = a["roofline"]
        print(f"| {arch} | {shape} | {r['compute_s']:.4f} | "
              f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['dominant']} | {r.get('bsp_bound_s', 0):.4f} | "
              f"{r.get('lci_bound_s', 0):.4f} | "
              f"{r.get('overlap_speedup', 0):.2f} | "
              f"{r['useful_flop_ratio']:.2f} | "
              f"{r['roofline_fraction'] * 100:.0f}% |")
    print()


def variants_section():
    print("### §Perf — variant measurements (hillclimbed cells)\n")
    print("| cell | variant | compute s | memory s | collective s | "
          "LCI bound s | dominant |")
    print("|---|---|---|---|---|---|---|")
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if p.endswith(".ops.json"):
            continue
        base = os.path.basename(p)[:-5]
        parts = base.split("__")
        if len(parts) != 4 or "+" not in parts[3]:
            continue
        a = json.load(open(p))
        if a.get("status") != "ok":
            continue
        r = a["roofline"]
        mode, *variants = parts[3].split("+")
        print(f"| {a['arch']}/{a['shape']} | +{'+'.join(variants)} | "
              f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
              f"{r['collective_s']:.4f} | {r.get('lci_bound_s', 0):.4f} | "
              f"{r['dominant']} |")
    print()


if __name__ == "__main__":
    dryrun_section()
    roofline_section()
    variants_section()
