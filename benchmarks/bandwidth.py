"""Fig 4 analogue — bandwidth vs message size (inject/bufcopy/zerocopy).

Fixed lane count, sizes 16 B .. 1 MiB; reports MB/s through the runtime
and which protocol carried each size (the protocol crossover points are
the paper's §4.3 design made visible).  The endpoint sweep repeats the
largest (zero-copy) size across Endpoint widths 1/2/4 — the Fig-8-style
multi-device scaling curve for bulk transfers.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (LocalCluster, Protocol, post_am_x,
                        select_protocol)
from repro.configs.paper import PAPER

# protocol-threshold attrs for the size sweep (resolved per cluster;
# select_protocol reads the same values back off the effective config)
ATTRS = {"eager_max_bytes": 64, "rdv_threshold": 8 * 1024,
         "packet_bytes": 16 * 1024, "packets_per_lane": 64}


def run(quick: bool = True) -> List[dict]:
    iters = max(PAPER.bw_iters // (5 if quick else 1), 5)
    sizes = PAPER.bw_sizes[::2] if quick else PAPER.bw_sizes
    rows = []
    for size in sizes:
        cl = LocalCluster(2, attrs=ATTRS, fabric_depth=1 << 14)
        r0, r1 = cl[0], cl[1]
        cq = r1.alloc_cq()
        rc = r1.register_rcomp(cq)
        payload = np.random.default_rng(0).integers(
            0, 255, size, dtype=np.uint8)
        t0 = time.perf_counter()
        delivered = 0
        for _ in range(iters):
            st = post_am_x(r0, 1, payload, None, None, rc)()
            while st.is_retry():
                cl.progress_all()
                st = post_am_x(r0, 1, payload, None, None, rc)()
            cl.quiesce()
            while cq.pop().is_done():
                delivered += 1
        dt = time.perf_counter() - t0
        assert delivered == iters
        proto = select_protocol(size, cl.config).value
        mbps = size * iters / dt / 1e6
        rows.append({
            "bench": "bandwidth",
            "case": f"size={size}B({proto})",
            "us_per_call": dt / iters * 1e6,
            "derived": f"{mbps:.1f} MB/s",
        })
    rows.extend(run_endpoint_sweep(sizes[-1], iters))
    return rows


def run_endpoint_sweep(size: int, iters: int) -> List[dict]:
    """Bulk-transfer bandwidth vs endpoint width (multi-device scaling)."""
    rows = []
    payload = np.random.default_rng(0).integers(0, 255, size, dtype=np.uint8)
    for width in (1, 2, 4):
        cl = LocalCluster(2, attrs=ATTRS, fabric_depth=1 << 14)
        eps = cl.alloc_endpoint(n_devices=width, stripe="round_robin",
                                progress="dedicated", name="bw")
        cq = cl[1].alloc_cq()
        rc = cl[1].register_rcomp(cq)
        t0 = time.perf_counter()
        delivered = 0
        for _ in range(iters):
            st = eps[0].post_am(1, payload, remote_comp=rc)
            while st.is_retry():
                cl.progress_all()
                st = eps[0].post_am(1, payload, remote_comp=rc)
            cl.quiesce()
            while cq.pop().is_done():
                delivered += 1
        dt = time.perf_counter() - t0
        assert delivered == iters
        pushes = [d["pushes"] for d in eps[0].counters()["devices"]]
        rows.append({
            "bench": "bandwidth",
            "case": f"endpoint_width={width}/size={size}B",
            "us_per_call": dt / iters * 1e6,
            "derived": f"{size * iters / dt / 1e6:.1f} MB/s "
                       f"pushes={pushes}",
        })
    return rows
