"""Fig 7 analogue — AMT-style task DAG over the runtime (HPX/Octo-Tiger).

A layered stencil DAG (task (l, r) depends on (l-1, r±1) across ranks,
like the octree neighbour exchanges): tasks post their results as active
messages; ready tasks fire from completion handlers.  Two executions:

* BSP      — barrier (full quiesce) between layers: the paper's
  bulk-synchronous baseline;
* LCI async — tasks fire the moment their synchronizer fills (the AMT
  mode the paper accelerates).

Reported: makespan in engine *rounds* (a scheduling-depth proxy that is
independent of host speed) + wall time; async needs strictly fewer rounds
whenever task costs are imbalanced.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (CompletionQueue, LocalCluster,
                        Synchronizer, post_am_x)
from repro.configs.paper import PAPER


def _run(n_ranks: int, n_layers: int, bsp: bool) -> Tuple[int, float]:
    cl = LocalCluster(n_ranks, attrs={"eager_max_bytes": 256},
                      fabric_depth=1 << 14)
    cqs = [cl[r].alloc_cq() for r in range(n_ranks)]
    rcs = [cl[r].register_rcomp(cqs[r]) for r in range(n_ranks)]
    # value[(layer, rank)] arrives via AMs from (layer-1, rank+-1, rank)
    need: Dict[Tuple[int, int], int] = {}
    have: Dict[Tuple[int, int], int] = {}
    fired: set = set()
    payload = np.zeros(64, np.uint8)

    def deps_of(l: int, r: int) -> List[int]:
        return sorted({(r - 1) % n_ranks, r, (r + 1) % n_ranks})

    def fire(l: int, r: int):
        fired.add((l, r))
        if l + 1 >= n_layers:
            return
        for dst in deps_of(l + 1, r):
            # actually: task (l, r) feeds (l+1, dst) for dst neighbours of r
            st = post_am_x(cl[r], dst, payload, None, None,
                           rcs[dst]).tag(l + 1)()
            while st.is_retry():
                cl.progress_all()
                st = post_am_x(cl[r], dst, payload, None, None,
                               rcs[dst]).tag(l + 1)()

    t0 = time.perf_counter()
    for r in range(n_ranks):
        fire(0, r)
    rounds = 0
    total = n_layers * n_ranks
    while len(fired) < total:
        rounds += 1
        cl.progress_all()
        for r in range(n_ranks):
            while True:
                msg = cqs[r].pop()
                if msg.is_retry():
                    break
                l = msg.tag
                have[(l, r)] = have.get((l, r), 0) + 1
                if (l, r) not in fired and \
                        have[(l, r)] >= len(deps_of(l, r)):
                    if not bsp:
                        fire(l, r)           # async: fire immediately
        if bsp:
            # bulk-synchronous: fire only after the whole layer's messages
            # have quiesced (barrier semantics)
            cl.quiesce()
            for r in range(n_ranks):
                while True:
                    msg = cqs[r].pop()
                    if msg.is_retry():
                        break
                    l = msg.tag
                    have[(l, r)] = have.get((l, r), 0) + 1
            for (l, r), n in list(have.items()):
                if (l, r) not in fired and n >= len(deps_of(l, r)):
                    fire(l, r)
        assert rounds < 100 * n_layers, "pipeline stalled"
    return rounds, time.perf_counter() - t0


def run(quick: bool = True) -> List[dict]:
    n_ranks = PAPER.amt_ranks
    n_layers = max(PAPER.amt_tasks // n_ranks // (4 if quick else 1), 8)
    rows = []
    for bsp in (True, False):
        rounds, dt = _run(n_ranks, n_layers, bsp)
        rows.append({
            "bench": "amt_pipeline",
            "case": f"{'bsp' if bsp else 'lci_async'}/"
                    f"{n_ranks}r x {n_layers}l",
            "us_per_call": dt / (n_ranks * n_layers) * 1e6,
            "derived": f"{rounds} engine rounds, {dt:.3f}s",
        })
    return rows
