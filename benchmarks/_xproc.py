"""Cross-process benchmark plumbing shared by the message-rate scripts.

``--fabric shm`` (or ``socket``) turns a benchmark into an SPMD job: the
parent re-execs *itself* under :mod:`repro.launch.spmd` with the same
CLI, each rank-child detects the launcher env, runs its cells against a
:class:`ProcessCluster`, and drops a JSON *fragment* into a directory the
parent owns.  The parent merges the fragments into backend-tagged rows
that sit alongside the in-process ``sim`` rows in the same BENCH
document, so ``compare.py`` can gate them independently (rows are keyed
by ``(case, backend)``).
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
from typing import Dict, List, Sequence

FRAGDIR_ENV = "REPRO_BENCH_FRAGDIR"
ALLOW_DIRTY_ENV = "REPRO_BENCH_ALLOW_DIRTY"


def assert_clean_host() -> Dict:
    """Refuse to produce timing rows on a dirty host.

    An orphaned SPMD rank (launcher SIGKILLed, rank reparented to init)
    spins a full core; a stale ``repro-spmd-*`` session dir on /dev/shm
    pins ring memory.  Either skews every wall-clock number measured
    beside it, so benchmarks call this before their first timed cell and
    abort with the finding list instead of publishing numbers that look
    plausible but aren't.  ``REPRO_BENCH_ALLOW_DIRTY=1`` overrides (for
    hosts where the leftovers are known-idle and someone else's).
    """
    from repro.launch.spmd import hygiene_report
    rep = hygiene_report()
    if rep["clean"] or os.environ.get(ALLOW_DIRTY_ENV) == "1":
        return rep
    lines = [f"  orphaned rank pid={p['pid']} session={p['session']}"
             for p in rep["orphans"]]
    lines += [f"  stale session dir {path}"
              for path in rep["stale_sessions"]]
    raise RuntimeError(
        "refusing to run timed benchmark cells on a dirty host "
        "(leftovers of a dead SPMD job skew wall-clock timing):\n"
        + "\n".join(lines)
        + f"\nkill the orphans / remove the dirs, or set "
          f"{ALLOW_DIRTY_ENV}=1 to run anyway.")


def in_child() -> bool:
    """True when this process is an SPMD rank-child of a benchmark."""
    from repro.launch.spmd import RANK_ENV
    return os.environ.get(RANK_ENV) is not None


# ---------------------------------------------------------------------------
# BENCH telemetry block (DESIGN.md §15): every BENCH_*.json documents the
# run it measured — merged counters and, at timers level, per-stage span
# summaries.  Rank fragments ship raw snapshots; the parent merges them
# here, so SPMD rows aggregate the same way in-process cells do.
# ---------------------------------------------------------------------------

def timers_demo_snapshot(iters: int = 192) -> Dict:
    """A small timers-level cell exercising every instrumented stage
    class (scalar post, burst post, pool bufcopy, matching, progress
    sub-stages, CQ pop) and returning its raw telemetry snapshot.

    Benchmarks run their timed cells at level ``off`` (the overhead gate
    pins that contract), so the committed BENCH documents would carry no
    span summaries at all; this demo cell restores the observability
    payload without taxing the timed rows.  Callers mark the result
    ``spans_source: "demo"``.
    """
    import numpy as np

    from repro.core import CommDesc, CommKind, LocalCluster, post_am

    cl = LocalCluster(2, attrs={"telemetry_level": "timers",
                                "eager_max_bytes": 1,   # bufcopy -> pool
                                "packets_per_lane": 64},
                      fabric_depth=1 << 12)
    r0, r1 = cl[0], cl[1]
    cq = r1.alloc_cq()
    rc = r1.register_rcomp(cq)
    payload = np.zeros(8, np.uint8)
    descs = [CommDesc(CommKind.AM, 1, payload, size=payload.nbytes,
                      remote_comp=rc) for _ in range(4)]
    for i in range(iters):
        if i % 2:
            post_am(r0, 1, payload, remote_comp=rc)
        else:
            r0.post_many(descs)
        r1.progress()
        r0.progress()
        while cq.pop().is_done():
            pass
    cl.quiesce()
    while cq.pop().is_done():
        pass
    return cl.telemetry_snapshot()


def telemetry_block(snapshots: Sequence[Dict],
                    demo_when_off: bool = True) -> Dict:
    """Merge raw per-cell/per-rank snapshots into the BENCH ``telemetry``
    block.  ``spans_source`` says where the stage summaries came from:
    ``"run"`` when the timed cells themselves ran at timers level,
    ``"demo"`` when they ran at ``off`` and the summaries come from
    :func:`timers_demo_snapshot` instead."""
    from repro.core import merge_snapshots, render_block

    block = render_block(merge_snapshots([s for s in snapshots if s]))
    block["spans_source"] = "run"
    if not block["spans"] and demo_when_off:
        demo = render_block(timers_demo_snapshot())
        block["spans"] = demo["spans"]
        block["spans_source"] = "demo"
    return block


def write_fragment(payload: Dict) -> None:
    """Publish this rank's results for the parent (atomic rename)."""
    from repro.launch.spmd import RANK_ENV
    rank = int(os.environ[RANK_ENV])
    frag = os.path.join(os.environ[FRAGDIR_ENV], f"rank{rank}.json")
    tmp = frag + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.rename(tmp, frag)


def launch_self(argv: Sequence[str], fabric: str, ranks: int,
                timeout: float = 300.0) -> List[Dict]:
    """Re-exec the calling script as an N-rank SPMD job and collect the
    per-rank fragments.  Raises on nonzero exit (a rank lost messages,
    leaked, or wedged past the launcher's timeout)."""
    from repro.launch import spmd

    fragdir = tempfile.mkdtemp(prefix="repro-bench-frag-")
    prev = os.environ.get(FRAGDIR_ENV)
    os.environ[FRAGDIR_ENV] = fragdir
    try:
        cmd = [sys.executable, os.path.abspath(sys.argv[0])] + list(argv)
        code = spmd.launch(cmd, ranks, backend=fabric, timeout=timeout)
        if code != 0:
            raise RuntimeError(
                f"cross-process benchmark failed (exit {code}); see the "
                f"rank output above")
        frags = []
        for r in range(ranks):
            path = os.path.join(fragdir, f"rank{r}.json")
            if not os.path.exists(path):
                raise RuntimeError(f"rank {r} exited 0 but wrote no "
                                   f"result fragment")
            with open(path) as f:
                frags.append(json.load(f))
        return frags
    finally:
        if prev is None:
            os.environ.pop(FRAGDIR_ENV, None)
        else:
            os.environ[FRAGDIR_ENV] = prev
        shutil.rmtree(fragdir, ignore_errors=True)
