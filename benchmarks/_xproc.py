"""Cross-process benchmark plumbing shared by the message-rate scripts.

``--fabric shm`` (or ``socket``) turns a benchmark into an SPMD job: the
parent re-execs *itself* under :mod:`repro.launch.spmd` with the same
CLI, each rank-child detects the launcher env, runs its cells against a
:class:`ProcessCluster`, and drops a JSON *fragment* into a directory the
parent owns.  The parent merges the fragments into backend-tagged rows
that sit alongside the in-process ``sim`` rows in the same BENCH
document, so ``compare.py`` can gate them independently (rows are keyed
by ``(case, backend)``).
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
from typing import Dict, List, Sequence

FRAGDIR_ENV = "REPRO_BENCH_FRAGDIR"


def in_child() -> bool:
    """True when this process is an SPMD rank-child of a benchmark."""
    from repro.launch.spmd import RANK_ENV
    return os.environ.get(RANK_ENV) is not None


def write_fragment(payload: Dict) -> None:
    """Publish this rank's results for the parent (atomic rename)."""
    from repro.launch.spmd import RANK_ENV
    rank = int(os.environ[RANK_ENV])
    frag = os.path.join(os.environ[FRAGDIR_ENV], f"rank{rank}.json")
    tmp = frag + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.rename(tmp, frag)


def launch_self(argv: Sequence[str], fabric: str, ranks: int,
                timeout: float = 300.0) -> List[Dict]:
    """Re-exec the calling script as an N-rank SPMD job and collect the
    per-rank fragments.  Raises on nonzero exit (a rank lost messages,
    leaked, or wedged past the launcher's timeout)."""
    from repro.launch import spmd

    fragdir = tempfile.mkdtemp(prefix="repro-bench-frag-")
    prev = os.environ.get(FRAGDIR_ENV)
    os.environ[FRAGDIR_ENV] = fragdir
    try:
        cmd = [sys.executable, os.path.abspath(sys.argv[0])] + list(argv)
        code = spmd.launch(cmd, ranks, backend=fabric, timeout=timeout)
        if code != 0:
            raise RuntimeError(
                f"cross-process benchmark failed (exit {code}); see the "
                f"rank output above")
        frags = []
        for r in range(ranks):
            path = os.path.join(fragdir, f"rank{r}.json")
            if not os.path.exists(path):
                raise RuntimeError(f"rank {r} exited 0 but wrote no "
                                   f"result fragment")
            with open(path) as f:
                frags.append(json.load(f))
        return frags
    finally:
        if prev is None:
            os.environ.pop(FRAGDIR_ENV, None)
        else:
            os.environ[FRAGDIR_ENV] = prev
        shutil.rmtree(fragdir, ignore_errors=True)
