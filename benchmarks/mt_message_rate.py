"""Fig 2/3 multithreaded mode — real-thread message-rate sweep.

The paper's headline experiment: N threads on one runtime, each posting
8-byte active messages with a bounded completion window, all of them
driving progress on a *shared* engine through per-device try-locks (a
thread that fails a try-lock moves on — §4.2.3).  The fabric models wire
latency, so a thread whose window is full genuinely waits on completions;
with T threads those waits overlap, which is the asynchrony the runtime
exists to exploit.

Each thread-count cell also runs its own baseline: T *sequential*
1-thread runs of the same per-thread op count.  The acceptance claim —
progress work is shared, not serialized — is the ``speedup_vs_sequential``
column: the T-thread run must beat the aggregate rate of T back-to-back
single-thread runs.  Correctness is asserted every cell: zero lost
completions (every posted message's completion popped exactly once
through the thread-safe LCQ-backed queues) and a fully replenished
packet pool.

Emits ``BENCH_mt_message_rate.json`` including per-lock contention
telemetry (device progress locks, packet-pool lane locks, backlog locks,
LCQ ticket races).

    python benchmarks/mt_message_rate.py --threads 1 2 4
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
from typing import List

if __package__ in (None, ""):                 # `python benchmarks/...py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (CommDesc, CommKind, LocalCluster,
                        aggregate_lock_stats)


def _xproc():
    """The cross-process plumbing, importable both as a package module
    (benchmarks.run) and as a bare script (python benchmarks/...py)."""
    try:
        from . import _xproc as mod
    except ImportError:
        import _xproc as mod
    return mod

DEFAULT_PER_THREAD = 2000
DEFAULT_WINDOW = 16
DEFAULT_LATENCY = 1e-3          # 1 ms simulated wire
_IDLE_NAP = 5e-5                # first idle nap; doubles per idle sweep
_IDLE_NAP_CAP = 4 * _IDLE_NAP   # spin-then-sleep backoff ceiling


def _run_cell(n_threads: int, per_thread: int, window: int,
              latency: float) -> dict:
    """One measurement: T posters with completion windows on one shared
    runtime, every thread driving progress via try-locks."""
    # preempt every 50 us instead of CPython's 5 ms default: threads
    # genuinely interleave inside progress passes, so the try-lock
    # contention the paper measures actually occurs
    old_switch = sys.getswitchinterval()
    sys.setswitchinterval(5e-5)
    try:
        return _run_cell_inner(n_threads, per_thread, window, latency)
    finally:
        sys.setswitchinterval(old_switch)


def _run_cell_inner(n_threads: int, per_thread: int, window: int,
                    latency: float) -> dict:
    cl = LocalCluster(2, attrs={
        "eager_max_bytes": 1,                     # force bufcopy -> pool
        "packets_per_lane": max(64, 4 * window),
        "n_channels": n_threads,
    }, fabric_depth=1 << 16, link_latency=latency)
    r0, r1 = cl[0], cl[1]
    devs0 = [r0.alloc_device() for _ in range(n_threads)]
    devs1 = [r1.alloc_device() for _ in range(n_threads)]
    # per-thread completion queues (thread-safe: signaled by whichever
    # thread's progress pass delivers the message)
    cqs = [r1.alloc_cq(threadsafe=True) for _ in range(n_threads)]
    rcs = [r1.register_rcomp(cq) for cq in cqs]
    # progress targets: the traffic-bearing devices on both ranks; every
    # thread sweeps them round-robin through try_progress
    targets = [(r0.engine, d) for d in devs0] + \
              [(r1.engine, d) for d in devs1]
    payload = np.zeros(8, np.uint8)
    barrier = threading.Barrier(n_threads + 1)
    errors: List[BaseException] = []

    psize = payload.nbytes

    def poster(tid: int) -> None:
        dev, cq, rc = devs0[tid], cqs[tid], rcs[tid]
        rot, posted, comped, idle = tid, 0, 0, 0
        nap = _IDLE_NAP
        n_targets = len(targets)
        try:
            barrier.wait()
            while comped < per_thread:
                room = min(window - (posted - comped), per_thread - posted)
                if room > 0:
                    # burst posting: the whole window-worth of messages
                    # rides ONE doorbell — one pool get_n, one stacked
                    # payload staging, one fabric push_burst — instead of
                    # `room` scalar posts each paying a pool-lane lock
                    # round-trip (paper §4.3)
                    sts = r0.post_many(
                        [CommDesc(CommKind.AM, 1, payload, size=psize,
                                  remote_comp=rc)
                         for _ in range(room)], device=dev)
                    # acceptance is a prefix (post_many contract): a
                    # clean last status means the whole burst landed
                    if not sts[-1].is_retry():
                        posted += room
                        continue
                    posted += next(i for i, s in enumerate(sts)
                                   if s.is_retry())
                # window full (or pool/fabric retry): drive progress on
                # the next device; a failed try-lock just moves on
                eng, d = targets[rot % n_targets]
                rot += 1
                did = eng.try_progress(d)
                # burst drain: the whole published run comes out in one
                # head-CAS claim (LCQ.pop_many) instead of a CAS per pop
                got = cq.pop_many()
                comped += len(got)
                if got or did:
                    idle = 0
                    nap = _IDLE_NAP
                elif (idle := idle + 1) >= n_targets:
                    # every target idle for a full sweep: genuinely
                    # waiting on the wire — yield with spin-then-sleep
                    # backoff.  (Napping per idle *target* would sleep
                    # n_targets times per sweep and stretch delivery by
                    # the same factor; napping flat-rate keeps every
                    # waiting thread polling at full tilt, which under
                    # the GIL taxes the threads that DO have work.)
                    idle = 0
                    time.sleep(nap)
                    nap = min(nap * 2, _IDLE_NAP_CAP)
        except BaseException as e:            # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=poster, args=(t,), daemon=True,
                                name=f"poster/{t}")
               for t in range(n_threads)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    deadline = time.monotonic() + 120.0
    for t in threads:
        t.join(max(0.0, deadline - time.monotonic()))
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    stuck = [t.name for t in threads if t.is_alive()]
    if stuck:
        raise RuntimeError(f"mt_message_rate wedged (deadlock?): {stuck}")

    total = n_threads * per_thread
    completed = sum(cq.pushes for cq in cqs)
    lost = total - completed
    # snapshot BEFORE quiesce: the gated per-message amortization metric
    # must measure the hot path, not post-run drain bookkeeping
    hot_pool_acqs = sum(lk.acquisitions for lk in r0.packet_pool.locks)
    cl.quiesce()
    leaked = r0.packet_pool.n_packets - r0.packet_pool.free_packets()
    contention = {
        "device_progress_locks": aggregate_lock_stats(
            d.progress_lock for d in r0.devices + r1.devices),
        "pool_lane_locks": aggregate_lock_stats(r0.packet_pool.locks),
        "pool_steal_lock_failures": r0.packet_pool.steal_lock_failures,
        "backlog_locks": aggregate_lock_stats(
            d.backlog.lock for d in r0.devices + r1.devices),
        "lcq_ticket_races": {
            "push": sum(cq.races()["push_races"] for cq in cqs),
            "pop": sum(cq.races()["pop_races"] for cq in cqs),
        },
    }
    return {
        "threads": n_threads,
        "seconds": dt,
        "rate": total / dt,
        "lost": lost,
        "leaked_packets": leaked,
        "hot_pool_acqs": hot_pool_acqs,
        "contention": contention,
        "telemetry": cl.telemetry_snapshot(),
        "resolved_attrs": cl.attrs_echo(),
    }


# ---------------------------------------------------------------------------
# cross-process mode (--fabric shm|socket): N OS-process ranks, T threads
# each, over a real transport backend instead of the in-process sim
# ---------------------------------------------------------------------------

def _run_cell_xproc(ctx, n_threads: int, per_thread: int, window: int,
                    fabric: str) -> dict:
    """One rank's share of a cross-process cell: T posters with
    completion windows on this rank's runtime, posting to the ring
    neighbor over the ``fabric`` backend.  Pacing is symmetric — each
    thread windows on the deliveries arriving from its peer-rank twin —
    so flow control is the transport's back-pressure, not lockstep."""
    from repro.core import ProcessCluster

    cl = ProcessCluster(ctx.n_ranks, ctx.rank,
                        attrs={"n_channels": n_threads},
                        fabric_depth=1 << 16, fabric_backend=fabric,
                        session=os.path.join(ctx.session,
                                             f"cell{n_threads}"))
    rt = cl.runtime
    devs = [rt.alloc_device() for _ in range(n_threads)]
    # symmetric alloc: every rank registers T rcomps in the same order,
    # so thread t's remote_comp index means "peer's cq t" everywhere
    cqs = [rt.alloc_cq(threadsafe=True) for _ in range(n_threads)]
    rcs = [rt.register_rcomp(cq) for cq in cqs]
    peer = (ctx.rank + 1) % ctx.n_ranks
    payload = np.zeros(8, np.uint8)
    start = threading.Barrier(n_threads + 1)
    errors: List[BaseException] = []

    def poster(tid: int) -> None:
        dev, cq, rc = devs[tid], cqs[tid], rcs[tid]
        posted, comped = 0, 0
        nap = _IDLE_NAP
        try:
            start.wait()
            while posted < per_thread or comped < per_thread:
                room = min(window - max(0, posted - comped),
                           per_thread - posted)
                accepted = 0
                if room > 0:
                    sts = rt.post_many(
                        [CommDesc(CommKind.AM, peer, payload,
                                  size=payload.nbytes, remote_comp=rc)
                         for _ in range(room)], device=dev)
                    accepted = sum(1 for s in sts if not s.is_retry())
                    posted += accepted
                rt.engine.try_progress(dev)
                got = cq.pop_many()
                comped += len(got)
                if got or accepted:
                    nap = _IDLE_NAP
                else:
                    time.sleep(nap)     # waiting on the peer process
                    nap = min(nap * 2, _IDLE_NAP_CAP)
        except BaseException as e:            # surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=poster, args=(t,), daemon=True,
                                name=f"xposter/{t}")
               for t in range(n_threads)]
    for t in threads:
        t.start()
    ctx.barrier(timeout=60)                   # ranks aligned, then go
    start.wait()
    t0 = time.perf_counter()
    deadline = time.monotonic() + 120.0
    for t in threads:
        t.join(max(0.0, deadline - time.monotonic()))
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    stuck = [t.name for t in threads if t.is_alive()]
    if stuck:
        raise RuntimeError(f"xproc cell wedged (rank {ctx.rank}): {stuck}")
    ctx.barrier(timeout=60)                   # peer finished receiving too
    total = n_threads * per_thread
    lost = total - sum(cq.pushes for cq in cqs)
    leaked = cl.fabric.in_flight()
    cell = {
        "threads": n_threads,
        "seconds": dt,
        "total": total,
        "lost": int(lost),
        "leaked": int(leaked),
        "telemetry": cl.telemetry_snapshot(),
        "resolved_attrs": cl.attrs_echo(),
    }
    cl.close()
    return cell


def _xproc_child(args) -> int:
    """Rank-child entry: run every thread-count cell, publish a result
    fragment, exit nonzero on any lost/leaked message."""
    from repro.launch.spmd import bootstrap

    ctx = bootstrap()
    cells, echo = [], None
    for n in args.threads:
        cell = _run_cell_xproc(ctx, n, args.iters, args.window,
                               args.fabric)
        echo = cell.pop("resolved_attrs")
        cells.append(cell)
    _xproc().write_fragment({"rank": ctx.rank, "cells": cells,
                             "resolved_attrs": echo})
    ctx.close()
    return 1 if any(c["lost"] or c["leaked"] for c in cells) else 0


def _sweep_xproc(args) -> tuple:
    """Parent side: re-exec self under the SPMD launcher, merge the
    per-rank fragments into backend-tagged rows."""
    frags = _xproc().launch_self(sys.argv[1:], args.fabric, args.ranks,
                                 timeout=args.xproc_timeout)
    rows, snaps = [], []
    for i, n in enumerate(args.threads):
        cells = [f["cells"][i] for f in frags]
        snaps += [c.pop("telemetry", None) for c in cells]
        total = sum(c["total"] for c in cells)
        dt = max(c["seconds"] for c in cells)
        rows.append({
            "bench": "mt_message_rate",
            "case": f"threads={n}/xproc/{args.fabric}",
            "backend": args.fabric,
            "ranks": args.ranks,
            "us_per_call": dt / total * 1e6,
            "derived": f"{total / dt / 1e3:.1f} kmsg/s",
            "threads": n,
            "lost": sum(c["lost"] for c in cells),
            "leaked_packets": sum(c["leaked"] for c in cells),
        })
    return rows, frags[0]["resolved_attrs"], snaps


def sweep(thread_counts, per_thread: int, window: int, latency: float,
          baseline: bool = True) -> tuple:
    rows = []
    echo = None
    snaps = []
    for n in thread_counts:
        cell = _run_cell(n, per_thread, window, latency)
        echo = cell["resolved_attrs"]
        snaps.append(cell["telemetry"])
        total = n * per_thread
        row = {
            "bench": "mt_message_rate",
            "case": f"threads={n}/shared",
            "us_per_call": cell["seconds"] / total * 1e6,
            "derived": f"{cell['rate'] / 1e3:.1f} kmsg/s",
            "threads": n,
            "lost": cell["lost"],
            "leaked_packets": cell["leaked_packets"],
            # the scalar data plane paid 2 pool-lane lock acquisitions per
            # message (one get, one put); burst get_n + batched put_n must
            # amortize that — the acceptance gate asserts >= 4x fewer.
            # Hot-path acquisitions only (snapshotted before quiesce).
            "pool_lock_acqs_per_msg": cell["hot_pool_acqs"] / total,
            "contention": cell["contention"],
        }
        if baseline:
            # T sequential 1-thread runs of the same per-thread op count:
            # the "serialized progress" strawman the paper beats
            t_seq = sum(_run_cell(1, per_thread, window, latency)["seconds"]
                        for _ in range(n))
            row["seq_us_per_call"] = t_seq / total * 1e6
            row["speedup_vs_sequential"] = t_seq / cell["seconds"]
        rows.append(row)
    # one echo block for the sweep (the widest cell's resolved attrs;
    # the per-cell n_channels difference is already the threads field)
    return rows, echo, snaps


def run(quick: bool = True) -> List[dict]:
    """benchmarks.run entry point."""
    counts = (1, 2) if quick else (1, 2, 4, 8)
    per = DEFAULT_PER_THREAD // (8 if quick else 1)
    rows, _, _ = sweep(counts, per, DEFAULT_WINDOW, DEFAULT_LATENCY)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--threads", type=int, nargs="+", default=[1, 2, 4],
                    help="thread counts to sweep")
    ap.add_argument("--iters", type=int, default=DEFAULT_PER_THREAD,
                    help="messages per thread")
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW,
                    help="max outstanding completions per thread")
    ap.add_argument("--latency-us", type=float, default=DEFAULT_LATENCY * 1e6,
                    help="simulated wire latency in microseconds")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the sequential-runs baseline")
    ap.add_argument("--fabric", default="sim",
                    choices=("sim", "shm", "socket"),
                    help="transport backend; non-sim adds a cross-process "
                         "sweep (N OS-process ranks) alongside the sim "
                         "baseline rows")
    ap.add_argument("--ranks", type=int, default=2,
                    help="OS-process ranks for the cross-process sweep")
    ap.add_argument("--xproc-timeout", type=float, default=300.0,
                    help="launcher wall-clock bound for the cross-process "
                         "sweep")
    ap.add_argument("--json", default="BENCH_mt_message_rate.json",
                    help="output JSON path ('' disables)")
    args = ap.parse_args()

    if args.fabric != "sim" and _xproc().in_child():
        sys.exit(_xproc_child(args))

    _xproc().assert_clean_host()     # leftover SPMD jobs skew timing
    rows, resolved_attrs, snaps = sweep(args.threads, args.iters,
                                        args.window, args.latency_us / 1e6,
                                        baseline=not args.no_baseline)
    for r in rows:
        r["backend"] = "sim"
    if args.fabric != "sim":
        xrows, xecho, xsnaps = _sweep_xproc(args)
        rows += xrows
        snaps += xsnaps
        resolved_attrs = {**resolved_attrs, "xproc": xecho}
    for r in rows:
        speed = (f"  speedup={r['speedup_vs_sequential']:.2f}x"
                 if "speedup_vs_sequential" in r else "")
        if "contention" in r:
            locks = r["contention"]["device_progress_locks"]
            speed += f"  lock_contentions={locks['contentions']}"
        print(f"{r['case']:24s} {r['us_per_call']:8.2f} us/msg  "
              f"{r['derived']:>12s}  lost={r['lost']}{speed}")

    # acceptance: zero lost completions, no leaked packets, and the
    # multithreaded runs beat their sequential aggregates (progress work
    # is shared, not serialized)
    assert all(r["lost"] == 0 for r in rows), "lost completions!"
    assert all(r["leaked_packets"] == 0 for r in rows), "leaked packets!"
    # burst plane: >= 4x fewer pool-lane lock acquisitions per message
    # than the scalar plane's 2 (get + put per message)
    for r in rows:
        if "pool_lock_acqs_per_msg" not in r:
            continue                    # cross-process rows ride inject
        assert r["pool_lock_acqs_per_msg"] <= 2.0 / 4, (
            f"threads={r['threads']}: pool lock amortization regressed "
            f"({r['pool_lock_acqs_per_msg']:.3f} acquisitions/msg)")
    for r in rows:
        if r["threads"] > 1 and "speedup_vs_sequential" in r:
            assert r["speedup_vs_sequential"] > 1.0, (
                f"threads={r['threads']}: multithreaded run did not beat "
                f"{r['threads']} sequential runs "
                f"({r['speedup_vs_sequential']:.2f}x)")
    print("zero lost completions, zero leaked packets: OK")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "mt_message_rate",
                       "iters": args.iters,
                       "threads": args.threads,
                       "window": args.window,
                       "latency_us": args.latency_us,
                       "fabric": args.fabric,
                       "ranks": args.ranks if args.fabric != "sim" else 1,
                       "resolved_attrs": resolved_attrs,
                       "telemetry": _xproc().telemetry_block(snaps),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
