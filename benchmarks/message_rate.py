"""Fig 2/3 analogue — message rate vs lane count, per resource mode.

The paper's modes map to (DESIGN.md §2):
  process-based  -> one lane, one device (per-"core" baseline, Fig 2)
  thread/shared  -> N lanes sharing ONE device (Fig 3b/3d)
  thread/dedicated -> N lanes, one device each (Fig 3a/3c)

Metric: uni-directional 8-byte active messages per second through the
full posting+progress path (pool -> fabric -> CQ delivery).  The paper's
headline — dedicated devices scale with lanes while shared serializes —
reproduces here structurally: shared mode funnels every message through
one backlog/CQ/packet-lane set.

The **endpoint sweep** (``--devices N``, Fig-8 analogue) posts the same
traffic through a striped multi-device Endpoint at widths 1..N and
reports the per-device push counters — the evidence that ops really
landed on every device of the bundle.  Results are also written to
``BENCH_message_rate.json`` so later PRs have a perf trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

if __package__ in (None, ""):                 # `python benchmarks/...py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import LocalCluster, post_am_x
from repro.configs.paper import PAPER


def _xproc():
    try:
        from . import _xproc as mod
    except ImportError:
        import _xproc as mod
    return mod


def _run_lanes(n_lanes: int, dedicated: bool, iters: int) -> float:
    cl = LocalCluster(2, attrs={"eager_max_bytes": 64,
                                "packets_per_lane": 64,
                                "n_channels": n_lanes if dedicated else 1},
                      fabric_depth=1 << 16)
    r0, r1 = cl[0], cl[1]
    cq = r1.alloc_cq()
    rc = r1.register_rcomp(cq)
    if dedicated:
        devs = [r0.alloc_device() for _ in range(n_lanes)]
        rdevs = [r1.alloc_device() for _ in range(n_lanes)]
    else:
        devs = [r0.default_device] * n_lanes
        rdevs = [r1.default_device] * n_lanes
    payload = np.zeros(PAPER.msg_rate_size, np.uint8)

    t0 = time.perf_counter()
    sent = 0
    for i in range(iters):
        lane = i % n_lanes
        st = post_am_x(r0, 1, payload, None, None, rc).device(devs[lane])()
        sent += 1
        if i % 64 == 63:                      # periodic progress (all-worker)
            for d in rdevs[:1] if not dedicated else rdevs:
                r1.progress(d)
            while cq.pop().is_done():
                pass
    cl.quiesce()
    while cq.pop().is_done():
        pass
    dt = time.perf_counter() - t0
    return sent / dt


def _run_endpoint(width: int, stripe: str, iters: int,
                  burst: int = 32, wire_bf16: bool = False) -> dict:
    """One endpoint-width cell: post through a striped Endpoint with
    burst doorbells (``post_am_many``), report rate + per-device
    counters.  ``burst=1`` falls back to scalar posting (the pre-batched
    data plane, kept measurable for A/B runs).  ``wire_bf16`` posts
    float32 payloads of the same byte size with the bf16 wire
    compression attr on — the fused copy halves the wire bytes."""
    cl = LocalCluster(2, attrs={"eager_max_bytes": 64,
                                "packets_per_lane": 64,
                                "n_channels": width,
                                "wire_bf16": wire_bf16},
                      fabric_depth=1 << 16)
    eps = cl.alloc_endpoint(n_devices=width, stripe=stripe,
                            progress="dedicated", name="sweep")
    ep0, ep1 = eps
    cq = cl[1].alloc_cq()
    rc = cl[1].register_rcomp(cq)
    payload = (np.zeros(PAPER.msg_rate_size // 4, np.float32) if wire_bf16
               else np.zeros(PAPER.msg_rate_size, np.uint8))
    bufs = [payload] * burst

    t0 = time.perf_counter()
    sent = 0
    while sent < iters:
        if burst > 1:
            k = min(burst, iters - sent)
            sts = ep0.post_am_many(1, bufs[:k], rc)
            # count only accepted posts: a prefix-rejected suffix (pool /
            # fabric back-pressure) is retried on the next loop pass
            sent += sum(1 for s in sts if not s.is_retry())
        else:
            ep0.post_am(1, payload, remote_comp=rc)
            sent += 1
            if sent % 64:
                continue
        ep1.progress()
        while cq.pop().is_done():
            pass
    cl.quiesce()
    while cq.pop().is_done():
        pass
    dt = time.perf_counter() - t0
    counters = ep0.counters()
    return {
        "_tele": cl.telemetry_snapshot(),
        "bench": "message_rate",
        "case": f"endpoint_width={width}/{stripe}"
                + ("/bf16" if wire_bf16 else ""),
        "us_per_call": dt / iters * 1e6,
        "derived": f"{iters / dt / 1e3:.1f} kmsg/s",
        "width": width,
        "stripe": stripe,
        "burst": burst,
        "device_posts": [d["posts"] for d in counters["devices"]],
        "device_pushes": [d["pushes"] for d in counters["devices"]],
        # full resolved-attr provenance for this cell's cluster — perf
        # numbers always carry their configuration (DESIGN.md §12)
        "_echo": cl.attrs_echo(),
    }


# ---------------------------------------------------------------------------
# cross-process mode (--fabric shm|socket): the paper's PROCESS mode for
# real — one OS process per rank over a real transport backend
# ---------------------------------------------------------------------------

def _run_xproc_cell(ctx, iters: int, fabric: str) -> dict:
    """One rank's half of the cross-process cell: post ``iters`` AMs to
    the ring neighbor, drain the deliveries the neighbor posts to us."""
    from repro.core import ProcessCluster, post_am

    cl = ProcessCluster(ctx.n_ranks, ctx.rank, fabric_backend=fabric,
                        session=os.path.join(ctx.session, "cell"),
                        fabric_depth=1 << 16)
    rt = cl.runtime
    cq = rt.alloc_cq()
    rc = rt.register_rcomp(cq)      # symmetric alloc: same index per rank
    peer = (ctx.rank + 1) % ctx.n_ranks
    payload = np.zeros(PAPER.msg_rate_size, np.uint8)
    got = 0
    ctx.barrier(timeout=60)
    t0 = time.perf_counter()
    sent = 0
    while sent < iters:
        st = post_am(rt, peer, payload, remote_comp=rc)
        if not st.is_retry():
            sent += 1
        else:
            rt.progress()
        if sent % 64 == 0:
            rt.progress()
        while cq.pop().is_done():
            got += 1
    deadline = time.monotonic() + 60.0
    while got < iters and time.monotonic() < deadline:
        rt.progress()
        while cq.pop().is_done():
            got += 1
    dt = time.perf_counter() - t0
    ctx.barrier(timeout=60)
    cell = {
        "seconds": dt,
        "total": iters,
        "lost": int(iters - got),
        "leaked": int(cl.fabric.in_flight()),
        "telemetry": cl.telemetry_snapshot(),
        "resolved_attrs": cl.attrs_echo(),
    }
    cl.close()
    return cell


def _xproc_child(args, iters: int) -> int:
    from repro.launch.spmd import bootstrap

    ctx = bootstrap()
    cell = _run_xproc_cell(ctx, iters, args.fabric)
    echo = cell.pop("resolved_attrs")
    _xproc().write_fragment({"rank": ctx.rank, "cell": cell,
                             "resolved_attrs": echo})
    ctx.close()
    return 1 if (cell["lost"] or cell["leaked"]) else 0


def _sweep_xproc(args, iters: int) -> tuple:
    frags = _xproc().launch_self(sys.argv[1:], args.fabric, args.ranks,
                                 timeout=args.xproc_timeout)
    cells = [f["cell"] for f in frags]
    snaps = [c.pop("telemetry", None) for c in cells]
    total = sum(c["total"] for c in cells)
    dt = max(c["seconds"] for c in cells)
    row = {
        "bench": "message_rate",
        "case": f"xproc/{args.fabric}",
        "backend": args.fabric,
        "ranks": args.ranks,
        "us_per_call": dt / total * 1e6,
        "derived": f"{total / dt / 1e3:.1f} kmsg/s",
        "lost": sum(c["lost"] for c in cells),
        "leaked_packets": sum(c["leaked"] for c in cells),
    }
    return [row], frags[0]["resolved_attrs"], snaps


def run(quick: bool = True) -> List[dict]:
    iters = PAPER.msg_rate_iters // (4 if quick else 1)
    rows = []
    lanes = (1, 4, 16) if quick else PAPER.msg_rate_lanes
    for n in lanes:
        for dedicated in (False, True):
            rate = _run_lanes(n, dedicated, iters)
            rows.append({
                "bench": "message_rate",
                "case": f"lanes={n}/"
                        f"{'dedicated' if dedicated else 'shared'}",
                "us_per_call": 1e6 / rate,
                "derived": f"{rate / 1e3:.1f} kmsg/s",
            })
    return rows


def run_endpoint_sweep(max_width: int, iters: int,
                       stripe: str = "round_robin",
                       burst: int = 32, repeats: int = 3) -> List[dict]:
    """Each cell reports its median-of-``repeats`` run.  On a shared
    host the minimum rewards whichever cell got the single luckiest
    scheduler window (different per cell), so cross-cell comparisons
    flip on noise; the median is the typical per-message software cost
    and compares cleanly.  Repeats are the OUTER loop — widths
    interleave so every cell samples the same noise windows."""
    widths = [w for w in (1, 2, 4, 8, 16) if w <= max_width]
    if widths[-1] != max_width:
        widths.append(max_width)
    # widths + one bf16-wire cell at the widest width (satellite of the
    # fused-doorbell PR: the wire_bf16 attr must stay measured, not dead)
    cells = [(w, False) for w in widths] + [(max_width, True)]
    runs: dict = {c: [] for c in cells}
    for _ in range(max(1, repeats)):
        for w, bf16 in cells:
            runs[(w, bf16)].append(
                _run_endpoint(w, stripe, iters, burst, wire_bf16=bf16))
    return [sorted(runs[c], key=lambda r: r["us_per_call"])
            [len(runs[c]) // 2] for c in cells]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=4, choices=(1, 2, 4),
                    help="max endpoint width for the sweep")
    ap.add_argument("--stripe", default="round_robin",
                    choices=("round_robin", "by_peer", "by_size"))
    ap.add_argument("--iters", type=int, default=0,
                    help="messages per cell (0 = paper quick count)")
    ap.add_argument("--burst", type=int, default=32,
                    help="doorbell size for post_am_many (1 = scalar "
                         "posting, the pre-batched data plane)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="runs per cell (interleaved); the median run "
                         "is reported")
    ap.add_argument("--fabric", default="sim",
                    choices=("sim", "shm", "socket"),
                    help="transport backend; non-sim adds a cross-process "
                         "row (N OS-process ranks) alongside the sim rows")
    ap.add_argument("--ranks", type=int, default=2,
                    help="OS-process ranks for the cross-process row")
    ap.add_argument("--xproc-timeout", type=float, default=300.0,
                    help="launcher wall-clock bound for the cross-process "
                         "row")
    ap.add_argument("--json", default="BENCH_message_rate.json",
                    help="output JSON path ('' disables)")
    args = ap.parse_args()
    iters = args.iters or PAPER.msg_rate_iters // 4

    if args.fabric != "sim" and _xproc().in_child():
        sys.exit(_xproc_child(args, iters))

    _xproc().assert_clean_host()     # leftover SPMD jobs skew timing
    rows = run_endpoint_sweep(args.devices, iters, args.stripe, args.burst,
                              args.repeats)
    for r in rows:
        r["backend"] = "sim"
    snaps = [r.pop("_tele", None) for r in rows]
    xproc_extra = []
    if args.fabric != "sim":
        xproc_extra, xecho, xsnaps = _sweep_xproc(args, iters)
        snaps += xsnaps
    # one echo block per document: the widest plain cell's resolved
    # attrs (per-cell differences — n_channels/width, the bf16 cell's
    # wire_bf16 — are already encoded in the row's case name)
    plain = [r for r in rows if not r["case"].endswith("/bf16")]
    resolved_attrs = plain[-1]["_echo"]
    for r in rows:
        r.pop("_echo", None)
        print(f"{r['case']:33s} {r['us_per_call']:8.3f} us/msg  "
              f"{r['derived']:>14s}  pushes/device={r['device_pushes']}")
    for r in xproc_extra:
        print(f"{r['case']:33s} {r['us_per_call']:8.3f} us/msg  "
              f"{r['derived']:>14s}  ranks={r['ranks']} lost={r['lost']} "
              f"leaked={r['leaked_packets']}")
        assert r["lost"] == 0 and r["leaked_packets"] == 0, r
    if xproc_extra:
        rows += xproc_extra
        resolved_attrs = {**resolved_attrs, "xproc": xecho}
    widest = plain[-1]
    if args.stripe == "round_robin":
        # by_peer/by_size legitimately concentrate homogeneous traffic on
        # one device; only round-robin must touch the whole bundle
        assert all(p > 0 for p in widest["device_pushes"]), (
            f"striping failed: {widest['device_pushes']}")
        print(f"striped across all {widest['width']} devices "
              f"({args.stripe}): OK")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "message_rate", "iters": iters,
                       "stripe": args.stripe, "burst": args.burst,
                       "fabric": args.fabric,
                       "ranks": args.ranks if args.fabric != "sim" else 1,
                       "resolved_attrs": resolved_attrs,
                       "telemetry": _xproc().telemetry_block(snaps),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
