"""Fig 2/3 analogue — message rate vs lane count, per resource mode.

The paper's modes map to (DESIGN.md §2):
  process-based  -> one lane, one device (per-"core" baseline, Fig 2)
  thread/shared  -> N lanes sharing ONE device (Fig 3b/3d)
  thread/dedicated -> N lanes, one device each (Fig 3a/3c)

Metric: uni-directional 8-byte active messages per second through the
full posting+progress path (pool -> fabric -> CQ delivery).  The paper's
headline — dedicated devices scale with lanes while shared serializes —
reproduces here structurally: shared mode funnels every message through
one backlog/CQ/packet-lane set.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import CommConfig, LocalCluster, post_am_x
from repro.configs.paper import PAPER


def _run_lanes(n_lanes: int, dedicated: bool, iters: int) -> float:
    cfg = CommConfig(inject_max_bytes=64, packets_per_lane=64,
                     n_channels=n_lanes if dedicated else 1)
    cl = LocalCluster(2, cfg, fabric_depth=1 << 16)
    r0, r1 = cl[0], cl[1]
    cq = r1.alloc_cq()
    rc = r1.register_rcomp(cq)
    if dedicated:
        devs = [r0.alloc_device() for _ in range(n_lanes)]
        rdevs = [r1.alloc_device() for _ in range(n_lanes)]
    else:
        devs = [r0.default_device] * n_lanes
        rdevs = [r1.default_device] * n_lanes
    payload = np.zeros(PAPER.msg_rate_size, np.uint8)

    t0 = time.perf_counter()
    sent = 0
    for i in range(iters):
        lane = i % n_lanes
        st = post_am_x(r0, 1, payload, None, None, rc).device(devs[lane])()
        sent += 1
        if i % 64 == 63:                      # periodic progress (all-worker)
            for d in rdevs[:1] if not dedicated else rdevs:
                r1.progress(d)
            while cq.pop().is_done():
                pass
    cl.quiesce()
    while cq.pop().is_done():
        pass
    dt = time.perf_counter() - t0
    return sent / dt


def run(quick: bool = True) -> List[dict]:
    iters = PAPER.msg_rate_iters // (4 if quick else 1)
    rows = []
    lanes = (1, 4, 16) if quick else PAPER.msg_rate_lanes
    for n in lanes:
        for dedicated in (False, True):
            rate = _run_lanes(n, dedicated, iters)
            rows.append({
                "bench": "message_rate",
                "case": f"lanes={n}/"
                        f"{'dedicated' if dedicated else 'shared'}",
                "us_per_call": 1e6 / rate,
                "derived": f"{rate / 1e3:.1f} kmsg/s",
            })
    return rows
