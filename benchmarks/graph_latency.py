"""Async completion-graph overhead vs the Figure-1 reaction chain.

Three cells, all moving the same N-hop ping-pong between two ranks
(odd hops r0→r1, even hops r1→r0, each hop an 8-byte inject-class
message unless ``--size`` says otherwise):

* ``reaction_chain`` — the Figure-1 baseline: each hop is posted by hand
  the moment the previous hop's completion handler fires, with explicit
  progress in between.  This is the floor: pure posting+progress cost.
* ``async_graph``   — the same chain expressed once as a
  :class:`~repro.core.graph.CompletionGraph` of send/recv *comm nodes*
  (``post_send_x``/``post_recv_x`` OFF builders, endpoint-routed):
  ``graph.start()`` posts the ready ops and the progress engine signals
  node completions.  The delta to ``reaction_chain`` is the per-node
  price of the graph machinery.
* ``host_graph``    — an N-node host-function chain through the same
  executor: graph dispatch overhead with zero communication.

Emits ``BENCH_graph_latency.json`` (same row schema as the other
benchmarks) so later PRs can track the graph tax over time.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

if __package__ in (None, ""):                 # `python benchmarks/...py`
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (LocalCluster, post_recv_x, post_send_x)


def _xproc():
    """Shared benchmark plumbing (hygiene preflight, telemetry block),
    importable as a package module and as a bare script."""
    try:
        from . import _xproc as mod
    except ImportError:
        import _xproc as mod
    return mod

_ATTRS = {"eager_max_bytes": 64, "packets_per_lane": 64}
_DEPTH = 1 << 14


def _cluster(depth: int = _DEPTH) -> LocalCluster:
    return LocalCluster(2, attrs=_ATTRS, fabric_depth=depth)


def _attrs_echo() -> dict:
    """The resolved-attr echo for the benchmark's configuration — run
    through the same chain the clusters use, without building one."""
    from repro.core import attrs as A
    from repro.core.runtime import RUNTIME_ATTRS
    return A.resolve((*RUNTIME_ATTRS, "fabric_depth", "link_latency"),
                     runtime=_ATTRS,
                     overrides={"fabric_depth": _DEPTH}).echo()


def run_reaction_chain(n_hops: int, size: int, snaps=None) -> float:
    """Figure-1 baseline: hop i+1 posted from hop i's completion."""
    cl = _cluster()
    payload = np.zeros(size, np.uint8)
    bufs = [np.zeros(size, np.uint8) for _ in range(n_hops)]
    t0 = time.perf_counter()
    for i in range(n_hops):
        src, dst = (0, 1) if i % 2 == 0 else (1, 0)
        landed = []
        h = cl[dst].alloc_handler(landed.append)
        post_recv_x(cl[dst], src, bufs[i], size, i).local_comp(h)()
        post_send_x(cl[src], dst, payload, size, i)()
        while not landed:                     # explicit progress (§3.2.6)
            cl.progress_all()
    us = (time.perf_counter() - t0) / n_hops * 1e6
    if snaps is not None:
        snaps.append(cl.telemetry_snapshot())
    return us


def run_async_graph(n_hops: int, size: int, use_endpoint: bool = True,
                    snaps=None) -> tuple[float, "object"]:
    """The same chain as ONE completion graph of comm nodes."""
    cl = _cluster()
    eps = cl.alloc_endpoint(n_devices=1, name="graph") if use_endpoint \
        else None
    payload = np.zeros(size, np.uint8)
    bufs = [np.zeros(size, np.uint8) for _ in range(n_hops)]
    g = cl[0].alloc_graph("ping-chain")

    def _ep(b, rank):
        return b.endpoint(eps[rank]) if eps is not None else b

    prev_recv = None
    for i in range(n_hops):
        src, dst = (0, 1) if i % 2 == 0 else (1, 0)
        recv = g.add_comm(_ep(post_recv_x(cl[dst], src, bufs[i], size, i),
                              dst), name=f"recv{i}")
        send_deps = [prev_recv] if prev_recv is not None else []
        g.add_comm(_ep(post_send_x(cl[src], dst, payload, size, i), src),
                   deps=send_deps, name=f"send{i}")
        prev_recv = recv

    t0 = time.perf_counter()
    g.start()
    g.wait()                                  # drives the cluster's progress
    us = (time.perf_counter() - t0) / n_hops * 1e6
    g.assert_partial_order()
    if snaps is not None:
        snaps.append(cl.telemetry_snapshot())
    return us, g


def run_host_graph(n_nodes: int) -> float:
    """Pure graph-executor dispatch cost: an N-node host-fn chain."""
    cl = _cluster()
    g = cl[0].alloc_graph("host-chain")
    prev = ()
    for i in range(n_nodes):
        prev = (g.add_node(lambda *a: i, deps=list(prev), name=f"n{i}"),)
    t0 = time.perf_counter()
    g.execute()
    return (time.perf_counter() - t0) / n_nodes * 1e6


def run(quick: bool = True, n_hops: int = 0, size: int = 8,
        snaps=None) -> List[dict]:
    n_hops = n_hops or (64 if quick else 256)
    rows = []
    host_us = run_host_graph(n_hops)
    rows.append({"bench": "graph_latency", "case": f"host_graph/{n_hops}n",
                 "us_per_call": host_us,
                 "derived": f"{host_us:.2f} us/node dispatch"})
    chain_us = run_reaction_chain(n_hops, size, snaps=snaps)
    rows.append({"bench": "graph_latency",
                 "case": f"reaction_chain/{n_hops}hop/{size}B",
                 "us_per_call": chain_us,
                 "derived": f"{chain_us:.2f} us/hop (Figure-1 baseline)"})
    graph_us, g = run_async_graph(n_hops, size, snaps=snaps)
    rows.append({"bench": "graph_latency",
                 "case": f"async_graph/{n_hops}hop/{size}B",
                 "us_per_call": graph_us,
                 "derived": f"{graph_us:.2f} us/hop "
                            f"({graph_us / max(chain_us, 1e-9):.2f}x chain); "
                            f"{g.counters()['comm_nodes']} comm nodes",
                 "overhead_vs_chain": graph_us - chain_us})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=64,
                    help="hops in the chain (= comm node pairs)")
    ap.add_argument("--size", type=int, default=8,
                    help="payload bytes per hop")
    ap.add_argument("--json", default="BENCH_graph_latency.json",
                    help="output JSON path ('' disables)")
    args = ap.parse_args()

    _xproc().assert_clean_host()     # leftover SPMD jobs skew timing
    snaps: list = []
    rows = run(n_hops=args.nodes, size=args.size, snaps=snaps)
    for r in rows:
        print(f"{r['case']:34s} {r['us_per_call']:9.3f} us  {r['derived']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "graph_latency", "nodes": args.nodes,
                       "size": args.size,
                       "resolved_attrs": _attrs_echo(),
                       "telemetry": _xproc().telemetry_block(snaps),
                       "rows": rows}, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
