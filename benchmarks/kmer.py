"""Fig 6 analogue — k-mer counting strong scaling.

Runs the HipMer-stage mini-app (repro.apps.kmer) over rank counts,
verifies exactness against the oracle, and reports wall time + message
statistics (aggregation flushes = the paper's 8 KB buffer behaviour).
"""
from __future__ import annotations

from typing import List

from repro.apps.kmer import generate_reads, reference_count, run_kmer_count
from repro.configs.paper import PAPER


def run(quick: bool = True) -> List[dict]:
    n_reads = PAPER.kmer_reads // (4 if quick else 1)
    reads = generate_reads(n_reads, PAPER.kmer_read_len, seed=3)
    oracle = reference_count(reads, PAPER.kmer_k)
    rows = []
    ranks_list = (2, 4) if quick else PAPER.kmer_ranks
    for n_ranks in ranks_list:
        counts, stats = run_kmer_count(reads, PAPER.kmer_k, n_ranks,
                                       agg_bytes=PAPER.kmer_agg_bytes)
        # exactness: every k-mer with >= 2 occurrences counted exactly
        # (Bloom false positives may add count-1 k-mers; never miss)
        missing = sum(1 for k in oracle if counts.get(k, 0) != oracle[k])
        assert missing == 0, f"kmer counts wrong for {missing} kmers"
        kmers_total = sum(oracle.values())
        rows.append({
            "bench": "kmer",
            "case": f"ranks={n_ranks}",
            "us_per_call": stats.elapsed_s / max(kmers_total, 1) * 1e6,
            "derived": (f"{stats.elapsed_s:.2f}s, "
                        f"{stats.messages} msgs, "
                        f"{stats.aggregation_flushes} flushes, exact"),
        })
    return rows
