"""Benchmark aggregator — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,case,us_per_call,derived`` CSV rows:

    message_rate  -> paper Fig 2/3 (lanes x shared/dedicated)
    mt_message_rate -> paper Fig 2/3 multithreaded mode (real threads)
    bandwidth     -> paper Fig 4  (size sweep, protocol crossovers)
    resources     -> paper Fig 5  (CQ / matching / packet pool Mops)
    kmer          -> paper Fig 6  (HipMer k-mer stage, strong scaling)
    amt_pipeline  -> paper Fig 7  (AMT DAG: BSP barrier vs LCI async)
    graph_latency -> §3.2.5 async graph tax vs the Figure-1 chain
    chaos         -> DESIGN.md §16 fault-injection cost + rank-death
    serve_traffic -> DESIGN.md §17 continuous-batching open-loop traffic
    roofline      -> EXPERIMENTS.md §Roofline (from dry-run artifacts)
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale iteration counts (slow on CPU)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    quick = not args.full

    from . import (amt_pipeline, bandwidth, chaos, graph_latency, kmer,
                   message_rate, mt_message_rate, resources, roofline,
                   serve_traffic)
    suites = {
        "message_rate": message_rate.run,
        "mt_message_rate": mt_message_rate.run,
        "bandwidth": bandwidth.run,
        "resources": resources.run,
        "kmer": kmer.run,
        "amt_pipeline": amt_pipeline.run,
        "graph_latency": graph_latency.run,
        "chaos": chaos.run,
        "serve_traffic": serve_traffic.run,
        "roofline": roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,case,us_per_call,derived")
    failures = []
    for name, fn in suites.items():
        t0 = time.time()
        try:
            rows = fn(quick=quick)
        except Exception as e:                      # pragma: no cover
            failures.append((name, repr(e)))
            print(f"{name},ERROR,,{e!r}", flush=True)
            continue
        for r in rows:
            print(f"{r['bench']},{r['case']},{r['us_per_call']:.3f},"
                  f"\"{r['derived']}\"", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
