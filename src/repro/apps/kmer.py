"""K-mer counting mini-app (paper §5.3) — the HipMer stage on LCI-X.

Faithful structure: each rank reads its share of the error-prone reads;
every k-mer is statically mapped to an owner rank by hash; k-mers travel
as **active messages with per-destination aggregation buffers** (paper:
8 KB); all ranks serve incoming RPCs and periodically progress the
runtime (the *all-worker* setup).  Two traversals: (1) insert into a
two-layer Bloom filter, (2) exact counts into a hashmap for k-mers seen
at least twice (the Bloom layers drop the single-occurrence — likely
erroneous — k-mers without hashmap space).

``run_kmer_count`` executes on a :class:`LocalCluster` (ranks = the
paper's processes/threads in one address space) and returns the exact
histogram, which tests compare against a direct oracle count.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import Counter, defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (LocalCluster, post_am_x)

BASES = np.frombuffer(b"ACGT", dtype=np.uint8)


def generate_reads(n_reads: int, read_len: int, *, seed: int = 0,
                   error_rate: float = 0.01, genome_len: int = 4096
                   ) -> List[bytes]:
    """Error-prone reads off a synthetic genome (errors -> unique k-mers)."""
    rng = np.random.default_rng(seed)
    genome = BASES[rng.integers(0, 4, genome_len)]
    reads = []
    for _ in range(n_reads):
        start = int(rng.integers(0, genome_len - read_len))
        read = genome[start:start + read_len].copy()
        errs = rng.random(read_len) < error_rate
        read[errs] = BASES[rng.integers(0, 4, int(errs.sum()))]
        reads.append(read.tobytes())
    return reads


def kmers_of(read: bytes, k: int):
    for i in range(len(read) - k + 1):
        yield read[i:i + k]


def owner_of(kmer: bytes, n_ranks: int) -> int:
    return int.from_bytes(hashlib.blake2b(kmer, digest_size=4).digest(),
                          "little") % n_ranks


class BloomPair:
    """Two-layer Bloom filter (paper: filters out count-1 k-mers)."""

    def __init__(self, n_bits: int = 1 << 18, seed: int = 0):
        self.n_bits = n_bits
        self.layer1 = np.zeros(n_bits, bool)
        self.layer2 = np.zeros(n_bits, bool)

    def _idx(self, kmer: bytes) -> Tuple[int, int]:
        h = hashlib.blake2b(kmer, digest_size=8).digest()
        return (int.from_bytes(h[:4], "little") % self.n_bits,
                int.from_bytes(h[4:], "little") % self.n_bits)

    def insert(self, kmer: bytes) -> None:
        i, j = self._idx(kmer)
        if self.layer1[i] and self.layer1[j]:
            self.layer2[i] = self.layer2[j] = True      # second sighting
        else:
            self.layer1[i] = self.layer1[j] = True

    def probably_repeated(self, kmer: bytes) -> bool:
        i, j = self._idx(kmer)
        return bool(self.layer2[i] and self.layer2[j])


@dataclasses.dataclass
class KmerStats:
    n_ranks: int
    elapsed_s: float
    messages: int
    bytes_sent: int
    aggregation_flushes: int


class _RankState:
    def __init__(self, rank: int, n_ranks: int, agg_bytes: int):
        self.rank = rank
        self.bloom = BloomPair(seed=rank)
        self.counts: Counter = Counter()
        self.agg: Dict[int, List[bytes]] = defaultdict(list)
        self.agg_sizes: Dict[int, int] = defaultdict(int)
        self.agg_bytes = agg_bytes
        self.flushes = 0


def run_kmer_count(reads: List[bytes], k: int, n_ranks: int, *,
                   agg_bytes: int = 8 * 1024
                   ) -> Tuple[Counter, KmerStats]:
    """Distributed two-pass k-mer count; returns (histogram, stats)."""
    cl = LocalCluster(n_ranks, attrs={"eager_max_bytes": 256,
                                      "rdv_threshold": 16 * 1024,
                                      "packet_bytes": 32 * 1024})
    states = [_RankState(r, n_ranks, agg_bytes) for r in range(n_ranks)]
    cqs = [cl[r].alloc_cq() for r in range(n_ranks)]
    rcomps = [cl[r].register_rcomp(cqs[r]) for r in range(n_ranks)]
    t0 = time.perf_counter()

    def flush(src: int, dst: int, phase: int):
        st = states[src]
        if not st.agg[dst]:
            return
        payload = b"\0".join(st.agg[dst])
        status = post_am_x(cl[src], dst, np.frombuffer(payload, np.uint8),
                           None, None, rcomps[dst]).tag(phase)()
        while status.is_retry():                     # back-pressure: progress
            cl.progress_all()
            status = post_am_x(cl[src], dst,
                               np.frombuffer(payload, np.uint8),
                               None, None, rcomps[dst]).tag(phase)()
        st.agg[dst].clear()
        st.agg_sizes[dst] = 0
        st.flushes += 1

    def drain(rank: int, phase: int):
        """Serve incoming RPCs (the all-worker setup)."""
        while True:
            msg = cqs[rank].pop()
            if msg.is_retry():
                break
            data = bytes(np.asarray(msg.get_buffer()).tobytes())
            st = states[rank]
            for kmer in data.split(b"\0"):
                if not kmer:
                    continue
                if phase == 1:
                    st.bloom.insert(kmer)
                else:
                    if st.bloom.probably_repeated(kmer):
                        st.counts[kmer] += 1

    def traverse(phase: int):
        share = (len(reads) + n_ranks - 1) // n_ranks
        for r in range(n_ranks):
            st = states[r]
            for read in reads[r * share:(r + 1) * share]:
                for kmer in kmers_of(read, k):
                    dst = owner_of(kmer, n_ranks)
                    st.agg[dst].append(kmer)
                    st.agg_sizes[dst] += len(kmer) + 1
                    if st.agg_sizes[dst] >= st.agg_bytes:
                        flush(r, dst, phase)
                # all-worker: serve + progress while producing
                cl[r].progress()
                drain(r, phase)
        for r in range(n_ranks):
            for dst in range(n_ranks):
                flush(r, dst, phase)
        for _ in range(4):
            cl.progress_all()
            for r in range(n_ranks):
                drain(r, phase)
        cl.quiesce()
        for r in range(n_ranks):
            drain(r, phase)

    traverse(1)                                      # Bloom pass
    traverse(2)                                      # exact-count pass

    total = Counter()
    for st in states:
        total.update(st.counts)
    elapsed = time.perf_counter() - t0
    stats = KmerStats(
        n_ranks=n_ranks, elapsed_s=elapsed,
        messages=sum(cl[r].stats.total_msgs for r in range(n_ranks)),
        bytes_sent=sum(cl[r].stats.total_bytes for r in range(n_ranks)),
        aggregation_flushes=sum(st.flushes for st in states))
    return total, stats


def reference_count(reads: List[bytes], k: int) -> Counter:
    """Oracle: exact counts of k-mers occurring at least twice."""
    c = Counter()
    for read in reads:
        for kmer in kmers_of(read, k):
            c[kmer] += 1
    return Counter({km: n for km, n in c.items() if n >= 2})
