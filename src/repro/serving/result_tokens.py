"""ResultTokens — one decode step's output as a single packed array.

JetStream's observation (SNIPPETS.md §1) is that per-slot result objects
are the wrong shape for a serving engine: the hot loop wants *one* array
holding tokens, validity, and lengths side by side, "because copying a
single array to host is much faster than copying two separate ones" —
and, here, because one contiguous array is what the burst data plane
stages into a fused doorbell with a single stacked copy.

Layout: ``data`` is ``(n_slots, 5)`` int32 with column ranges addressed
by index tuples, so consumers never hard-code offsets::

    tokens_idx  = (0, 1)   token generated for the slot this step
    valid_idx   = (1, 2)   1 when the slot was active this step
    length_idx  = (2, 3)   tokens generated so far (seq + 1)
    rid / done  = cols 3,4 request id, end-of-stream flag

The wire side slices the packed array into uniform 16-byte rows
(``[rid, seq, token, done]`` little-endian int32) — a burst of them is
exactly the uniform eager run the fused-doorbell path packs into one
``PackedBurst``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

#: columns of the packed array
TOKEN_COL, VALID_COL, LENGTH_COL, RID_COL, DONE_COL = range(5)
N_COLS = 5

#: one wire row: [rid, seq, token, done] as int32 -> 16 bytes, uniform
ROW_WORDS = 4
ROW_BYTES = ROW_WORDS * 4


@dataclasses.dataclass
class SlotData:
    """Per-slot view into a :class:`ResultTokens` (JetStream's shape)."""
    tokens: np.ndarray
    valid: np.ndarray
    lengths: np.ndarray


class ResultTokens:
    """The packed per-step result array with named column ranges."""

    def __init__(self, data: np.ndarray,
                 tokens_idx: Tuple[int, int] = (TOKEN_COL, TOKEN_COL + 1),
                 valid_idx: Tuple[int, int] = (VALID_COL, VALID_COL + 1),
                 length_idx: Tuple[int, int] = (LENGTH_COL, LENGTH_COL + 1)):
        data = np.ascontiguousarray(data, np.int32)
        if data.ndim != 2 or data.shape[1] != N_COLS:
            raise ValueError(f"ResultTokens expects (n_slots, {N_COLS}) "
                             f"int32, got {data.shape}")
        self.data = data
        self.tokens_idx = tokens_idx
        self.valid_idx = valid_idx
        self.length_idx = length_idx

    @classmethod
    def pack(cls, slots: List[int], rids: List[int], tokens: List[int],
             lengths: List[int], dones: List[int], n_slots: int
             ) -> "ResultTokens":
        """Build the packed array from the decode step's per-slot results
        (inactive slots stay zero / invalid)."""
        data = np.zeros((n_slots, N_COLS), np.int32)
        for slot, rid, tok, length, is_done in zip(slots, rids, tokens,
                                                   lengths, dones):
            data[slot, TOKEN_COL] = tok
            data[slot, VALID_COL] = 1
            data[slot, LENGTH_COL] = length
            data[slot, RID_COL] = rid
            data[slot, DONE_COL] = is_done
        return cls(data)

    @property
    def n_slots(self) -> int:
        return self.data.shape[0]

    def get_result_at_slot(self, slot: int) -> SlotData:
        row = self.data[slot]
        return SlotData(tokens=row[self.tokens_idx[0]:self.tokens_idx[1]],
                        valid=row[self.valid_idx[0]:self.valid_idx[1]],
                        lengths=row[self.length_idx[0]:self.length_idx[1]])

    def active_slots(self) -> np.ndarray:
        return np.flatnonzero(self.data[:, VALID_COL])

    def wire_rows(self) -> List[Tuple[int, np.ndarray]]:
        """Slice the packed array into per-client uniform wire rows:
        ``[(rid, 16-byte row)]`` for every valid slot, ready for one
        ``post_am_many`` burst (uniform size -> fused doorbell)."""
        out = []
        for slot in self.active_slots():
            row = self.data[slot]
            out.append((int(row[RID_COL]),
                        encode_token_row(int(row[RID_COL]),
                                         int(row[LENGTH_COL]) - 1,
                                         int(row[TOKEN_COL]),
                                         int(row[DONE_COL]))))
        return out


def encode_token_row(rid: int, seq: int, token: int, done: int
                     ) -> np.ndarray:
    """One token message payload: uniform 16 bytes so a burst of them
    rides the fused-doorbell path."""
    return np.array([rid, seq, token, done], np.int32).view(np.uint8)


def decode_token_row(buf) -> Tuple[int, int, int, int]:
    """Inverse of :func:`encode_token_row`: ``(rid, seq, token, done)``."""
    words = np.frombuffer(bytes(buf), np.int32)
    if words.size != ROW_WORDS:
        raise ValueError(f"token row must be {ROW_BYTES} bytes, got "
                         f"{words.size * 4}")
    return int(words[0]), int(words[1]), int(words[2]), int(words[3])
