"""Slot-based continuous batching on the comm core (DESIGN.md §17).

The paper's "new possibilities" scenario made load-bearing: a serving
engine whose *entire* data plane is the LCI runtime.

* **Endpoint isolation** — prompts (large, bursty) ride a ``by_size``
  striped prefill endpoint; token returns (tiny, latency-critical) ride
  a separate decode endpoint, so a decode token never queues behind a
  bulk prompt on the same device stream (paper §3.2.3).
* **CompletionGraph interleaving** — every engine tick is a completion
  graph: per-request prefill-chunk chains (bounded by the
  ``prefill_chunk`` attr) end in an insert node whose first token is a
  *comm node* on the decode endpoint, while the decode step for already
  resident slots runs as an independent chain.  No edges connect the
  chains, so the graph's ready-set execution interleaves prefill with
  decode — a long prompt cannot stall resident streams.
* **Burst delivery** — each decode step packs its tokens into one
  :class:`~repro.serving.result_tokens.ResultTokens` array and posts the
  uniform 16-byte rows through ``post_am_many`` — one doorbell, fused
  into a single ``PackedBurst`` when the run is long enough.
* **Exactly-once drains** — the client's thread-safe result CQ is popped
  by :class:`~repro.serving.scheduler.ResultDrain` workers; rows a full
  CQ or fabric rejected with ``retry`` park per-client **in order** and
  redeliver ahead of new tokens, so a client's stream is never dropped,
  duplicated, or reordered — including under ``chaos_drop`` faults,
  where the reliability plane retransmits underneath.
* **Paged KV attrs** — slot count, page size, total pages, and the
  eviction policy resolve through the four-layer attr chain
  (:data:`~repro.serving.slots.SERVING_ATTRS`) with full ``get_attr``
  introspection, and every stage is a telemetry span
  (``serve.enqueue/prefill/insert/decode/deliver/drain``).

Roles split cleanly across ranks so the same classes run single-process
(:class:`~repro.core.runtime.LocalCluster`, both roles in one address
space) or as an SPMD job (:class:`~repro.core.runtime.ProcessCluster`,
client and server in separate OS processes over shm rings).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import attrs as _attrs
from repro.core.backlog import BacklogQueue
from repro.core.graph import CompletionGraph
from repro.core.post import post_am_x
from repro.core.status import FatalError
from .result_tokens import (ROW_BYTES, ResultTokens, decode_token_row,
                            encode_token_row)
from .scheduler import ResultDrain
from .slots import SERVING_ATTRS, SlotAllocator

#: deterministic remote-completion handles: each role registers exactly
#: one rcomp on its own runtime, first, so both sides can name the
#: peer's handle without an exchange (required for process mode, where
#: the peer's registry is another process's memory)
PROMPT_RC = 0
RESULT_RC = 0

#: a prompt whose max_new field carries this value is the end-of-traffic
#: control message (process-mode shutdown), not a request
EOT_MAX_NEW = -1

_rid_counter = itertools.count(1)


class SyntheticModel:
    """Deterministic stand-in for the model compute: token ``(rid, pos)``
    is a pure function, so the *client* can recompute the exact stream it
    must receive — the exactly-once verification oracle."""

    def __init__(self, seed: int = 0, vocab: int = 32000):
        self.seed = seed
        self.vocab = vocab

    def decode(self, rids, positions) -> np.ndarray:
        r = np.asarray(rids, np.int64)
        p = np.asarray(positions, np.int64)
        mix = r * 1_000_003 + p * 9_176_919 + self.seed * 2_654_435_761
        return (mix % self.vocab).astype(np.int32)

    def prefill(self, rid: int, tokens: np.ndarray) -> int:
        """One prefill chunk's "KV build" — a pure host reduction."""
        return int(np.sum(np.asarray(tokens, np.int64))) & 0x7FFFFFFF

    def expected(self, rid: int, prompt_len: int, n: int) -> np.ndarray:
        """The full token stream request ``rid`` must receive."""
        return self.decode(np.full(n, rid), prompt_len + np.arange(n))


class ServePlane:
    """The serving comm plane: symmetric striped endpoints plus the two
    registered completion queues.

    Allocation is symmetric per rank (device streams match by index), so
    construction works on a :class:`LocalCluster` (both roles local) and
    on each rank of a :class:`ProcessCluster` (only the local role's CQ
    exists).  Each role registers its CQ as the *first* rcomp on its
    runtime, pinning the deterministic handles :data:`PROMPT_RC` /
    :data:`RESULT_RC` both sides rely on.
    """

    def __init__(self, cluster, *, client_rank: int = 0,
                 server_rank: int = 1, n_prefill: int = 2,
                 n_decode: int = 1):
        if client_rank == server_rank:
            raise FatalError("ServePlane: client and server must be "
                             "distinct ranks")
        self.cluster = cluster
        self.client_rank = client_rank
        self.server_rank = server_rank
        self.tele = cluster.tele
        self.prefill: Dict[int, object] = {}
        self.decode: Dict[int, object] = {}
        local = []
        for rt in cluster.local_runtimes():
            local.append(rt.rank)
            self.prefill[rt.rank] = rt.alloc_endpoint(
                n_prefill, "by_size", "dedicated",
                name=f"serve/prefill@{rt.rank}")
            self.decode[rt.rank] = rt.alloc_endpoint(
                n_decode, "round_robin", name=f"serve/decode@{rt.rank}")
        self.prompt_cq = None
        self.result_cq = None
        if server_rank in local:
            srv = cluster[server_rank]
            self.prompt_cq = srv.alloc_cq()
            rc = srv.register_rcomp(self.prompt_cq)
            if rc != PROMPT_RC:
                raise FatalError(
                    f"ServePlane must register the prompt CQ first on the "
                    f"server runtime (got rcomp handle {rc}); allocate the "
                    f"plane before other rcomp registrations")
        if client_rank in local:
            cli = cluster[client_rank]
            self.result_cq = cli.alloc_cq(threadsafe=True)
            rc = cli.register_rcomp(self.result_cq)
            if rc != RESULT_RC:
                raise FatalError(
                    f"ServePlane must register the result CQ first on the "
                    f"client runtime (got rcomp handle {rc}); allocate the "
                    f"plane before other rcomp registrations")

    def pump(self, rounds: int = 1) -> int:
        """Drive progress on every local endpoint device."""
        n = 0
        for eps in (self.prefill, self.decode):
            for ep in eps.values():
                n += ep.progress(rounds)
        return n

    def counters(self) -> dict:
        return {
            "prefill": [ep.counters() for ep in self.prefill.values()],
            "decode": [ep.counters() for ep in self.decode.values()],
        }


class _ServeReq:
    """Server-side request state (one resident slot's stream)."""

    __slots__ = ("rid", "prompt", "max_new", "generated")

    def __init__(self, rid: int, prompt: np.ndarray, max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.generated = 0                 # == next token's seq number

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


class ContinuousBatcher(_attrs.AttrResource):
    """The server role: admit → prefill/insert → decode → burst-deliver.

    Every tunable (``kv_*``, ``prefill_chunk``, ``max_batch``) resolves
    through the four-layer attr chain at construction; ``get_attr``
    answers for all of them plus the discovered state (occupancy, active
    slots, parked rows).
    """

    def __init__(self, plane: ServePlane, model, **overrides):
        self.plane = plane
        self.model = model
        self.tele = plane.tele
        cluster = plane.cluster
        resolved = _attrs.resolve(
            SERVING_ATTRS, runtime=getattr(cluster, "_attr_layer", None),
            overrides=overrides)
        self.slots = SlotAllocator(resolved=resolved)
        self.prefill_chunk: int = resolved["prefill_chunk"]
        self.max_batch: int = resolved["max_batch"] or resolved["kv_slots"]
        self.runtime = cluster[plane.server_rank]
        self.decode_ep = plane.decode[plane.server_rank]
        self.active: Dict[int, _ServeReq] = {}       # resident (all states)
        self.decoding: Dict[int, _ServeReq] = {}     # past first token
        self._inserting: List[_ServeReq] = []        # admitted this tick
        self.backlog = BacklogQueue()
        # rows a full CQ / full fabric rejected: parked per client, FIFO,
        # redelivered ahead of that client's new tokens (order survives)
        self._parked: Dict[int, List[np.ndarray]] = {}
        self.eot_seen = False
        self.ticks = 0
        self.arrived = 0
        self.completed = 0
        self.tokens_generated = 0
        self.delivery_retries = 0
        self._init_attrs(resolved)
        self._export_attr("active_requests", lambda: len(self.active))
        self._export_attr("backlog_depth", lambda: len(self.backlog))
        self._export_attr("parked_rows", lambda: sum(
            len(q) for q in self._parked.values()))
        self._export_attr("occupancy", self.slots.occupancy)
        self.tele.attach("serve", self.counters)

    # -- admission -----------------------------------------------------------
    def _admit_now(self, req: _ServeReq) -> bool:
        if len(self.active) >= self.max_batch:
            return False
        total = req.prompt_len + req.max_new
        st = self.slots.admit(req.rid, total)
        if st.is_retry() and self.slots.evict_policy == "preempt_longest":
            victim = self._pick_victim(exclude=req.rid)
            if victim is not None:
                self._preempt(victim)
                st = self.slots.admit(req.rid, total)
        if st.is_retry():
            return False
        self.active[req.rid] = req
        self._inserting.append(req)
        return True

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Largest-footprint resident stream that is safely preemptible:
        already decoding and not back-pressured (a parked stream's pages
        cannot move without risking reorder)."""
        eligible = [r for r in self.decoding
                    if r != exclude and r not in self._parked]
        if not eligible:
            return None
        return max(eligible,
                   key=lambda r: self.slots.tokens_of.get(r, 0))

    def _preempt(self, rid: int) -> None:
        req = self.active.pop(rid)
        self.decoding.pop(rid, None)
        self.slots.release(rid)
        self.slots.preemptions += 1
        # generated-token count survives: on re-admission the stream
        # re-prefills prompt+generated and resumes at seq=generated —
        # recompute-style preemption with zero duplicated tokens
        self.backlog.push(req)

    def _ingest(self) -> None:
        cq = self.plane.prompt_cq
        while True:
            st = cq.pop()
            if st.is_retry():
                return
            with self.tele.span("serve.enqueue"):
                data = np.asarray(st.get_buffer()).view(np.int32)
                rid, max_new = int(data[0]), int(data[1])
                if max_new == EOT_MAX_NEW:
                    self.eot_seen = True
                    continue
                req = _ServeReq(rid, data[2:].copy(), max_new)
                self.arrived += 1
                if not self._admit_now(req):
                    self.backlog.push(req)

    def _readmit_backlog(self) -> None:
        while not self.backlog.empty_flag \
                and len(self.active) < self.max_batch:
            req, st = self.backlog.pop()
            if st.is_retry():
                return
            if not self._admit_now(req):
                self.backlog.push_front(req)
                return

    # -- the tick graph ------------------------------------------------------
    def _make_prefill_fn(self, req: _ServeReq, c0: int, c1: int):
        def fn(*_):
            with self.tele.span("serve.prefill"):
                chunk = req.prompt[c0:min(c1, req.prompt_len)]
                return self.model.prefill(req.rid, chunk)
        return fn

    def _make_insert_fn(self, req: _ServeReq, buf: np.ndarray):
        def fn(*_):
            with self.tele.span("serve.insert"):
                seq = req.generated
                tok = int(self.model.decode(
                    [req.rid], [req.prompt_len + seq])[0])
                req.generated += 1
                self.tokens_generated += 1
                is_done = req.generated >= req.max_new
                buf[:] = encode_token_row(req.rid, seq, tok, int(is_done))
                if is_done:
                    self._finish(req)
            return req.rid
        return fn

    def _make_activate_fn(self, req: _ServeReq):
        def fn(*_):
            if req.rid in self.active and req.generated < req.max_new:
                self.decoding[req.rid] = req
            return req.rid
        return fn

    def _build_graph(self) -> Optional[CompletionGraph]:
        decode_rids = [r for r in self.decoding if r not in self._parked]
        inserting, self._inserting = self._inserting, []
        if not decode_rids and not inserting:
            return None
        g = CompletionGraph(name=f"serve/tick{self.ticks}")
        if decode_rids:
            d = g.add_node(lambda: self._decode_step(decode_rids),
                           name="decode")
            g.add_node(lambda res: self._deliver(res.wire_rows()),
                       deps=(d,), name="deliver")
        for req in inserting:
            # resumed streams re-prefill their generated suffix too
            length = req.prompt_len + req.generated
            deps: Tuple[int, ...] = ()
            for c0 in range(0, max(length, 1), self.prefill_chunk):
                nid = g.add_node(
                    self._make_prefill_fn(req, c0, c0 + self.prefill_chunk),
                    deps=deps, name=f"prefill/{req.rid}/{c0}")
                deps = (nid,)
            buf = np.zeros(ROW_BYTES, np.uint8)
            ins = g.add_node(self._make_insert_fn(req, buf), deps=deps,
                             name=f"insert/{req.rid}")
            # the first token is a comm NODE: posted at readiness on the
            # decode endpoint, completed by the progress engine — this is
            # what interleaves prefill chains with the decode chain
            cm = g.add_comm(
                post_am_x(self.runtime, self.plane.client_rank, buf)
                .remote_comp(RESULT_RC).tag(req.rid)
                .endpoint(self.decode_ep),
                deps=(ins,), name=f"first_tok/{req.rid}")
            g.add_node(self._make_activate_fn(req), deps=(cm,),
                       name=f"activate/{req.rid}")
        return g

    def _decode_step(self, rids: List[int]) -> ResultTokens:
        with self.tele.span("serve.decode"):
            reqs = [self.decoding[r] for r in rids]
            positions = np.array([r.prompt_len + r.generated for r in reqs],
                                 np.int64)
            toks = self.model.decode([r.rid for r in reqs], positions)
            slot_ids = [self.slots.slot_of[r.rid] for r in reqs]
            lengths, dones = [], []
            for req, tok in zip(reqs, toks):
                req.generated += 1
                self.tokens_generated += 1
                lengths.append(req.generated)
                is_done = req.generated >= req.max_new
                dones.append(int(is_done))
                if is_done:
                    self._finish(req)
            return ResultTokens.pack(slot_ids, [r.rid for r in reqs],
                                     [int(t) for t in toks], lengths,
                                     dones, n_slots=self.slots.n_slots)

    def _finish(self, req: _ServeReq) -> None:
        self.slots.release(req.rid)
        self.active.pop(req.rid, None)
        self.decoding.pop(req.rid, None)
        self.completed += 1

    # -- burst delivery ------------------------------------------------------
    def _deliver(self, rows: List[Tuple[int, np.ndarray]]) -> int:
        """Burst-post token rows over the decode endpoint.  Parked rows
        flush first; a client with parked rows gets its new rows parked
        behind them (per-client order is sacred)."""
        with self.tele.span("serve.deliver"):
            burst: List[Tuple[int, np.ndarray]] = [
                (rid, buf) for rid, q in self._parked.items() for buf in q]
            for rid, buf in rows:
                if rid in self._parked:
                    self._parked[rid].append(buf)
                else:
                    burst.append((rid, buf))
            if not burst:
                return 0
            sts = self.decode_ep.post_am_many(
                self.plane.client_rank, [b for _, b in burst], RESULT_RC,
                tags=[r for r, _ in burst])
            parked: Dict[int, List[np.ndarray]] = {}
            accepted = 0
            for (rid, buf), st in zip(burst, sts):
                if st.is_retry() or rid in parked:
                    parked.setdefault(rid, []).append(buf)
                    self.delivery_retries += 1
                else:
                    accepted += 1
            self._parked = parked
            return accepted

    # -- lifecycle -----------------------------------------------------------
    def step(self) -> int:
        """One engine tick; returns requests finished this tick."""
        self.ticks += 1
        self.plane.pump()
        if self._parked:
            self._deliver([])              # retry-rejected rows go first
        self._ingest()
        self._readmit_backlog()
        before = self.completed
        g = self._build_graph()
        if g is not None:
            g.start()
            g.wait(progress=self.plane.pump, max_rounds=200_000)
        return self.completed - before

    @property
    def idle(self) -> bool:
        return (not self.active and self.backlog.empty_flag
                and not self._parked)

    def run_until_idle(self, deadline_s: float = 30.0) -> None:
        """Drain everything resident/backlogged/parked (shutdown path)."""
        import time
        deadline = time.monotonic() + deadline_s
        while not self.idle:
            self.step()
            if time.monotonic() > deadline:
                raise FatalError(
                    f"serving engine failed to drain: active="
                    f"{len(self.active)} backlog={len(self.backlog)} "
                    f"parked={sum(len(q) for q in self._parked.values())}")

    def counters(self) -> dict:
        return {"ticks": self.ticks, "arrived": self.arrived,
                "completed": self.completed,
                "tokens_generated": self.tokens_generated,
                "delivery_retries": self.delivery_retries,
                "preemptions": self.slots.preemptions,
                "admission_rejections": self.slots.rejections,
                "backlog_max_depth": self.backlog.max_depth}


class TokenClient(_attrs.AttrResource):
    """The client role: open-loop submission plus worker-thread drains.

    ``drain_workers`` threads pop the thread-safe result CQ; every popped
    row is timestamped (TTFT / inter-token latency) and kept per worker,
    so :meth:`collect` can assert per-worker FIFO — the LCQ pops of one
    worker must see each client's sequence numbers strictly increasing.
    """

    def __init__(self, plane: ServePlane, model, *, stamp: bool = True,
                 **overrides):
        if plane.result_cq is None:
            raise FatalError("TokenClient needs the client rank local "
                             "(plane.result_cq is remote)")
        self.plane = plane
        self.model = model
        self.tele = plane.tele
        resolved = _attrs.resolve(
            ("drain_workers",),
            runtime=getattr(plane.cluster, "_attr_layer", None),
            overrides=overrides)
        self.n_drain: int = resolved["drain_workers"]
        self.prefill_ep = plane.prefill[plane.client_rank]
        # (t_submit, prompt_len, max_new) per submitted request
        self.records: Dict[int, Tuple[float, int, int]] = {}
        self.submit_retries = 0
        self.drain = ResultDrain(plane.result_cq, self.n_drain,
                                 stamp=stamp, tele=plane.tele).start()
        self._init_attrs(resolved)
        self._export_attr("submitted", lambda: len(self.records))
        self._export_attr("drained", lambda: self.drain.drained)

    def submit(self, prompt: np.ndarray, max_new: int,
               rid: Optional[int] = None, *, t_submit: float = 0.0):
        """Post one prompt over the prefill endpoint.  Returns
        ``(rid, status)``; on retry the caller pumps and resubmits with
        the same ``rid`` (open-loop harnesses bound this)."""
        import time
        rid = next(_rid_counter) if rid is None else rid
        prompt = np.asarray(prompt, np.int32)
        with self.tele.span("serve.enqueue"):
            payload = np.concatenate(
                [np.array([rid, max_new], np.int32), prompt]).view(np.uint8)
            st = self.prefill_ep.post_am(
                self.plane.server_rank, payload, remote_comp=PROMPT_RC,
                tag=rid)
        if st.is_retry():
            self.submit_retries += 1
        elif max_new != EOT_MAX_NEW:       # control messages aren't requests
            self.records[rid] = (t_submit or time.perf_counter(),
                                 len(prompt), max_new)
        return rid, st

    def send_eot(self) -> None:
        """Process-mode shutdown: tell the server traffic has ended."""
        while True:
            _, st = self.submit(np.zeros(1, np.int32), EOT_MAX_NEW, rid=0)
            if not st.is_retry():
                return
            self.plane.pump()

    def pump(self, rounds: int = 1) -> int:
        return self.plane.pump(rounds)

    @property
    def expected_tokens(self) -> int:
        return sum(m for _, _, m in self.records.values())

    def collect(self) -> dict:
        """Stop the drain workers, verify every stream against the
        model oracle, and return the traffic report."""
        self.drain.stop()
        streams = self.drain.worker_results()
        per_rid: Dict[int, List[Tuple[int, int, int, float, int]]] = {}
        out_of_order = unexpected = 0
        for wid, chunk in enumerate(streams):
            last_seq: Dict[int, int] = {}
            for entry in chunk:
                st, t = entry if isinstance(entry, tuple) else (entry, 0.0)
                rid, seq, tok, is_done = decode_token_row(st.get_buffer())
                if rid not in self.records:
                    unexpected += 1
                    continue
                # one worker's pops are FIFO: within a worker, a client's
                # seqs must be strictly increasing (stream never reorders)
                if rid in last_seq and seq <= last_seq[rid]:
                    out_of_order += 1
                last_seq[rid] = seq
                per_rid.setdefault(rid, []).append(
                    (seq, tok, is_done, t, wid))
        lost = duplicated = mismatched = bad_done = completed = 0
        ttfts: List[float] = []
        gaps: List[float] = []
        for rid, (t_sub, prompt_len, max_new) in self.records.items():
            got = sorted(per_rid.get(rid, []))
            seqs = [g[0] for g in got]
            distinct = sorted(set(seqs))
            duplicated += len(seqs) - len(distinct)
            lost += max_new - len(distinct)
            expect = self.model.expected(rid, prompt_len, max_new)
            by_seq = {g[0]: g for g in got}
            for s in distinct:
                if not 0 <= s < max_new or \
                        by_seq[s][1] != int(expect[s]):
                    mismatched += 1
            dones = [g[0] for g in got if g[2]]
            if distinct == list(range(max_new)):
                completed += 1
                if dones != [max_new - 1]:
                    bad_done += 1
                first = min((g[3] for g in got if g[0] == 0),
                            default=0.0)
                if first:
                    ttfts.append(first - t_sub)
                if max_new > 1:
                    times = [min(g[3] for g in got if g[0] == s)
                             for s in range(max_new)]
                    gaps.extend(np.diff(times).tolist())
        return {"submitted": len(self.records),
                "completed": completed, "lost": lost,
                "duplicated": duplicated, "mismatched": mismatched,
                "out_of_order": out_of_order, "bad_done": bad_done,
                "unexpected": unexpected,
                "tokens": sum(len(v) for v in per_rid.values()),
                "submit_retries": self.submit_retries,
                "ttft_s": ttfts, "gap_s": gaps}
