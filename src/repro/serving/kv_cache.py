"""Paged KV-cache allocation — the packet pool applied to serving memory.

The in-graph decode cache (:mod:`repro.serving.engine`) is a dense ring of
slots; *which requests own which slots* is managed host-side by this
allocator, which is literally an LCI packet pool: pages are fixed-size
pre-registered buffers, ``get`` is nonblocking and returns ``retry`` under
exhaustion (the scheduler then parks the request in the backlog queue),
``put`` returns pages on request completion, and per-lane deques with
steal-half keep multi-engine allocation contention-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.packet_pool import HostPacketPool
from repro.core.status import Status, done, retry, ErrorCode


@dataclasses.dataclass
class PageTable:
    """Per-request page list (block table): logical position -> page id."""
    request_id: int
    pages: List[int]
    page_size: int

    @property
    def capacity(self) -> int:
        return len(self.pages) * self.page_size

    def slot_of(self, position: int) -> Tuple[int, int]:
        return self.pages[position // self.page_size], \
            position % self.page_size


class PagedKVAllocator:
    """Allocate cache pages to requests out of a packet pool."""

    def __init__(self, n_pages: int, page_size: int, n_lanes: int = 1):
        per_lane = max(1, n_pages // n_lanes)
        self.pool = HostPacketPool(n_lanes=n_lanes,
                                   packets_per_lane=per_lane,
                                   packet_bytes=0)
        self.page_size = page_size
        self.tables: Dict[int, PageTable] = {}

    def admit(self, request_id: int, prompt_len: int, lane: int = 0
              ) -> Status:
        """Reserve pages for a prompt; all-or-nothing (retry on shortage)."""
        need = -(-prompt_len // self.page_size)
        got: List[int] = []
        for _ in range(need):
            pid, st = self.pool.get(lane)
            if st.is_retry():
                for p in got:                       # roll back
                    self.pool.put(lane, p)
                return retry(ErrorCode.RETRY_NOSLOT)
            got.append(pid)
        self.tables[request_id] = PageTable(request_id, got, self.page_size)
        return done(got)

    def extend(self, request_id: int, new_len: int, lane: int = 0
               ) -> Status:
        """Grow a request's table to cover ``new_len`` positions."""
        table = self.tables[request_id]
        while table.capacity < new_len:
            pid, st = self.pool.get(lane)
            if st.is_retry():
                return retry(ErrorCode.RETRY_NOSLOT)
            table.pages.append(pid)
        return done()

    def release(self, request_id: int, lane: int = 0) -> None:
        table = self.tables.pop(request_id, None)
        if table:
            for p in table.pages:
                self.pool.put(lane, p)

    @property
    def free_pages(self) -> int:
        return self.pool.free_packets()
