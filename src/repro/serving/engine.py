"""Serving engine: prefill and single-token decode for every arch family.

Cache layout (global view; local view divides by the mesh):

    k/v      (L, S, B, n_kv, dh)     seq-sharded over ``model`` (and over
                                     ``data`` too when B == 1: long-context
                                     flash-decode over the joint axis)
    ssm_state (L, B, H, N, P)        heads over ``model``, batch over ``data``
    conv_tail (L, K-1, B, d_inner)   channels with the heads
    cross_k/v (L, T, B, n_kv, dh)    (enc-dec / VLM) precomputed memory KV

Decode dataflow per layer (the LCI reading: every KV shard is a *channel*;
partial attention results are joined by a synchronizer — implemented as
the flash-decode max/sum-exp psum combine):

    x (b, d) replicated over model
      -> q/k/v local head shards   (tiny matmuls)
      -> all-gather q,kv over model (bytes ~ b·h·dh: inject-protocol small)
      -> cache write at ``pos`` on the owning seq shard
      -> decode_attention against the LOCAL seq shard (all heads)
      -> combine partials (psum/pmax over the KV-sharding axes)
      -> out-projection row shard + psum

Weights keep their at-rest layout: TP over ``model``; the FSDP dim over
``data`` is gathered per layer exactly like training ("FSDP-serving") —
HBM-bound deployments trade ICI for memory.

**2D-TP serving** (``tp2d=True``, the §Perf hillclimb result): weights are
*stationary* in their 2-D (data × model) shards; instead of gathering a
weight the engine slices the (tiny) activation along the contraction dim
per data rank and psums partial products — per-matmul wire bytes drop
from O(weight) to O(activation), turning decode from collective-bound
into its natural memory-bound regime.  MoE expert weights keep the gather
path (dispatch already owns the a2a); everything else goes through
:func:`_wmul`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size

from repro.distributed.comm import Comm, _axes, local_comm
from repro.models.attention import (combine_decode_partials, decode_attention)
from repro.models.blocks import TPPlan, layer_window, tp_plan
from repro.models.common import ModelConfig, shard_decisions
from repro.models.layers import (apply_norm, apply_rope, greedy_sample,
                                 lm_head_logits, mlp_activation, rms_norm)
from repro.models.moe import moe_block
from repro.models.ssm import ssd_decode_step
from repro.models import lm as lm_mod


# ---------------------------------------------------------------------------
# cache container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeCache:
    k: Optional[jax.Array] = None            # (L, S_loc, b, n_kv, dh)
    v: Optional[jax.Array] = None
    ssm_state: Optional[jax.Array] = None    # (L, b, H_loc, N, P)
    conv_tail: Optional[jax.Array] = None    # (L, K-1, b, di_loc)
    cross_k: Optional[jax.Array] = None      # (L, T, b, n_kv, dh)
    cross_v: Optional[jax.Array] = None
    length: Optional[jax.Array] = None       # () int32 — #valid positions


jax.tree_util.register_pytree_node(
    DecodeCache,
    lambda c: ((c.k, c.v, c.ssm_state, c.conv_tail, c.cross_k, c.cross_v,
                c.length), None),
    lambda _, xs: DecodeCache(*xs))


def _has_attn(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm"


def _has_ssm(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


def _n_cross(cfg: ModelConfig) -> int:
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    if cfg.is_encdec:
        return cfg.n_layers
    return 0


def init_cache(cfg: ModelConfig, seq_len: int, batch: int, *,
               kv_shards: int = 1, data_shards: int = 1,
               n_memory: int = 0) -> DecodeCache:
    """GLOBAL-shape cache (callers shard via :func:`cache_pspecs`)."""
    L = cfg.n_layers - _n_cross(cfg) if cfg.family == "vlm" else cfg.n_layers
    c = DecodeCache(length=jnp.zeros((), jnp.int32))
    if _has_attn(cfg):
        dh = cfg.resolved_head_dim
        shape = (L, seq_len, batch, cfg.n_kv_heads, dh)
        c.k = jnp.zeros(shape, cfg.dtype)
        c.v = jnp.zeros(shape, cfg.dtype)
    if _has_ssm(cfg):
        c.ssm_state = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
             cfg.ssm_headdim), jnp.float32)
        c.conv_tail = jnp.zeros(
            (cfg.n_layers, cfg.ssm_conv_kernel - 1, batch, cfg.ssm_d_inner),
            cfg.dtype)
    nx = _n_cross(cfg)
    if nx and n_memory:
        xshape = (nx, n_memory, batch, cfg.n_kv_heads,
                  cfg.resolved_head_dim)
        c.cross_k = jnp.zeros(xshape, cfg.dtype)
        c.cross_v = jnp.zeros(xshape, cfg.dtype)
    return c


def cache_pspecs(cfg: ModelConfig, *, batch: int, model_axis="model",
                 data_axis="data", tp2d: bool = False):
    """PartitionSpecs for the cache: seq over model (+data when B==1 or
    under 2D-TP serving, where the batch is replicated over data and the
    data axis becomes extra sequence parallelism for the KV)."""
    from jax.sharding import PartitionSpec as P
    daxes = _axes(data_axis)
    joint = batch == 1
    seq_axes = ((model_axis,) + daxes) if joint else (model_axis,)
    batch_spec = None if joint else daxes
    dec = shard_decisions(cfg)
    ssm_head = model_axis if dec["ssm"] else None
    return DecodeCache(
        k=P(None, seq_axes, batch_spec, None, None) if _has_attn(cfg) else None,
        v=P(None, seq_axes, batch_spec, None, None) if _has_attn(cfg) else None,
        ssm_state=(P(None, batch_spec, ssm_head, None, None)
                   if _has_ssm(cfg) else None),
        conv_tail=(P(None, None, batch_spec, ssm_head)
                   if _has_ssm(cfg) else None),
        cross_k=(P(None, None, batch_spec, None, None) if _n_cross(cfg)
                 else None),
        cross_v=(P(None, None, batch_spec, None, None) if _n_cross(cfg)
                 else None),
        length=P(),
    )


# ---------------------------------------------------------------------------
# decode helpers
# ---------------------------------------------------------------------------

def _embed_flat(tokens: jax.Array, emb: jax.Array, comm: Comm, *,
                scale: bool, tp2d: bool = False) -> jax.Array:
    """tokens (b,) replicated over model -> (b, d) via vocab-shard psum.
    tp2d: emb columns stay data-sharded; reassemble with a tiny ag."""
    v_local, d_loc = emb.shape
    rank = comm.model_index()
    local = tokens - rank * v_local
    valid = (local >= 0) & (local < v_local)
    rows = jnp.take(emb, jnp.clip(local, 0, v_local - 1), axis=0)
    rows = jnp.where(valid[:, None], rows, 0).astype(jnp.float32)
    out = comm.psum_model(rows)
    if tp2d:
        out = comm.ag_data(out, axis=1)
    if scale:
        out = out * jnp.sqrt(jnp.float32(out.shape[-1]))
    return out.astype(emb.dtype)


def _wmul(x, w, *, fsdp_axis: int, comm: Comm, tp2d: bool) -> jax.Array:
    """``x @ w`` with w's FSDP dim either gathered (classic) or stationary.

    tp2d & fsdp_axis == 0 (contraction dim data-sharded): slice the
    activation's last dim to this data rank's rows, partial product, psum
    over data — wire bytes O(activation), not O(weight).
    tp2d & fsdp_axis == 1 (output dim data-sharded): local product, then
    all-gather the (tiny) output columns over data.
    """
    if not tp2d or not comm.fsdp:
        # tp2d presumes data-sharded weights; with fsdp off the weight is
        # already full — plain local product
        return jnp.tensordot(x, comm.weight(w, fsdp_axis=fsdp_axis),
                             axes=1)
    if fsdp_axis == 0:
        k_l = w.shape[0]
        start = comm.data_index() * k_l
        xs = jax.lax.dynamic_slice_in_dim(x, start, k_l, axis=x.ndim - 1)
        return comm.psum_data(jnp.tensordot(xs, w, axes=1))
    y = jnp.tensordot(x, w, axes=1)
    return comm.ag_data(y, axis=y.ndim - 1)


def _row_parallel_out(x_loc, w, *, comm: Comm, tp2d: bool,
                      shard_model: bool) -> jax.Array:
    """Row-parallel exit (wo / w_out): model psum + (tp2d) data column
    gather, in the cheap order (reduce the narrow shard first)."""
    if not tp2d or not comm.fsdp:
        w_full = comm.weight(w, fsdp_axis=1)
        y = jnp.tensordot(x_loc, w_full, axes=1)
        return comm.psum_model(y) if shard_model else y
    part = jnp.tensordot(x_loc, w, axes=1)        # (..., d/dp)
    if shard_model:
        part = comm.psum_model(part)
    return comm.ag_data(part, axis=part.ndim - 1)


def _kv_axes(comm: Comm, *, joint: bool):
    """Axes the KV seq dim is sharded over (model [+ data for B==1])."""
    axes = list(_axes(comm.model_axis))
    if joint:
        axes = list(_axes(comm.data_axis)) + axes
    return tuple(axes)


def _axes_index(comm: Comm, axes) -> jax.Array:
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def _axes_size(comm: Comm, axes) -> int:
    import math
    return math.prod([axis_size(a) for a in axes] or [1])


def _psum_axes(x, axes):
    for a in axes:
        x = jax.lax.psum(x, a)
    return x


def _pmax_axes(x, axes):
    for a in axes:
        x = jax.lax.pmax(x, a)
    return x


def _decode_attn_layer(x, lp, cfg, comm: Comm, plan: TPPlan, k_cache,
                       v_cache, pos, window, *, joint_kv: bool,
                       prefix: str = "", memory_kv=None,
                       tp2d: bool = False, defer_out: bool = False):
    """One attention layer for a single token.

    x (b, d) replicated over model; k/v_cache (S_loc, b, nkv, dh) local seq
    shard.  Returns (out (b, d), k_cache', v_cache').
    """
    dh = cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads

    # local projections, then tiny gathers to full heads
    q = _wmul(x, lp[prefix + "wq"], fsdp_axis=0, comm=comm, tp2d=tp2d)
    if plan.shard_heads:
        q = comm.ag_seq(q.T, axis=0).T             # (b, nq*dh)
    q = q.reshape(-1, nq, dh)

    is_cross = memory_kv is not None
    if is_cross:
        k_new = v_new = None
    else:
        k_new = _wmul(x, lp[prefix + "wk"], fsdp_axis=0, comm=comm,
                      tp2d=tp2d)
        v_new = _wmul(x, lp[prefix + "wv"], fsdp_axis=0, comm=comm,
                      tp2d=tp2d)
        if plan.shard_kv:
            k_new = comm.ag_seq(k_new.T, axis=0).T
            v_new = comm.ag_seq(v_new.T, axis=0).T
        k_new = k_new.reshape(-1, nkv, dh)
        v_new = v_new.reshape(-1, nkv, dh)

    if cfg.qk_norm:
        q = rms_norm(q, lp[prefix + "q_norm"])
        if not is_cross:
            k_new = rms_norm(k_new, lp[prefix + "k_norm"])
    # tp2d §Perf iteration 2: x/q are batch-replicated over data (the
    # weight-stationary layout), but the attention inner loop is cheapest
    # batch-SHARDED: slice this data rank's batch rows, attend against the
    # classic (seq/model, batch/data) cache, combine over model only, and
    # reassemble (b, d) once after the out-projection.
    b_full = x.shape[0]
    dp = comm.dp
    batch_sharded = tp2d and not joint_kv and dp > 1 and b_full % dp == 0
    if batch_sharded:
        b_l = b_full // dp
        bstart = comm.data_index() * b_l
        q = jax.lax.dynamic_slice_in_dim(q, bstart, b_l, axis=0)
        if not is_cross:
            k_new = jax.lax.dynamic_slice_in_dim(k_new, bstart, b_l, axis=0)
            v_new = jax.lax.dynamic_slice_in_dim(v_new, bstart, b_l, axis=0)
    if not is_cross:
        posv = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q[None], posv, cfg.rope_theta)[0]
        k_new = apply_rope(k_new[None], posv, cfg.rope_theta)[0]

        # cache write on the owning seq shard
        axes = _kv_axes(comm, joint=joint_kv and not batch_sharded)
        if batch_sharded:
            axes = _kv_axes(comm, joint=False)
        shard_len = k_cache.shape[0]
        my_idx = _axes_index(comm, axes)
        my_start = my_idx * shard_len
        rel = pos - my_start
        owns = (rel >= 0) & (rel < shard_len)
        rel_c = jnp.clip(rel, 0, shard_len - 1)
        k_cache = k_cache.at[rel_c].set(
            jnp.where(owns, k_new.astype(k_cache.dtype), k_cache[rel_c]))
        v_cache = v_cache.at[rel_c].set(
            jnp.where(owns, v_new.astype(v_cache.dtype), v_cache[rel_c]))
        num, m, l = decode_attention(
            q, k_cache, v_cache, valid_len=pos + 1, kv_offset=my_start,
            window=window, q_pos=pos)
        m_g = _pmax_axes(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = _psum_axes(l * corr, axes)
        num_g = _psum_axes(num * corr[..., None], axes)
        attn = (num_g / jnp.maximum(l_g, 1e-37)[..., None])
    else:
        mk, mv = memory_kv                        # (T, b, nkv, dh) local full
        num, m, l = decode_attention(q, mk, mv, valid_len=None)
        attn = num / jnp.maximum(l, 1e-37)[..., None]

    attn = attn.reshape(-1, nq * dh).astype(x.dtype)
    if batch_sharded:
        # rejoin the batch rows BEFORE the out-projection (bf16, one
        # ~b·h·dh stream); the stationary out-proj then produces complete
        # rows — reassembling after would leave diagonal blocks (rank r
        # holds rows r x wo-columns r and nobody computes the rest)
        attn = comm.ag_data(attn, axis=0)             # (b, nq*dh)
    if plan.shard_heads:
        nq_l = plan.q_local(cfg)
        start = comm.model_index() * (nq_l * dh)
        attn_loc = jax.lax.dynamic_slice_in_dim(attn, start, nq_l * dh,
                                                axis=1)
        if defer_out:
            return (jnp.tensordot(attn_loc, lp[prefix + "wo"], axes=1),
                    k_cache, v_cache)
        out = _row_parallel_out(attn_loc, lp[prefix + "wo"], comm=comm,
                                tp2d=tp2d, shard_model=True)
    else:
        if defer_out:
            return (jnp.tensordot(attn, lp[prefix + "wo"], axes=1),
                    k_cache, v_cache)
        out = _row_parallel_out(attn, lp[prefix + "wo"], comm=comm,
                                tp2d=tp2d, shard_model=False)
    return out, k_cache, v_cache


def _decode_mlp(x, lp, cfg, comm: Comm, prefix: str = "",
                tp2d: bool = False, defer_out: bool = False) -> jax.Array:
    if cfg.mlp in ("swiglu", "geglu"):
        h = jnp.concatenate(
            [_wmul(x, lp[prefix + "w_gate"], fsdp_axis=0, comm=comm,
                   tp2d=tp2d),
             _wmul(x, lp[prefix + "w_up"], fsdp_axis=0, comm=comm,
                   tp2d=tp2d)], axis=-1)
    else:
        h = _wmul(x, lp[prefix + "w_in"], fsdp_axis=0, comm=comm,
                  tp2d=tp2d)
    h = mlp_activation(cfg.mlp, h)
    if defer_out:
        return jnp.tensordot(h, lp[prefix + "w_out"], axes=1)
    return _row_parallel_out(h, lp[prefix + "w_out"], comm=comm,
                             tp2d=tp2d, shard_model=True)


def _decode_ssm(x, lp, cfg, comm: Comm, plan: TPPlan, state, conv_tail,
                prefix: str = "ssm_", tp2d: bool = False):
    """x (b, d); state (b, H_loc, N, P); conv_tail (K-1, b, di_loc)."""
    def nm(s):
        return prefix + s

    di, h = cfg.ssm_d_inner, cfg.ssm_heads
    tp = comm.tp if plan.shard_ssm_heads else 1
    di_l, h_l = di // tp, h // tp
    zxdt = jnp.concatenate(
        [_wmul(x, lp[nm("w_z")], fsdp_axis=0, comm=comm, tp2d=tp2d),
         _wmul(x, lp[nm("w_x")], fsdp_axis=0, comm=comm, tp2d=tp2d),
         _wmul(x, lp[nm("w_dt")], fsdp_axis=0, comm=comm, tp2d=tp2d)],
        axis=-1)
    bc = _wmul(x, lp[nm("w_bc")], fsdp_axis=0, comm=comm, tp2d=tp2d)
    # tp2d: the recurrent state/conv caches are batch-sharded over data;
    # slice this rank's batch rows for the recurrence, rejoin after
    b_full = x.shape[0]
    dp = comm.dp
    batch_sharded = tp2d and dp > 1 and b_full % dp == 0
    if batch_sharded:
        b_l = b_full // dp
        bstart = comm.data_index() * b_l
        zxdt = jax.lax.dynamic_slice_in_dim(zxdt, bstart, b_l, axis=0)
        bc = jax.lax.dynamic_slice_in_dim(bc, bstart, b_l, axis=0)
    z, xs, dt_raw = jnp.split(zxdt, [di_l, 2 * di_l], axis=-1)
    b_t, c_t = jnp.split(bc, 2, axis=-1)
    g, n = cfg.ssm_groups, cfg.ssm_state

    # causal conv: roll the tail window
    conv_w = lp[nm("conv_w")]                      # (K, di_l)
    K = conv_w.shape[0]
    window = jnp.concatenate([conv_tail, xs[None]], axis=0)  # (K, b, di_l)
    xs_c = jnp.einsum("kbc,kc->bc", window.astype(jnp.float32),
                      conv_w.astype(jnp.float32)).astype(x.dtype)
    conv_tail = window[1:]
    xs_c = jax.nn.silu(xs_c.astype(jnp.float32)).astype(x.dtype)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp[nm("dt_bias")].astype(jnp.float32))
    state, y = ssd_decode_step(
        state, xs_c.reshape(-1, h_l, cfg.ssm_headdim), dt,
        lp[nm("a_log")], b_t.reshape(-1, g, n), c_t.reshape(-1, g, n),
        lp[nm("d_skip")])
    y = y.reshape(-1, di_l)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    ssq = (yf * yf).sum(axis=-1, keepdims=True)
    denom = di_l
    if plan.shard_ssm_heads:
        ssq = comm.psum_model(ssq)
        denom = di
    yf = yf * jax.lax.rsqrt(ssq / denom + 1e-6)
    y = (yf * lp[nm("norm_w")].astype(jnp.float32)).astype(x.dtype)
    if batch_sharded:
        y = comm.ag_data(y, axis=0)          # rejoin rows pre-out-proj
    out = _row_parallel_out(y, lp[nm("w_out")], comm=comm, tp2d=tp2d,
                            shard_model=plan.shard_ssm_heads)
    return out, state, conv_tail


# ---------------------------------------------------------------------------
# serve_step
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, comm: Optional[Comm] = None, *,
                    joint_kv: bool = False, tp2d: bool = False):
    """Build ``serve_step(params, cache, tokens) -> (next_tokens, cache')``.

    tokens: (b,) int32 — the tokens decoded at position ``cache.length``;
    returns greedily sampled next tokens and the updated cache.
    ``joint_kv``: shard the KV seq dim over data AND model (B == 1 long-
    context shapes).
    """
    comm = comm or local_comm()

    def serve_step(params, cache: DecodeCache, tokens: jax.Array):
        plan = tp_plan(cfg, comm.tp)
        pos = cache.length
        emb_w = (params["emb"] if tp2d
                 else comm.weight(params["emb"], fsdp_axis=1))
        x = _embed_flat(tokens, emb_w, comm,
                        scale=cfg.name.startswith("gemma"), tp2d=tp2d)

        is_vlm = cfg.family == "vlm"
        n_cross = _n_cross(cfg)
        per = (cfg.cross_attn_every - 1) if is_vlm else 0

        def layer(carry, scanned):
            xc, kall, vall, sall, call_ = carry
            idx, lp = scanned["idx"], scanned["lp"]
            aux_kv = scanned.get("xlp")
            h = apply_norm(cfg.norm, xc, lp.get("norm1"))
            window = layer_window(cfg, idx) if cfg.sliding_window else 0

            kc = kall[idx] if kall is not None else None
            vc = vall[idx] if vall is not None else None
            st = sall[idx] if sall is not None else None
            ct = call_[idx] if call_ is not None else None

            if cfg.family == "ssm":
                out, st, ct = _decode_ssm(h, lp, cfg, comm, plan, st, ct,
                                          tp2d=tp2d)
                xc = xc + out
            elif cfg.family == "hybrid":
                a_out, kc, vc = _decode_attn_layer(
                    h, lp, cfg, comm, plan, kc, vc, pos, window,
                    joint_kv=joint_kv, tp2d=tp2d)
                s_out, st, ct = _decode_ssm(h, lp, cfg, comm, plan, st, ct,
                                            tp2d=tp2d)
                mix = 0.5 * (rms_norm(a_out, lp["mix_norm_a"])
                             + rms_norm(s_out, lp["mix_norm_s"]))
                xc = xc + mix
                h2 = apply_norm(cfg.norm, xc, lp.get("norm2"))
                xc = xc + _decode_mlp(h2, lp, cfg, comm, tp2d=tp2d)
            else:
                a_out, kc, vc = _decode_attn_layer(
                    h, lp, cfg, comm, plan, kc, vc, pos, window,
                    joint_kv=joint_kv, tp2d=tp2d,
                    defer_out=tp2d and cfg.parallel_block)
                if cfg.parallel_block:
                    # §Perf iteration 3: under tp2d, attention and MLP
                    # write the SAME residual; add their pre-reduction
                    # partials and pay one psum_model + one column gather
                    if tp2d:
                        pm = _decode_mlp(h, lp, cfg, comm, tp2d=True,
                                         defer_out=True)
                        combined = comm.psum_model(a_out + pm)
                        xc = xc + comm.ag_data(combined,
                                               axis=combined.ndim - 1)
                    else:
                        xc = xc + a_out + _decode_mlp(h, lp, cfg, comm)
                else:
                    xc = xc + a_out
                    if cfg.is_encdec and aux_kv is not None:
                        hx = rms_norm(xc, lp["normx"])
                        x_out, _, _ = _decode_attn_layer(
                            hx, lp, cfg, comm, plan, None, None, pos, 0,
                            joint_kv=joint_kv, prefix="x_",
                            memory_kv=aux_kv, tp2d=tp2d)
                        xc = xc + x_out
                    h2 = apply_norm(cfg.norm, xc, lp.get("norm2"))
                    if cfg.family == "moe":
                        # MoE experts keep the gather path (dispatch owns
                        # the a2a); router/shared-mlp ride tp2d
                        mo, _ = moe_block(h2[None], lp, cfg, comm)
                        mo = mo[0]
                        if cfg.shared_expert_ff:
                            mo = mo + _decode_mlp(h2, lp, cfg, comm,
                                                  prefix="shared_",
                                                  tp2d=tp2d)
                        xc = xc + mo
                    else:
                        xc = xc + _decode_mlp(h2, lp, cfg, comm,
                                              tp2d=tp2d)

            if kall is not None and kc is not None:
                kall = kall.at[idx].set(kc)
                vall = vall.at[idx].set(vc)
            if sall is not None and st is not None:
                sall = sall.at[idx].set(st)
                call_ = call_.at[idx].set(ct)
            return (xc, kall, vall, sall, call_), ()

        L_self = cfg.n_layers - n_cross if is_vlm else cfg.n_layers
        scanned = {"idx": jnp.arange(L_self, dtype=jnp.int32),
                   "lp": params["layers"]}
        carry = (x, cache.k, cache.v, cache.ssm_state, cache.conv_tail)
        if cfg.is_encdec:
            def layer_encdec(c, sl):
                idx, lp, xk, xv = sl
                return layer(c, {"idx": idx, "lp": lp,
                                 "xlp": (xk, xv)})
            carry, _ = jax.lax.scan(
                layer_encdec, carry,
                (scanned["idx"], params["layers"], cache.cross_k,
                 cache.cross_v))
        elif is_vlm:
            stack = jax.tree_util.tree_map(
                lambda a: a.reshape((n_cross, per) + a.shape[1:]),
                params["layers"])

            def superblock(c, sl):
                sb_idx, self_lp, cross_lp, xk, xv = sl

                def inner(c2, sl2):
                    j, lp2 = sl2
                    return layer(c2, {"idx": sb_idx * per + j, "lp": lp2})
                c, _ = jax.lax.scan(
                    inner, c, (jnp.arange(per, dtype=jnp.int32), self_lp))
                xc = c[0]
                hx = rms_norm(xc, cross_lp["normx"])
                x_out, _, _ = _decode_attn_layer(
                    hx, cross_lp, cfg, comm, tp_plan(cfg, comm.tp), None,
                    None, pos, 0, joint_kv=joint_kv, prefix="x_",
                    memory_kv=(xk, xv), tp2d=tp2d)
                xc = xc + jnp.tanh(cross_lp["gate_attn"]).astype(xc.dtype) \
                    * x_out
                hm = rms_norm(xc, cross_lp["normm"])
                ff = _decode_mlp(hm, cross_lp, cfg, comm, prefix="xm_",
                                 tp2d=tp2d)
                xc = xc + jnp.tanh(cross_lp["gate_mlp"]).astype(xc.dtype) \
                    * ff
                return (xc,) + c[1:], ()

            carry, _ = jax.lax.scan(
                superblock, carry,
                (jnp.arange(n_cross, dtype=jnp.int32), stack,
                 params["cross_layers"], cache.cross_k, cache.cross_v))
        else:
            def layer_plain(c, sl):
                idx, lp = sl
                return layer(c, {"idx": idx, "lp": lp})
            carry, _ = jax.lax.scan(layer_plain, carry,
                                    (scanned["idx"], params["layers"]))

        xc, kall, vall, sall, call_ = carry
        xc = apply_norm("rmsnorm" if cfg.norm == "rmsnorm" else "layernorm",
                        xc, params["final_norm"])
        head = params.get("lm_head", params["emb"])
        if tp2d:
            # head columns (d) stay data-sharded: slice x, partial logits,
            # psum over data; vocab masking/argmax unchanged
            d_l = head.shape[1]
            start = comm.data_index() * d_l
            x_slice = jax.lax.dynamic_slice_in_dim(xc, start, d_l, axis=1)
            logits = jnp.tensordot(x_slice.astype(jnp.float32),
                                   head.astype(jnp.float32).T, axes=1)
            logits = comm.psum_data(logits)
            v_local = head.shape[0]
            gid = comm.model_index() * v_local + jnp.arange(v_local)
            logits = jnp.where(gid[None, :] < cfg.vocab, logits, -1e30)
        else:
            head_full = comm.weight(head, fsdp_axis=1)
            logits = lm_head_logits(xc, head_full, comm,
                                    real_vocab=cfg.vocab)
        next_tokens = greedy_sample(logits, comm)
        new_cache = DecodeCache(k=kall, v=vall, ssm_state=sall,
                                conv_tail=call_, cross_k=cache.cross_k,
                                cross_v=cache.cross_v, length=pos + 1)
        return next_tokens, new_cache

    return serve_step


def precompute_cross_kv(params, memory: jax.Array, cfg: ModelConfig,
                        comm: Optional[Comm] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Project encoder/image memory through every cross-attn layer's K/V.

    memory: (T, b, d) full-length (replicated over model).  Returns
    (cross_k, cross_v): (L_cross, T, b, n_kv, dh) — computed once at
    admission, reused every decode step (the big prefill→decode win for
    enc-dec/VLM).
    """
    comm = comm or local_comm()
    dh = cfg.resolved_head_dim
    stack = (params["cross_layers"] if cfg.family == "vlm"
             else params["layers"])

    def one(lp):
        wk = comm.weight(lp["x_wk"], fsdp_axis=0)
        wv = comm.weight(lp["x_wv"], fsdp_axis=0)
        k = jnp.tensordot(memory, wk, axes=1)
        v = jnp.tensordot(memory, wv, axes=1)
        k = k.reshape(*k.shape[:-1], -1, dh)
        v = v.reshape(*v.shape[:-1], -1, dh)
        return k, v

    # lax.map (scan) rather than vmap: collectives inside the body follow
    # the proven scan path, no batching rules involved
    return jax.lax.map(one, stack)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, comm: Optional[Comm] = None):
    """Build ``prefill(params, batch) -> (last_hidden (b,d), logits_local)``.

    The prefill cell exercises the full-sequence forward at inference
    (no loss, last-position head).  Cache *population* for the serving
    engine's host path reuses the training forward's KV computation; the
    dry-run measures the compute/comm of the forward itself.
    """
    comm = comm or local_comm()

    def prefill(params, batch):
        x, _ = lm_mod.forward(params, batch, cfg, comm, remat=False)
        last = x[-1]                                   # (b, d)
        head = params.get("lm_head", params["emb"])
        head = comm.weight(head, fsdp_axis=1)
        logits = lm_head_logits(last, head, comm, real_vocab=cfg.vocab)
        return greedy_sample(logits, comm), last

    return prefill
