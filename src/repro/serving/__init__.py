from .engine import (DecodeCache, init_cache, make_serve_step,
                     make_prefill_step, cache_pspecs)
from .kv_cache import PagedKVAllocator
from .scheduler import Request, ResultDrain, ServeScheduler, ServeTransport

__all__ = ["DecodeCache", "init_cache", "make_serve_step",
           "make_prefill_step", "cache_pspecs", "PagedKVAllocator",
           "Request", "ResultDrain", "ServeScheduler", "ServeTransport"]
