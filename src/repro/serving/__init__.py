from .engine import (DecodeCache, init_cache, make_serve_step,
                     make_prefill_step, cache_pspecs)
from .kv_cache import PagedKVAllocator
from .scheduler import Request, ResultDrain, ServeScheduler, ServeTransport
from .result_tokens import (ResultTokens, SlotData, decode_token_row,
                            encode_token_row)
from .slots import SERVING_ATTRS, SlotAllocator
from .batching import (ContinuousBatcher, ServePlane, SyntheticModel,
                       TokenClient)

__all__ = ["DecodeCache", "init_cache", "make_serve_step",
           "make_prefill_step", "cache_pspecs", "PagedKVAllocator",
           "Request", "ResultDrain", "ServeScheduler", "ServeTransport",
           "ResultTokens", "SlotData", "encode_token_row",
           "decode_token_row", "SERVING_ATTRS", "SlotAllocator",
           "ContinuousBatcher", "ServePlane", "SyntheticModel",
           "TokenClient"]
