"""Slot + paged-KV admission for the continuous-batching engine.

A request occupies one *decode slot* (a row of the JetStream-style slot
array) plus a page table of fixed-size KV pages drawn from the packet
pool underneath :class:`~repro.serving.kv_cache.PagedKVAllocator`.  Both
geometries — page size, slot count, total pages, eviction policy — are
ordinary attributes resolved through the four-layer chain
(``kv_page_tokens`` / ``kv_slots`` / ``kv_pages`` / ``kv_evict``,
DESIGN.md §12), so a bad knob fails at alloc time naming the attribute,
and a live allocator answers ``get_attr`` for everything it runs with.

Admission is the paper's ternary contract: ``done`` (slot + pages
reserved), ``retry(RETRY_NOSLOT)`` (exhausted — the engine parks the
request in its backlog queue), never blocking.  Under
``kv_evict="preempt_longest"`` exhaustion instead preempts the active
request with the largest footprint: its pages free, its generated-token
count survives, and its stream resumes after re-prefill — continuous
batching's recompute-style preemption without ever duplicating a token.
"""
from __future__ import annotations

import collections
from typing import Dict, Optional

from repro.core import attrs as _attrs
from repro.core.status import ErrorCode, Status, done, retry
from .kv_cache import PagedKVAllocator

#: the serving attr set (satellite of DESIGN.md §12's registry table)
SERVING_ATTRS = ("kv_page_tokens", "kv_slots", "kv_pages", "kv_evict",
                 "prefill_chunk", "drain_workers", "max_batch")

#: the subset the slot allocator itself resolves
SLOT_ATTRS = ("kv_page_tokens", "kv_slots", "kv_pages", "kv_evict")


class SlotAllocator(_attrs.AttrResource):
    """Decode-slot + KV-page admission with the unified attr surface."""

    def __init__(self, *, runtime_layer=None,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 **overrides):
        if resolved is None:
            resolved = _attrs.resolve(SLOT_ATTRS, runtime=runtime_layer,
                                      overrides=overrides)
        elif overrides:
            resolved = resolved.merged(_attrs.resolve(
                tuple(overrides), overrides=overrides))
        self.page_tokens: int = resolved["kv_page_tokens"]
        self.n_slots: int = resolved["kv_slots"]
        self.n_pages: int = resolved["kv_pages"] or 8 * self.n_slots
        self.evict_policy: str = resolved["kv_evict"]
        self.pages = PagedKVAllocator(self.n_pages, self.page_tokens)
        self._free_slots: collections.deque = collections.deque(
            range(self.n_slots))
        self.slot_of: Dict[int, int] = {}          # rid -> slot
        self.tokens_of: Dict[int, int] = {}        # rid -> reserved tokens
        self.admissions = 0
        self.rejections = 0
        self.preemptions = 0
        self._init_attrs(resolved.subset(SLOT_ATTRS))
        self._export_attr("free_slots", lambda: len(self._free_slots))
        self._export_attr("active_slots", lambda: len(self.slot_of))
        self._export_attr("free_pages", lambda: self.pages.free_pages)
        self._export_attr("occupancy", self.occupancy)

    def occupancy(self) -> float:
        """Fraction of decode slots currently held by a request."""
        return len(self.slot_of) / self.n_slots

    def admit(self, rid: int, total_tokens: int) -> Status:
        """Reserve a slot and pages covering ``total_tokens`` positions;
        all-or-nothing.  ``done(slot)`` or ``retry(RETRY_NOSLOT)``."""
        if rid in self.slot_of:
            raise ValueError(f"request {rid} already holds slot "
                             f"{self.slot_of[rid]}")
        if not self._free_slots:
            self.rejections += 1
            return retry(ErrorCode.RETRY_NOSLOT)
        st = self.pages.admit(rid, total_tokens)
        if st.is_retry():
            self.rejections += 1
            return st
        slot = self._free_slots.popleft()
        self.slot_of[rid] = slot
        self.tokens_of[rid] = total_tokens
        self.admissions += 1
        return done(slot)

    def extend(self, rid: int, new_len: int) -> Status:
        """Grow a resident request's page table to ``new_len`` tokens."""
        st = self.pages.extend(rid, new_len)
        if st.is_done():
            self.tokens_of[rid] = max(self.tokens_of.get(rid, 0), new_len)
        return st

    def release(self, rid: int) -> None:
        slot = self.slot_of.pop(rid, None)
        self.tokens_of.pop(rid, None)
        if slot is not None:
            self._free_slots.append(slot)
        self.pages.release(rid)

    def victim(self) -> Optional[int]:
        """Pick the preemption victim under ``kv_evict=preempt_longest``:
        the resident request with the largest reserved footprint."""
        if self.evict_policy != "preempt_longest" or not self.slot_of:
            return None
        return max(self.tokens_of, key=self.tokens_of.get)

    def counters(self) -> dict:
        return {"admissions": self.admissions,
                "rejections": self.rejections,
                "preemptions": self.preemptions,
                "active_slots": len(self.slot_of),
                "free_pages": self.pages.free_pages}
