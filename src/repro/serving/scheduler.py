"""Serving scheduler — continuous batching on LCI admission semantics.

Requests are *posted* to the engine; the scheduler returns the paper's
ternary status to the client: ``done`` (finished, payload = generated
ids), ``posted`` (admitted, completion object will be signaled), or
``retry`` (KV pages exhausted — the request goes to the **backlog queue**
and is re-admitted as pages free up).  Completion objects are real LCI
objects: pass a CompletionQueue to poll finished requests, or a handler
for push delivery.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.backlog import BacklogQueue
from repro.core.completion import CompletionObject, CompletionQueue
from repro.core.matching import HostMatchingEngine, MatchKind
from repro.core.status import ErrorCode, Status, done, posted, retry
from .kv_cache import PagedKVAllocator

_req_ids = itertools.count()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (len,) int32
    max_new: int
    comp: Optional[CompletionObject]
    generated: List[int] = dataclasses.field(default_factory=list)
    position: int = 0


class ServeScheduler:
    """Continuous batching: admit -> decode rounds -> complete.

    ``decode_fn(tokens (b,), positions (b,)) -> next tokens (b,)`` is the
    device-side step (the engine's serve_step bound to params/cache); the
    scheduler owns admission, the backlog, and completion delivery.  The
    matching engine routes finished requests back to per-client queues
    (client id = rank, request id = tag — exactly the send/recv pattern).
    """

    def __init__(self, decode_fn: Callable, *, max_batch: int,
                 allocator: PagedKVAllocator, eos_id: int = -1):
        self.decode_fn = decode_fn
        self.max_batch = max_batch
        self.alloc = allocator
        self.eos_id = eos_id
        self.active: Dict[int, Request] = {}
        self.backlog = BacklogQueue()
        self.router = HostMatchingEngine()
        self.completed = 0
        self.retries = 0

    # -- client API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               comp: Optional[CompletionObject] = None,
               allow_retry: bool = True) -> Status:
        rid = next(_req_ids)
        req = Request(rid, np.asarray(prompt, np.int32), max_new, comp)
        st = self._admit(req)
        if st.is_retry():
            self.retries += 1
            if allow_retry:
                return st
            self.backlog.push(req)
            return posted(code=ErrorCode.POSTED_BACKLOG, ctx=rid)
        return posted(ctx=rid)

    def _admit(self, req: Request) -> Status:
        if len(self.active) >= self.max_batch:
            return retry(ErrorCode.RETRY_NOSLOT)
        st = self.alloc.admit(req.rid, len(req.prompt) + req.max_new)
        if st.is_retry():
            return st
        req.position = len(req.prompt)
        self.active[req.rid] = req
        return done()

    # -- engine progress -----------------------------------------------------
    def step(self) -> int:
        """One decode round over the active set; returns #finished."""
        # (3) drain the backlog first, exactly like the progress engine
        while not self.backlog.empty_flag and len(self.active) < \
                self.max_batch:
            req, st = self.backlog.pop()
            if st.is_retry():
                break
            if self._admit(req).is_retry():
                self.backlog.push(req)
                break

        if not self.active:
            return 0
        reqs = list(self.active.values())
        tokens = np.array([r.prompt[-1] if not r.generated
                           else r.generated[-1] for r in reqs], np.int32)
        positions = np.array([r.position for r in reqs], np.int32)
        nxt = np.asarray(self.decode_fn(tokens, positions))

        finished = 0
        for r, t in zip(reqs, nxt):
            r.generated.append(int(t))
            r.position += 1
            if len(r.generated) >= r.max_new or int(t) == self.eos_id:
                self._complete(r)
                finished += 1
        return finished

    def _complete(self, req: Request) -> None:
        del self.active[req.rid]
        self.alloc.release(req.rid)
        st = done(np.array(req.generated, np.int32), tag=req.rid)
        if req.comp is not None:
            req.comp.signal(st)
        else:
            self.router.insert(req.rid, MatchKind.SEND, st)
        self.completed += 1

    def poll(self, rid: int) -> Status:
        """Pull-style completion for clients without a completion object."""
        match = self.router.insert(rid, MatchKind.RECV, None)
        if match is None:
            return retry()
        return match
