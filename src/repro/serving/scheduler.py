"""Serving scheduler — continuous batching on LCI admission semantics.

Requests are *posted* to the engine; the scheduler returns the paper's
ternary status to the client: ``done`` (finished, payload = generated
ids), ``posted`` (admitted, completion object will be signaled), or
``retry`` (KV pages exhausted — the request goes to the **backlog queue**
and is re-admitted as pages free up).  Completion objects are real LCI
objects: pass a CompletionQueue to poll finished requests, or a handler
for push delivery.

With a :class:`ServeTransport`, request/response traffic actually rides
the host runtime: prompts (large, bursty) are posted on a **prefill
endpoint** striped by size class, generated tokens (tiny,
latency-sensitive) on a separate narrow **decode endpoint** — so decode
results never queue behind a bulk prompt on the same device stream (the
paper's size-class-isolation "new possibilities" scenario, §3.2.3).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.backlog import BacklogQueue
from repro.core.completion import CompletionObject, CompletionQueue
from repro.core.concurrency import ThreadSafeCompletionQueue
from repro.core.concurrency import drain as drain_cq
from repro.core.matching import HostMatchingEngine, MatchKind
from repro.core.runtime import LocalCluster
from repro.core.status import ErrorCode, FatalError, Status, done, posted, retry
from .kv_cache import PagedKVAllocator

_req_ids = itertools.count()


class ServeTransport:
    """Client<->server request plumbing over striped endpoints.

    One :class:`~repro.core.runtime.LocalCluster` rank is the client, one
    the server.  Two symmetric endpoint bundles are allocated cluster-wide
    (device streams match by index, so every rank replicates the shape):

    * ``prefill`` — ``n_prefill`` devices, ``by_size`` stripe: prompt
      payloads sort into size classes, so a short prompt is never stuck
      behind a long one on the same stream.
    * ``decode``  — ``n_decode`` device(s), round-robin: the token-return
      path, isolated from all prompt traffic.
    """

    def __init__(self, cluster: LocalCluster, *, client_rank: int = 0,
                 server_rank: int = 1, n_prefill: int = 2,
                 n_decode: int = 1):
        self.cluster = cluster
        self.client_rank = client_rank
        self.server_rank = server_rank
        self.prefill = cluster.alloc_endpoint(
            n_devices=n_prefill, stripe="by_size", progress="dedicated",
            name="prefill")
        self.decode = cluster.alloc_endpoint(
            n_devices=n_decode, stripe="round_robin", name="decode")
        server = cluster[server_rank]
        client = cluster[client_rank]
        self.prompt_cq = server.alloc_cq()
        self._prompt_rc = server.register_rcomp(self.prompt_cq)
        self.result_cq = client.alloc_cq()
        self._result_rc = client.register_rcomp(self.result_cq)

    # -- client side ---------------------------------------------------------
    def send_prompt(self, rid: int, prompt: np.ndarray) -> Status:
        """Post the prompt to the server over the prefill endpoint."""
        payload = np.ascontiguousarray(prompt, np.int32).view(np.uint8)
        return self.prefill[self.client_rank].post_am(
            self.server_rank, payload, remote_comp=self._prompt_rc, tag=rid,
            allow_retry=False)

    def poll_results(self) -> List[Tuple[int, np.ndarray]]:
        """Drain finished (rid, generated tokens) pairs at the client."""
        out = []
        while True:
            st = self.result_cq.pop()
            if st.is_retry():
                return out
            out.append((st.tag, np.asarray(st.get_buffer())
                        .view(np.int32).copy()))

    # -- server side ---------------------------------------------------------
    def recv_prompts(self) -> List[Tuple[int, np.ndarray]]:
        """Drain (rid, prompt) pairs that arrived over the wire."""
        out = []
        while True:
            st = self.prompt_cq.pop()
            if st.is_retry():
                return out
            out.append((st.tag, np.asarray(st.get_buffer())
                        .view(np.int32).copy()))

    def send_result(self, rid: int, tokens: np.ndarray) -> Status:
        """Return generated ids over the decode endpoint (small messages —
        they stripe onto the isolated decode devices)."""
        payload = np.ascontiguousarray(tokens, np.int32).view(np.uint8)
        return self.decode[self.server_rank].post_am(
            self.client_rank, payload, remote_comp=self._result_rc, tag=rid,
            allow_retry=False)

    def send_results(self, batch: List[Tuple[int, np.ndarray]]
                     ) -> List[Status]:
        """Burst-post a step's finished results in one ``post_am_many``
        doorbell: one staged copy + one push per device instead of a
        host-synchronous scalar post per request.  Per-status ternary
        results come back positionally — ``retry`` entries are the
        caller's to park (see ``ServeScheduler._flush_results``)."""
        bufs = [np.ascontiguousarray(tokens, np.int32).view(np.uint8)
                for _, tokens in batch]
        return self.decode[self.server_rank].post_am_many(
            self.client_rank, bufs, self._result_rc,
            tags=[rid for rid, _ in batch])

    def pump(self, rounds: int = 4) -> int:
        """Drive progress on both sides' endpoint devices."""
        n = 0
        for eps in (self.prefill, self.decode):
            for ep in eps:
                n += ep.progress(rounds)
        return n

    def counters(self) -> dict:
        return {
            "prefill": [ep.counters() for ep in self.prefill],
            "decode": [ep.counters() for ep in self.decode],
        }

    @property
    def attrs(self) -> dict:
        """Queryable endpoint attributes per side (unified get_attr
        surface, DESIGN.md §12): what the transport actually runs with."""
        return {
            "prefill": self.prefill[0].attrs,
            "decode": self.decode[0].attrs,
        }


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (len,) int32
    max_new: int
    comp: Optional[CompletionObject]
    generated: List[int] = dataclasses.field(default_factory=list)
    position: int = 0
    remote: bool = False                  # arrived over the ServeTransport


class ServeScheduler:
    """Continuous batching: admit -> decode rounds -> complete.

    ``decode_fn(tokens (b,), positions (b,)) -> next tokens (b,)`` is the
    device-side step (the engine's serve_step bound to params/cache); the
    scheduler owns admission, the backlog, and completion delivery.  The
    matching engine routes finished requests back to per-client queues
    (client id = rank, request id = tag — exactly the send/recv pattern).
    """

    def __init__(self, decode_fn: Callable, *, max_batch: int,
                 allocator: PagedKVAllocator, eos_id: int = -1,
                 transport: Optional[ServeTransport] = None):
        self.decode_fn = decode_fn
        self.max_batch = max_batch
        self.alloc = allocator
        self.eos_id = eos_id
        self.transport = transport
        self.active: Dict[int, Request] = {}
        self.backlog = BacklogQueue()
        self.router = HostMatchingEngine()
        # completions rejected with retry (bounded client CQ full) —
        # redelivered each step, mirroring the progress-engine backlog
        self._pending_signals: collections.deque = collections.deque()
        # remote results finished this step, flushed as ONE post_am_many
        # burst; retry-rejected sends park here per client, in order
        self._outbox: List[Tuple[int, np.ndarray]] = []
        self._pending_sends: collections.deque = collections.deque()
        self.completed = 0
        self.retries = 0

    def alloc_cq(self, capacity: Optional[int] = None, *,
                 threadsafe: bool = False) -> CompletionObject:
        """Allocate a result queue through the unified comp API: routed to
        the transport's client runtime when one exists (so remote results
        and local completions share one allocation surface).
        ``threadsafe=True`` returns the LCQ-backed queue — required when
        results are drained by :meth:`start_result_drain` workers."""
        if self.transport is not None:
            client = self.transport.cluster[self.transport.client_rank]
            return client.alloc_cq(capacity, threadsafe=threadsafe)
        if threadsafe:
            return ThreadSafeCompletionQueue(capacity)
        return CompletionQueue(capacity)

    def start_result_drain(self, cq: CompletionObject,
                           n_workers: int = 2) -> "ResultDrain":
        """Drain a client CQ from ``n_workers`` threads while the caller
        keeps stepping the engine — the multithreaded-client pattern the
        concurrency subsystem exists for.  ``cq`` must be thread-safe
        (``alloc_cq(threadsafe=True)``)."""
        if isinstance(cq, CompletionQueue):
            raise FatalError("start_result_drain needs a thread-safe CQ: "
                             "alloc_cq(threadsafe=True)")
        return ResultDrain(cq, n_workers).start()

    # -- client API ----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               comp: Optional[CompletionObject] = None,
               allow_retry: bool = True) -> Status:
        rid = next(_req_ids)
        req = Request(rid, np.asarray(prompt, np.int32), max_new, comp)
        st = self._admit(req)
        if st.is_retry():
            self.retries += 1
            if allow_retry:
                return st
            self.backlog.push(req)
            return posted(code=ErrorCode.POSTED_BACKLOG, ctx=rid)
        return posted(ctx=rid)

    def _admit(self, req: Request) -> Status:
        if len(self.active) >= self.max_batch:
            return retry(ErrorCode.RETRY_NOSLOT)
        st = self.alloc.admit(req.rid, len(req.prompt) + req.max_new)
        if st.is_retry():
            return st
        req.position = len(req.prompt)
        self.active[req.rid] = req
        return done()

    def submit_remote(self, prompt: np.ndarray, max_new: int) -> int:
        """Client-side submit: the prompt rides the prefill endpoint to the
        server; results come back via ``transport.poll_results()``."""
        if self.transport is None:
            raise ValueError("submit_remote needs a ServeTransport")
        rid = next(_req_ids)
        payload = np.concatenate([np.array([max_new], np.int32),
                                  np.asarray(prompt, np.int32)])
        self.transport.send_prompt(rid, payload)
        return rid

    def _ingest_transport(self) -> None:
        """Server side: admit prompts that arrived over the wire."""
        self.transport.pump()
        for rid, data in self.transport.recv_prompts():
            req = Request(rid, data[1:], int(data[0]), comp=None,
                          remote=True)
            if self._admit(req).is_retry():
                self.retries += 1
                self.backlog.push(req)

    # -- engine progress -----------------------------------------------------
    def step(self) -> int:
        """One decode round over the active set; returns #finished."""
        if self.transport is not None:
            self._ingest_transport()
        # redeliver completions a full client CQ rejected earlier — one
        # full CQ must not block other clients' results, and a client's
        # own results must stay in order (once one of its signals is
        # rejected, its later ones wait behind it)
        rejected, blocked = [], set()
        for _ in range(len(self._pending_signals)):
            comp, st = self._pending_signals.popleft()
            if id(comp) in blocked or self._signal_rejected(comp, st):
                rejected.append((comp, st))
                blocked.add(id(comp))
        self._pending_signals.extendleft(reversed(rejected))
        # (3) drain the backlog first, exactly like the progress engine
        while not self.backlog.empty_flag and len(self.active) < \
                self.max_batch:
            req, st = self.backlog.pop()
            if st.is_retry():
                break
            if self._admit(req).is_retry():
                self.backlog.push(req)
                break

        if not self.active:
            self._flush_results()      # parked sends still redeliver
            return 0
        reqs = list(self.active.values())
        tokens = np.array([r.prompt[-1] if not r.generated
                           else r.generated[-1] for r in reqs], np.int32)
        positions = np.array([r.position for r in reqs], np.int32)
        nxt = np.asarray(self.decode_fn(tokens, positions))

        finished = 0
        for r, t in zip(reqs, nxt):
            r.generated.append(int(t))
            r.position += 1
            if len(r.generated) >= r.max_new or int(t) == self.eos_id:
                self._complete(r)
                finished += 1
        self._flush_results()
        return finished

    def _flush_results(self) -> int:
        """Send parked + newly finished remote results as one burst.

        Parked results go first (a client's stream stays in order); the
        burst rides the single decode stream with prefix-accept, so a
        ``retry`` for one client re-parks that client's later results
        behind it while other clients' results still land."""
        if self.transport is None or not (self._outbox
                                          or self._pending_sends):
            return 0
        batch = list(self._pending_sends) + self._outbox
        self._pending_sends.clear()
        self._outbox = []
        sts = self.transport.send_results(batch)
        blocked, accepted = set(), 0
        for (rid, tokens), st in zip(batch, sts):
            if st.is_retry() or rid in blocked:
                self._pending_sends.append((rid, tokens))
                blocked.add(rid)
            else:
                accepted += 1
        self.transport.pump()
        return accepted

    def _complete(self, req: Request) -> None:
        del self.active[req.rid]
        self.alloc.release(req.rid)
        if req.remote:
            self._outbox.append((req.rid,
                                 np.array(req.generated, np.int32)))
            self.completed += 1
            return
        st = done(np.array(req.generated, np.int32), tag=req.rid)
        if req.comp is not None:
            # park behind any already-parked result for the same comp (a
            # direct delivery would overtake it and break per-client
            # ordering), or when the comp rejects the signal (CQ full)
            queued = any(c is req.comp for c, _ in self._pending_signals)
            if queued or self._signal_rejected(req.comp, st):
                self._pending_signals.append((req.comp, st))  # never drop
        else:
            self.router.insert(req.rid, MatchKind.SEND, st)
        self.completed += 1

    @staticmethod
    def _signal_rejected(comp, st: Status) -> bool:
        result = comp.signal(st)
        return isinstance(result, Status) and result.is_retry()

    def poll(self, rid: int) -> Status:
        """Pull-style completion for clients without a completion object."""
        match = self.router.insert(rid, MatchKind.RECV, None)
        if match is None:
            return retry()
        return match


class ResultDrain:
    """Worker threads concurrently popping finished results off one CQ.

    Each worker collects into its own list (no shared mutable state on
    the hot path); ``stop()`` joins the workers, performs one final drain
    so nothing signaled between the stop flag and the join is stranded,
    and returns every collected status.  The LCQ backend guarantees no
    result is lost or double-delivered across the workers — asserted by
    the threaded stress tests.

    With ``stamp=True`` every entry is ``(status, perf_counter())`` —
    receive timestamps for TTFT / inter-token latency — and
    :meth:`worker_results` exposes the per-worker streams so callers can
    assert per-worker FIFO (one worker's pops of a client's stream must
    see strictly increasing sequence numbers).
    """

    def __init__(self, cq: CompletionObject, n_workers: int = 2, *,
                 stamp: bool = False, tele=None):
        if n_workers < 1:
            raise FatalError("result drain needs n_workers >= 1")
        self.cq = cq
        self.n_workers = n_workers
        self.stamp = stamp
        self._tele = tele
        self._threads: List[threading.Thread] = []
        self._stopping = False
        # one list per worker + one for stop()'s final sweep
        self._collected: List[list] = [[] for _ in range(n_workers + 1)]

    def start(self) -> "ResultDrain":
        self._stopping = False
        self._threads = [
            threading.Thread(target=self._run, args=(w,), daemon=True,
                             name=f"result-drain/{w}")
            for w in range(self.n_workers)
        ]
        for t in self._threads:
            t.start()
        return self

    def _run(self, wid: int) -> None:
        out = self._collected[wid]
        span = self._tele.span if self._tele is not None else None
        delay = 1e-5
        while not self._stopping:
            st = self.cq.pop()
            if st.is_retry():
                time.sleep(delay)
                delay = min(delay * 2, 1e-3)
            else:
                if span is not None:
                    with span("serve.drain"):
                        out.append((st, time.perf_counter())
                                   if self.stamp else st)
                else:
                    out.append((st, time.perf_counter())
                               if self.stamp else st)
                delay = 1e-5

    @property
    def drained(self) -> int:
        return sum(len(c) for c in self._collected)

    def worker_results(self) -> List[list]:
        """Per-worker collected entries (the last list is ``stop()``'s
        final sweep, popped single-threaded after the join)."""
        return [list(c) for c in self._collected]

    def stop(self, timeout: float = 10.0) -> List[Status]:
        """Join workers (deadlock fails fast) and return all results."""
        self._stopping = True
        deadline = time.monotonic() + timeout
        for t in self._threads:
            t.join(max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                raise FatalError(f"result-drain worker stuck: {t.name}")
        self._threads = []
        final = drain_cq(self.cq)          # final sweep: nothing stranded
        now = time.perf_counter()
        self._collected[-1].extend((st, now) if self.stamp else st
                                   for st in final)
        return [entry[0] if self.stamp else entry
                for chunk in self._collected for entry in chunk]
