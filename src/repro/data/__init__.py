from .pipeline import (SyntheticPipeline, TokenFilePipeline, stub_frames,
                       stub_image_embeds)
