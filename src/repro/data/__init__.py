from .pipeline import (SyntheticPipeline, TokenFilePipeline, stub_frames,
                       stub_image_embeds)

__all__ = ["SyntheticPipeline", "TokenFilePipeline", "stub_frames",
           "stub_image_embeds"]
