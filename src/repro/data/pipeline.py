"""Deterministic, step-indexed data pipelines.

Fault-tolerance contract (DESIGN.md §7): a batch is a pure function of
``(seed, step)`` — restoring a checkpoint at step k and replaying
reproduces bit-identical batches, so checkpoint/restart never skips or
repeats data.  The file-backed pipeline reads from a flat binary token
file through ``np.memmap`` (no copies until slicing).

Batch layout is seq-major ``(S, B)`` to match the model stack's local
view; the launcher shards S over ``model`` and B over ``data``/``pod``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticPipeline:
    """Markov-ish synthetic tokens — enough structure for loss to drop."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    n_motifs: int = 32
    motif_len: int = 8

    def __post_init__(self):
        # a FIXED motif table (function of seed only): successive batches
        # share structure, so a model actually learns across steps
        rng = np.random.default_rng(self.seed)
        self._motifs = rng.integers(
            0, self.vocab, size=(self.n_motifs, self.motif_len),
            dtype=np.int32)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ (step + 1))
        b, s = self.global_batch, self.seq_len
        ml = self.motif_len
        idx = rng.integers(0, self.n_motifs,
                           size=(b, (s + ml) // ml + 1), dtype=np.int32)
        seqs = self._motifs[idx].reshape(b, -1)[:, :s + 1]
        tokens = seqs[:, :-1].T.copy()            # (S, B)
        labels = seqs[:, 1:].T.copy()
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}


@dataclasses.dataclass
class TokenFilePipeline:
    """Flat binary token file (uint16/uint32), step-indexed windows."""

    path: str
    vocab: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        n = len(self._data)
        self._n_windows = (n - 1) // self.seq_len
        if self._n_windows < self.global_batch:
            raise ValueError(f"token file too small: {n} tokens for "
                             f"{self.global_batch}x{self.seq_len}")

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        rows = rng.integers(0, self._n_windows, size=self.global_batch)
        tok = np.stack([self._data[r * self.seq_len:
                                   r * self.seq_len + self.seq_len + 1]
                        for r in rows]).astype(np.int32)
        tok = np.minimum(tok, self.vocab - 1)
        return {"tokens": tok[:, :-1].T.copy(),
                "labels": tok[:, 1:].T.copy()}


def stub_image_embeds(n_tokens: int, batch: int, d_model: int,
                      step: int = 0, seed: int = 1) -> np.ndarray:
    """VLM frontend stub: precomputed patch embeddings (ti, B, d)."""
    rng = np.random.default_rng((seed << 32) ^ step)
    return rng.standard_normal((n_tokens, batch, d_model)).astype(np.float32)


def stub_frames(n_frames: int, batch: int, d_model: int,
                step: int = 0, seed: int = 2) -> np.ndarray:
    """Audio frontend stub: precomputed frame embeddings (t, B, d)."""
    rng = np.random.default_rng((seed << 32) ^ step)
    return rng.standard_normal((n_frames, batch, d_model)).astype(np.float32)
