"""Communication execution modes — the paper's evaluation axes, on TPU.

The paper compares (§5.2):

* *process-based*      — one process per core (the classic MPI mode)
* *thread, shared*     — all threads share one set of comm resources
* *thread, dedicated*  — one device (NIC resource set) per thread

On TPU the serialization the paper fights lives in the *schedule*: a
monolithic collective is one giant serialized transfer that the step must
wait on, while chunked collectives on independent channels can be scheduled
by XLA concurrently with compute.  The three modes map to:

* ``BSP``            — monolithic blocking collectives, compute strictly
  after comm (the "MPI baseline"); no chunking, no overlap.
* ``LCI_SHARED``     — asynchronous posting, but a single channel
  (one chunk-stream); overlap only across *different* operations.
* ``LCI_DEDICATED``  — ``n_channels`` independent chunk-streams; ring
  collective-matmuls interleave ICI steps with MXU work (full overlap).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from . import attrs as _attrs


class CommMode(enum.Enum):
    BSP = "bsp"                    # paper baseline: MPI-like bulk synchronous
    LCI_SHARED = "lci_shared"      # async, shared single channel
    LCI_DEDICATED = "lci_dedicated"  # async, dedicated per-stream channels

    @property
    def is_lci(self) -> bool:
        return self is not CommMode.BSP


# CommConfig field -> canonical attribute name (the thin-view mapping).
# The field spellings (inject_max_bytes, ...) are the deprecation shim:
# every historical call site keeps working, but the stored values, their
# defaults, and REPRO_ATTR_* overridability all come from the registry.
_FIELD_TO_ATTR = {
    "mode": "mode",
    "n_channels": "n_channels",
    "inject_max_bytes": "eager_max_bytes",
    "bufcopy_max_bytes": "rdv_threshold",
    "matching_buckets": "matching_buckets",
    "packets_per_lane": "packets_per_lane",
    "packet_bytes": "packet_bytes",
    "wire_bf16": "wire_bf16",
}


@dataclasses.dataclass(frozen=True)
class CommConfig(_attrs.AttrResource):
    """Per-step communication configuration — a thin view over resolved
    attributes (DESIGN.md §12).

    Every field defaults to ``None`` = "resolve through the attribute
    chain" (library default, then ``REPRO_ATTR_*``); an explicitly passed
    field is a runtime-level override.  After construction all fields are
    concrete, so existing reads (``config.inject_max_bytes``) are
    untouched, and ``get_attr``/``attrs`` expose the same values under
    their canonical attribute names with provenance.

    ``n_channels`` is the resource-replication knob (paper: #devices).
    In ``LCI_DEDICATED`` mode ring collectives split their payload into
    ``n_channels`` chunks per ring step so that chunk *i+1* is in flight
    while chunk *i* is being consumed by the MXU.
    """

    mode: Optional[CommMode] = None
    n_channels: Optional[int] = None
    # protocol thresholds, bytes (paper §4.3: inject / buffer-copy /
    # zero-copy); attr names: eager_max_bytes / rdv_threshold
    inject_max_bytes: Optional[int] = None
    bufcopy_max_bytes: Optional[int] = None
    # matching-engine defaults (paper §4.1.3: 65536 buckets by default)
    matching_buckets: Optional[int] = None
    # packet pool
    packets_per_lane: Optional[int] = None
    packet_bytes: Optional[int] = None
    # ring wire format: cast reduce-ring accumulators to bf16 per hop
    # (local accumulation stays fp32).  ~1.5-2x fewer scatter bytes at
    # ~sqrt(hops)*2^-9 relative rounding noise — a §Perf (cell 3) knob.
    wire_bf16: Optional[bool] = None

    def __post_init__(self):
        explicit = {}
        for field, attr in _FIELD_TO_ATTR.items():
            value = getattr(self, field)
            if value is not None:
                if field == "mode":
                    value = parse_mode(value) if isinstance(value, str) \
                        else value
                    value = value.value
                explicit[attr] = value
        resolved = _attrs.resolve(list(_FIELD_TO_ATTR.values()),
                                  runtime=explicit)
        self._init_attrs(resolved)
        for field, attr in _FIELD_TO_ATTR.items():
            value = resolved[attr]
            if field == "mode":
                value = CommMode(value)
            object.__setattr__(self, field, value)

    def explicit_attrs(self) -> dict:
        """The fields this config was *explicitly* constructed with, as
        {attr name: value} — the runtime-level layer a Runtime feeds back
        into per-resource resolution."""
        return {attr: self._resolved_attrs[attr]
                for attr in _FIELD_TO_ATTR.values()
                if self._resolved_attrs.source(attr) == "runtime"}

    def resolved_channels(self) -> int:
        if self.mode == CommMode.BSP:
            return 1
        if self.mode == CommMode.LCI_SHARED:
            return 1
        return max(1, self.n_channels)


def parse_mode(name: str) -> CommMode:
    try:
        return CommMode(name)
    except ValueError as e:
        raise ValueError(
            f"unknown comm mode {name!r}; pick from "
            f"{[m.value for m in CommMode]}") from e
