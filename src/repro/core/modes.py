"""Communication execution modes — the paper's evaluation axes, on TPU.

The paper compares (§5.2):

* *process-based*      — one process per core (the classic MPI mode)
* *thread, shared*     — all threads share one set of comm resources
* *thread, dedicated*  — one device (NIC resource set) per thread

On TPU the serialization the paper fights lives in the *schedule*: a
monolithic collective is one giant serialized transfer that the step must
wait on, while chunked collectives on independent channels can be scheduled
by XLA concurrently with compute.  The three modes map to:

* ``BSP``            — monolithic blocking collectives, compute strictly
  after comm (the "MPI baseline"); no chunking, no overlap.
* ``LCI_SHARED``     — asynchronous posting, but a single channel
  (one chunk-stream); overlap only across *different* operations.
* ``LCI_DEDICATED``  — ``n_channels`` independent chunk-streams; ring
  collective-matmuls interleave ICI steps with MXU work (full overlap).
"""
from __future__ import annotations

import dataclasses
import enum


class CommMode(enum.Enum):
    BSP = "bsp"                    # paper baseline: MPI-like bulk synchronous
    LCI_SHARED = "lci_shared"      # async, shared single channel
    LCI_DEDICATED = "lci_dedicated"  # async, dedicated per-stream channels

    @property
    def is_lci(self) -> bool:
        return self is not CommMode.BSP


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Per-step communication configuration (attached to the Runtime).

    ``n_channels`` is the resource-replication knob (paper: #devices).
    In ``LCI_DEDICATED`` mode ring collectives split their payload into
    ``n_channels`` chunks per ring step so that chunk *i+1* is in flight
    while chunk *i* is being consumed by the MXU.
    """

    mode: CommMode = CommMode.LCI_DEDICATED
    n_channels: int = 4
    # protocol thresholds, bytes (paper §4.3: inject / buffer-copy / zero-copy)
    inject_max_bytes: int = 64 * 1024          # aggregate below this
    bufcopy_max_bytes: int = 2 * 1024 * 1024   # staged through packet slots
    # matching-engine defaults (paper §4.1.3: 65536 buckets by default)
    matching_buckets: int = 65536
    # packet pool
    packets_per_lane: int = 64
    packet_bytes: int = 8192
    # ring wire format: cast reduce-ring accumulators to bf16 per hop
    # (local accumulation stays fp32).  ~1.5-2x fewer scatter bytes at
    # ~sqrt(hops)*2^-9 relative rounding noise — a §Perf (cell 3) knob.
    wire_bf16: bool = False

    def resolved_channels(self) -> int:
        if self.mode == CommMode.BSP:
            return 1
        if self.mode == CommMode.LCI_SHARED:
            return 1
        return max(1, self.n_channels)


def parse_mode(name: str) -> CommMode:
    try:
        return CommMode(name)
    except ValueError as e:
        raise ValueError(
            f"unknown comm mode {name!r}; pick from "
            f"{[m.value for m in CommMode]}") from e
