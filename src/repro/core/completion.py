"""Completion objects (paper §3.2.5/§4.1.4) — handler, queue, synchronizer.

The paper: "a completion object is a functor with a virtual signal method
that takes a status_t object as an argument. Derived from it, LCI defines
four built-in completion object types: handler, queue, synchronizer, and
graph."  The graph lives in :mod:`repro.core.graph`.

Host-side objects carry the paper's exact semantics and are used by the
runtime (:mod:`repro.core.runtime`), the serving scheduler, and the k-mer
mini-app.  Their in-graph counterpart for queues is the FAA ring in
:mod:`repro.core.backlog`; synchronizers in-graph are plain signal counters
(:func:`sync_signal`).

Atomicity notes from the paper, and what happens to them here:

* completion queue — "one based on the state-of-the-art LCRQ and the other
  on a hand-written Fetch-And-Add-based fix-sized array".  The host queue is
  a deque (single-threaded host runtime); the in-graph queue is the FAA ring
  whose monotone head/tail counters are the FAA counters, sequenced by
  dataflow instead of x86 atomics.
* synchronizer — "an atomic flag (when expecting one signal) or a fixed-size
  array protected by two atomic counters".  Kept structurally: one expected
  signal skips the array entirely.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp

from . import attrs as _attrs
from .status import ErrorCode, FatalError, Status, done, retry
from .telemetry import NULL_TELEMETRY

# shared signal ack: Status is immutable and signalers only branch on
# is_retry()/code, so one object serves every accepted delivery (statuses
# are the highest-volume objects on the data plane — see status.Status)
_ACCEPTED = done()


def _as_progress_fn(source) -> Optional[Callable[[], Any]]:
    """Normalize anything that can drive progress into a 0-arg callable.

    Accepts a ``LocalCluster``/``ProgressEngine`` (``progress_all``), a
    ``Runtime``/``Endpoint`` (``progress``), a plain callable, or ``None``
    (no driver — the completion must arrive from another thread, e.g. the
    checkpoint writer).
    """
    if source is None:
        return None
    if callable(source) and not hasattr(source, "progress"):
        return source
    if hasattr(source, "progress_all"):
        return source.progress_all
    if hasattr(source, "progress"):
        return source.progress
    raise FatalError(f"cannot drive progress with {source!r}: expected a "
                     "cluster/runtime/engine/endpoint or a callable")


class CompletionObject(_attrs.AttrResource):
    """Base functor — the unified ``comp`` protocol (paper §3.2.5).

    Every completion object allocated from a runtime (``alloc_handler`` /
    ``alloc_cq`` / ``alloc_sync`` / ``alloc_graph``) satisfies one
    contract:

    * ``signal(status) -> Status`` — deliver one completion.  Returns
      ``done()`` when accepted, ``retry(RETRY_QUEUE_FULL)`` when the
      object cannot take the signal *right now* (the progress engine
      parks rejected signals in the device backlog and redelivers).
    * ``test() -> (ready, payload)`` — non-blocking readiness probe.
    * ``wait(progress=None)`` — drive ``progress`` (a cluster, runtime,
      engine, endpoint, or callable) until ``test()`` reports ready, then
      return the payload.  Progress stays explicit: the *caller* names
      who moves data (paper §3.2.6).
    """

    def signal(self, status: Status) -> Status:  # pragma: no cover
        raise NotImplementedError

    def signal_many(self, statuses: List[Status]) -> List[Status]:
        """Deliver a burst of completions in order; returns one result
        Status per delivery, aligned with the input.  The default just
        loops ``signal``; bulk-capable objects (queues) override it to
        pay their admission cost once per burst.  Acceptance is always a
        *prefix*: once one delivery is rejected (``retry``), the rest of
        the burst must be rejected too, so the progress engine's parked
        redeliveries stay in order."""
        out: List[Status] = []
        for i, st in enumerate(statuses):
            r = self.signal(st)
            out.append(r)
            if isinstance(r, Status) and r.is_retry():
                out.extend(retry(r.code) for _ in statuses[i + 1:])
                break
        return out

    def test(self) -> tuple[bool, Any]:  # pragma: no cover - interface
        raise NotImplementedError

    def wait(self, progress=None, max_rounds: int = 100_000) -> Any:
        drive = _as_progress_fn(progress)
        if drive is None:
            # completion owed by another thread (e.g. the checkpoint
            # writer): block until signaled — there is no progress to
            # drive, so rounds would measure nothing but sleep time
            delay = 1e-5
            while True:
                ok, payload = self.test()
                if ok:
                    return payload
                time.sleep(delay)
                delay = min(delay * 2, 1e-2)
        for _ in range(max_rounds):
            ok, payload = self.test()
            if ok:
                return payload
            drive()
        raise FatalError(f"{type(self).__name__}.wait: not ready after "
                         f"{max_rounds} progress rounds")


class CompletionHandler(CompletionObject):
    """Handler: a function invoked inline at completion time.

    Paper: "Completion handler is essentially a function and does not need
    any special treatment."  ``test()`` reports ready once at least one
    signal has been delivered; the payload is the most recent status.
    """

    def __init__(self, fn: Callable[[Status], None]):
        self.fn = fn
        self.signals = 0
        self.last: Optional[Status] = None
        self._export_attr("signals", lambda: self.signals)

    def signal(self, status: Status) -> Status:
        self.signals += 1
        self.last = status
        self.fn(status)
        return done()

    def test(self) -> tuple[bool, Optional[Status]]:
        return self.signals > 0, self.last


class CompletionQueue(CompletionObject):
    """Queue: completions are enqueued; the client polls with ``pop``.

    ``capacity`` bounds the queue like the FAA fixed-size array; a full
    queue surfaces ``retry(RETRY_QUEUE_FULL)`` to the *signaler* (the
    progress engine pushes it to the backlog instead of dropping it).
    """

    def __init__(self, capacity: Optional[int] = None,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 tele=None):
        self._q: collections.deque = collections.deque()
        self.capacity = capacity
        self.pushes = 0
        self.pops = 0
        self.tele = tele if tele is not None else NULL_TELEMETRY
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"cq_capacity": capacity or 0}))
        self._export_attr("depth", lambda: len(self._q))
        self._export_attr("pushes", lambda: self.pushes)
        self._export_attr("pops", lambda: self.pops)
        self._export_attr("telemetry", self._telemetry_block)

    def _telemetry_block(self) -> dict:
        return {"level": self.tele.level,
                "counters": {"cq.pushes": self.pushes,
                             "cq.pops": self.pops,
                             "cq.depth": len(self._q)}}

    def signal(self, status: Status) -> Status:
        if self.capacity is not None and len(self._q) >= self.capacity:
            return retry(ErrorCode.RETRY_QUEUE_FULL)
        self._q.append(status)
        self.pushes += 1
        return _ACCEPTED

    def signal_many(self, statuses: List[Status]) -> List[Status]:
        """Bulk enqueue: one capacity check + one deque extend for the
        accepted prefix (queue-full rejects the rest, in order)."""
        room = (len(statuses) if self.capacity is None
                else max(0, self.capacity - len(self._q)))
        n = min(room, len(statuses))
        self._q.extend(statuses if n == len(statuses) else statuses[:n])
        self.pushes += n
        return ([_ACCEPTED] * n
                + [retry(ErrorCode.RETRY_QUEUE_FULL)] * (len(statuses) - n))

    def pop(self) -> Status:
        """``cq_pop``: done-status with payload, or retry when empty."""
        tele = self.tele
        if tele.timers_on:
            with tele.span("cq.pop"):
                return self._pop()
        return self._pop()

    def _pop(self) -> Status:
        if not self._q:
            return retry(ErrorCode.RETRY_LOCKED)
        self.pops += 1
        return self._q.popleft()

    def test(self) -> tuple[bool, Optional[Status]]:
        """Non-destructive probe: (non-empty, front status or None)."""
        return bool(self._q), (self._q[0] if self._q else None)

    def wait(self, progress=None, max_rounds: int = 100_000) -> Status:
        """``cq_wait``: progress until non-empty, then pop one status."""
        super().wait(progress, max_rounds)
        return self.pop()

    def __len__(self) -> int:
        return len(self._q)


class Synchronizer(CompletionObject):
    """Synchronizer: becomes ready after ``expected`` signals.

    Paper: "similar to MPI requests but can accept multiple signals before
    becoming ready."
    """

    def __init__(self, expected: int = 1):
        if expected < 1:
            raise _attrs.AttrError(
                f"attribute 'expected' must be >= 1, got {expected}")
        self.expected = expected
        self._received: List[Status] = []
        self._error: Optional[BaseException] = None
        self._export_attr("expected", lambda: self.expected)
        self._export_attr("received", lambda: len(self._received))

    def signal(self, status: Status) -> Status:
        if len(self._received) >= self.expected:
            raise FatalError("synchronizer signaled past ready")
        self._received.append(status)
        return done()

    def fail(self, exc: BaseException) -> None:
        """Deliver a failure instead of a signal (e.g. the async
        checkpoint writer crashed): ready/test()/wait() re-raise it as a
        FatalError so a failed operation can never look complete."""
        self._error = exc

    def _check_failed(self) -> None:
        if self._error is not None:
            raise FatalError(f"synchronizer failed: "
                             f"{self._error!r}") from self._error

    @property
    def ready(self) -> bool:
        self._check_failed()
        return len(self._received) >= self.expected

    def test(self) -> tuple[bool, List[Status]]:
        """Nonblocking readiness check; payloads valid once ready."""
        return self.ready, list(self._received)

    def reset(self) -> None:
        self._received.clear()
        self._error = None


# ---------------------------------------------------------------------------
# Remote-completion registry — the MPMC array (paper §4.1.1).
#
# "rarely written but frequently read ... a write and append is protected by
# a lock to prevent missed writes, but read is lock-free.  Every resize
# swaps the old array with a new one that doubles the size."  We keep the
# doubling-growth array shape (reads index a plain list slot; appends may
# reallocate) because the Fig-5 benchmark and tests exercise its geometry.
# ---------------------------------------------------------------------------

class MPMCArray:
    """Append-mostly registry with doubling growth and O(1) reads."""

    def __init__(self, initial_cap: int = 8):
        self._arr: list = [None] * initial_cap
        self._n = 0
        self.resizes = 0

    def append(self, item: Any) -> int:
        if self._n == len(self._arr):
            old = self._arr
            self._arr = old + [None] * len(old)   # swap-with-doubled copy
            self.resizes += 1
        idx = self._n
        self._arr[idx] = item
        self._n += 1
        return idx

    def __getitem__(self, idx: int) -> Any:
        if idx >= self._n:
            raise FatalError(f"MPMCArray read past end: {idx} >= {self._n}")
        return self._arr[idx]

    def __len__(self) -> int:
        return self._n


# ---------------------------------------------------------------------------
# In-graph synchronizer: a signal counter + fixed payload slots.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyncState:
    expected: jax.Array    # () int32
    received: jax.Array    # () int32
    payload: jax.Array     # (expected_max, width)


jax.tree_util.register_pytree_node(
    SyncState,
    lambda s: ((s.expected, s.received, s.payload), None),
    lambda _, c: SyncState(*c))


def init_sync(expected: int, width: int, max_signals: int = 0) -> SyncState:
    cap = max(expected, max_signals, 1)
    return SyncState(expected=jnp.asarray(expected, jnp.int32),
                     received=jnp.zeros((), jnp.int32),
                     payload=jnp.zeros((cap, width), jnp.float32))


def sync_signal(state: SyncState, record) -> SyncState:
    pos = jnp.minimum(state.received, state.payload.shape[0] - 1)
    return SyncState(state.expected, state.received + 1,
                     state.payload.at[pos].set(record))


def sync_ready(state: SyncState) -> jax.Array:
    return state.received >= state.expected
