"""``post_comm`` — the unified communication posting operation (paper §3.2.4).

"LCI offers a generic communication posting operation, post_comm.  This
operation takes the target rank, the local buffer, the message size, and
the local completion object as positional arguments.  It takes a wide range
of optional arguments, among which the most important ones include the
direction, the remote buffer, and the remote completion object."

Table 1 of the paper, implemented verbatim by :func:`post_comm`:

    ======== ============ ================ ===========================
    direction remote buf   remote comp      meaning
    ======== ============ ================ ===========================
    OUT       none         none             send
    OUT       none         specified        active message
    OUT       specified    none             RMA put
    OUT       specified    specified        RMA put with signal
    IN        none         none             receive
    IN        none         specified        (invalid)
    IN        specified    none             RMA get
    IN        specified    specified        RMA get with signal (not
                                            implemented — mirrors paper §4.3)
    ======== ============ ================ ===========================

The five derived operations (``post_send/recv/am/put/get``) are "just
syntactic sugar for post_comm with the optional arguments set to the
corresponding values", each with an OFF ``_x`` variant.

Posting is endpoint-centric: every operation accepts ``endpoint=`` (an
:class:`~repro.core.progress.endpoint.Endpoint`), which routes the op onto
the endpoint's striped device bundle via its stripe policy — equivalent to
the :meth:`Endpoint.post_send`-style sugar, but available on the generic
``post_comm`` and on deferred OFF builders
(``post_send_x(...).endpoint(ep)``), which is how completion-graph comm
nodes ride endpoints.
"""
from __future__ import annotations

import enum
from typing import Any, Optional, Sequence

from .matching import MatchingPolicy
from .off import off
from .status import FatalError, Status


class Direction(enum.Enum):
    OUT = "out"
    IN = "in"


class CommKind(enum.Enum):
    SEND = "send"
    AM = "am"
    PUT = "put"
    PUT_SIGNAL = "put_signal"
    RECV = "recv"
    GET = "get"
    GET_SIGNAL = "get_signal"


def classify(direction: Direction, remote_buf, remote_comp) -> CommKind:
    """Table-1 dispatch; raises on the invalid / unimplemented rows."""
    if direction == Direction.OUT:
        if remote_buf is None and remote_comp is None:
            return CommKind.SEND
        if remote_buf is None:
            return CommKind.AM
        if remote_comp is None:
            return CommKind.PUT
        return CommKind.PUT_SIGNAL
    if remote_buf is None and remote_comp is None:
        return CommKind.RECV
    if remote_buf is None:
        raise FatalError("post_comm: direction=IN with a remote completion "
                         "but no remote buffer is invalid (paper Table 1)")
    if remote_comp is None:
        return CommKind.GET
    # paper §4.3: "Due to the lack of support for RDMA read with
    # notification in the interconnects we have access to, LCI does not
    # implement the get with signal communication operation"
    raise NotImplementedError(
        "get with signal is not implemented (paper §4.3: no 'RDMA read "
        "with notification' support on target interconnects)")


def payload_nbytes(buf: Any) -> int:
    """Size of a message payload; supports buffer *lists* (paper §3.3.1)."""
    if buf is None:
        return 0
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return len(buf)
    if isinstance(buf, (list, tuple)):
        return sum(payload_nbytes(b) for b in buf)
    if hasattr(buf, "nbytes"):
        return int(buf.nbytes)
    return len(bytes(buf))


def _route_endpoint(runtime, endpoint, device, rank: int, size: int):
    """Resolve the device an op rides when posted through an endpoint."""
    if endpoint is None:
        return device
    if device is not None:
        raise FatalError("post_comm: pass endpoint= or device=, not both "
                         "(the endpoint's stripe policy picks the device)")
    if endpoint.runtime is not runtime:
        raise FatalError(f"post_comm: endpoint {endpoint.name!r} belongs to "
                         f"rank {endpoint.runtime.rank}, not rank "
                         f"{runtime.rank}")
    return endpoint.select_device(rank=rank, size=size)


@off
def post_comm(runtime, direction: Direction, rank: int, buf: Any,
              local_comp=None, *, tag: int = 0, size: Optional[int] = None,
              remote_buf=None, remote_comp=None, device=None, endpoint=None,
              matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
              allow_retry: bool = True, user_context: Any = None) -> Status:
    """Generic posting operation; dispatches on Table 1 and hands the
    descriptor to the runtime's device path.  ``endpoint=`` routes the op
    through a striped device bundle instead of a raw device."""
    kind = classify(direction, remote_buf, remote_comp)
    nbytes = size if size is not None else payload_nbytes(buf)
    device = _route_endpoint(runtime, endpoint, device, rank, nbytes)
    return runtime._post(kind=kind, rank=rank, buf=buf, tag=tag,
                         size=nbytes,
                         local_comp=local_comp, remote_buf=remote_buf,
                         remote_comp=remote_comp, device=device,
                         matching_policy=matching_policy,
                         allow_retry=allow_retry, user_context=user_context)


# -- derived operations (sugar over post_comm; each has an OFF `.x`) --------

@off
def post_send(runtime, rank: int, buf: Any, size: Optional[int] = None,
              tag: int = 0, local_comp=None, *, device=None, endpoint=None,
              matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
              allow_retry: bool = True) -> Status:
    return post_comm(runtime, Direction.OUT, rank, buf, local_comp,
                     tag=tag, size=size, device=device, endpoint=endpoint,
                     matching_policy=matching_policy,
                     allow_retry=allow_retry)


@off
def post_recv(runtime, rank: int, buf: Any, size: Optional[int] = None,
              tag: int = 0, local_comp=None, *, device=None, endpoint=None,
              matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
              allow_retry: bool = True) -> Status:
    return post_comm(runtime, Direction.IN, rank, buf, local_comp,
                     tag=tag, size=size, device=device, endpoint=endpoint,
                     matching_policy=matching_policy,
                     allow_retry=allow_retry)


@off
def post_am(runtime, rank: int, buf: Any, size: Optional[int] = None,
            local_comp=None, remote_comp=None, *, tag: int = 0, device=None,
            endpoint=None, allow_retry: bool = True) -> Status:
    if remote_comp is None:
        raise FatalError("post_am requires a remote completion handle")
    return post_comm(runtime, Direction.OUT, rank, buf, local_comp,
                     tag=tag, size=size, remote_comp=remote_comp,
                     device=device, endpoint=endpoint,
                     allow_retry=allow_retry)


@off
def post_put(runtime, rank: int, buf: Any, remote_buf=None,
             size: Optional[int] = None, local_comp=None, remote_comp=None,
             *, tag: int = 0, device=None, endpoint=None,
             allow_retry: bool = True) -> Status:
    if remote_buf is None:
        raise FatalError("post_put requires a remote buffer")
    return post_comm(runtime, Direction.OUT, rank, buf, local_comp,
                     tag=tag, size=size, remote_buf=remote_buf,
                     remote_comp=remote_comp, device=device,
                     endpoint=endpoint, allow_retry=allow_retry)


@off
def post_get(runtime, rank: int, buf: Any, remote_buf=None,
             size: Optional[int] = None, local_comp=None, remote_comp=None,
             *, tag: int = 0, device=None, endpoint=None,
             allow_retry: bool = True) -> Status:
    if remote_buf is None:
        raise FatalError("post_get requires a remote buffer")
    return post_comm(runtime, Direction.IN, rank, buf, local_comp,
                     tag=tag, size=size, remote_buf=remote_buf,
                     remote_comp=remote_comp, device=device,
                     endpoint=endpoint, allow_retry=allow_retry)


# OFF variants under the paper's names
post_comm_x = post_comm.x
post_send_x = post_send.x
post_recv_x = post_recv.x
post_am_x = post_am.x
post_put_x = post_put.x
post_get_x = post_get.x
