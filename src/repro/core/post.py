"""``post_comm`` — the unified communication posting operation (paper §3.2.4).

"LCI offers a generic communication posting operation, post_comm.  This
operation takes the target rank, the local buffer, the message size, and
the local completion object as positional arguments.  It takes a wide range
of optional arguments, among which the most important ones include the
direction, the remote buffer, and the remote completion object."

Table 1 of the paper, implemented verbatim by :func:`post_comm`:

    ======== ============ ================ ===========================
    direction remote buf   remote comp      meaning
    ======== ============ ================ ===========================
    OUT       none         none             send
    OUT       none         specified        active message
    OUT       specified    none             RMA put
    OUT       specified    specified        RMA put with signal
    IN        none         none             receive
    IN        none         specified        (invalid)
    IN        specified    none             RMA get
    IN        specified    specified        RMA get with signal (not
                                            implemented — mirrors paper §4.3)
    ======== ============ ================ ===========================

The five derived operations (``post_send/recv/am/put/get``) are "just
syntactic sugar for post_comm with the optional arguments set to the
corresponding values", each with an OFF ``_x`` variant.

Posting is endpoint-centric: every operation accepts ``endpoint=`` (an
:class:`~repro.core.progress.endpoint.Endpoint`), which routes the op onto
the endpoint's striped device bundle via its stripe policy — equivalent to
the :meth:`Endpoint.post_send`-style sugar, but available on the generic
``post_comm`` and on deferred OFF builders
(``post_send_x(...).endpoint(ep)``), which is how completion-graph comm
nodes ride endpoints.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional, Sequence

from .matching import MatchingPolicy
from .off import OffBuilder, off
from .status import FatalError, Status


class Direction(enum.Enum):
    OUT = "out"
    IN = "in"


class CommKind(enum.Enum):
    SEND = "send"
    AM = "am"
    PUT = "put"
    PUT_SIGNAL = "put_signal"
    RECV = "recv"
    GET = "get"
    GET_SIGNAL = "get_signal"


def classify(direction: Direction, remote_buf, remote_comp) -> CommKind:
    """Table-1 dispatch; raises on the invalid / unimplemented rows."""
    if direction == Direction.OUT:
        if remote_buf is None and remote_comp is None:
            return CommKind.SEND
        if remote_buf is None:
            return CommKind.AM
        if remote_comp is None:
            return CommKind.PUT
        return CommKind.PUT_SIGNAL
    if remote_buf is None and remote_comp is None:
        return CommKind.RECV
    if remote_buf is None:
        raise FatalError("post_comm: direction=IN with a remote completion "
                         "but no remote buffer is invalid (paper Table 1)")
    if remote_comp is None:
        return CommKind.GET
    # paper §4.3: "Due to the lack of support for RDMA read with
    # notification in the interconnects we have access to, LCI does not
    # implement the get with signal communication operation"
    raise NotImplementedError(
        "get with signal is not implemented (paper §4.3: no 'RDMA read "
        "with notification' support on target interconnects)")


def payload_nbytes(buf: Any) -> int:
    """Size of a message payload; supports buffer *lists* (paper §3.3.1)."""
    if buf is None:
        return 0
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return len(buf)
    if isinstance(buf, (list, tuple)):
        return sum(payload_nbytes(b) for b in buf)
    if hasattr(buf, "nbytes"):
        return int(buf.nbytes)
    return len(bytes(buf))


def _route_endpoint(runtime, endpoint, device, rank: int, size: int):
    """Resolve the device an op rides when posted through an endpoint."""
    if endpoint is None:
        return device
    if device is not None:
        raise FatalError("post_comm: pass endpoint= or device=, not both "
                         "(the endpoint's stripe policy picks the device)")
    if endpoint.runtime is not runtime:
        raise FatalError(f"post_comm: endpoint {endpoint.name!r} belongs to "
                         f"rank {endpoint.runtime.rank}, not rank "
                         f"{runtime.rank}")
    return endpoint.select_device(rank=rank, size=size)


@off
def post_comm(runtime, direction: Direction, rank: int, buf: Any,
              local_comp=None, *, tag: int = 0, size: Optional[int] = None,
              remote_buf=None, remote_comp=None, device=None, endpoint=None,
              matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
              allow_retry: bool = True, user_context: Any = None) -> Status:
    """Generic posting operation; dispatches on Table 1 and hands the
    descriptor to the runtime's device path.  ``endpoint=`` routes the op
    through a striped device bundle instead of a raw device."""
    kind = classify(direction, remote_buf, remote_comp)
    nbytes = size if size is not None else payload_nbytes(buf)
    device = _route_endpoint(runtime, endpoint, device, rank, nbytes)
    return runtime._post(kind=kind, rank=rank, buf=buf, tag=tag,
                         size=nbytes,
                         local_comp=local_comp, remote_buf=remote_buf,
                         remote_comp=remote_comp, device=device,
                         matching_policy=matching_policy,
                         allow_retry=allow_retry, user_context=user_context)


# -- derived operations (sugar over post_comm; each has an OFF `.x`) --------

@off
def post_send(runtime, rank: int, buf: Any, size: Optional[int] = None,
              tag: int = 0, local_comp=None, *, device=None, endpoint=None,
              matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
              allow_retry: bool = True) -> Status:
    return post_comm(runtime, Direction.OUT, rank, buf, local_comp,
                     tag=tag, size=size, device=device, endpoint=endpoint,
                     matching_policy=matching_policy,
                     allow_retry=allow_retry)


@off
def post_recv(runtime, rank: int, buf: Any, size: Optional[int] = None,
              tag: int = 0, local_comp=None, *, device=None, endpoint=None,
              matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
              allow_retry: bool = True) -> Status:
    return post_comm(runtime, Direction.IN, rank, buf, local_comp,
                     tag=tag, size=size, device=device, endpoint=endpoint,
                     matching_policy=matching_policy,
                     allow_retry=allow_retry)


@off
def post_am(runtime, rank: int, buf: Any, size: Optional[int] = None,
            local_comp=None, remote_comp=None, *, tag: int = 0, device=None,
            endpoint=None, allow_retry: bool = True) -> Status:
    if remote_comp is None:
        raise FatalError("post_am requires a remote completion handle")
    return post_comm(runtime, Direction.OUT, rank, buf, local_comp,
                     tag=tag, size=size, remote_comp=remote_comp,
                     device=device, endpoint=endpoint,
                     allow_retry=allow_retry)


@off
def post_put(runtime, rank: int, buf: Any, remote_buf=None,
             size: Optional[int] = None, local_comp=None, remote_comp=None,
             *, tag: int = 0, device=None, endpoint=None,
             allow_retry: bool = True) -> Status:
    if remote_buf is None:
        raise FatalError("post_put requires a remote buffer")
    return post_comm(runtime, Direction.OUT, rank, buf, local_comp,
                     tag=tag, size=size, remote_buf=remote_buf,
                     remote_comp=remote_comp, device=device,
                     endpoint=endpoint, allow_retry=allow_retry)


@off
def post_get(runtime, rank: int, buf: Any, remote_buf=None,
             size: Optional[int] = None, local_comp=None, remote_comp=None,
             *, tag: int = 0, device=None, endpoint=None,
             allow_retry: bool = True) -> Status:
    if remote_buf is None:
        raise FatalError("post_get requires a remote buffer")
    return post_comm(runtime, Direction.IN, rank, buf, local_comp,
                     tag=tag, size=size, remote_buf=remote_buf,
                     remote_comp=remote_comp, device=device,
                     endpoint=endpoint, allow_retry=allow_retry)


# OFF variants under the paper's names
post_comm_x = post_comm.x
post_send_x = post_send.x
post_recv_x = post_recv.x
post_am_x = post_am.x
post_put_x = post_put.x
post_get_x = post_get.x


# ---------------------------------------------------------------------------
# Burst posting (paper §4.3) — coalesce K posts into per-device doorbells.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(slots=True)
class CommDesc:
    """One operation of a burst — ``post_comm``'s argument set as plain
    data, cheap enough to build by the thousand (slotted: descriptor
    construction is a measurable share of the scalar burst path).
    ``size=None`` is resolved to ``payload_nbytes(buf)`` by
    :func:`post_many`."""

    kind: CommKind
    rank: int
    buf: Any
    tag: int = 0
    size: Optional[int] = None
    local_comp: Any = None
    remote_buf: Any = None
    remote_comp: Any = None
    matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG
    allow_retry: bool = True
    user_context: Any = None


_BUILDER_KINDS = {"post_send": CommKind.SEND, "post_recv": CommKind.RECV,
                  "post_am": CommKind.AM, "post_put": CommKind.PUT,
                  "post_get": CommKind.GET}


def _desc_of_builder(b: OffBuilder):
    """Lower an unfired ``post_*_x`` builder to (runtime, endpoint, device,
    CommDesc) so a batch can group it with its peers."""
    name = b._fn.__name__
    remote_buf = b.get("remote_buf")
    remote_comp = b.get("remote_comp")
    if name == "post_comm":
        kind = classify(b.get("direction"), remote_buf, remote_comp)
    elif name in _BUILDER_KINDS:
        kind = _BUILDER_KINDS[name]
        if kind == CommKind.AM and remote_comp is None:
            raise FatalError("post_am requires a remote completion handle")
        if kind in (CommKind.PUT, CommKind.GET) and remote_buf is None:
            raise FatalError(f"{name} requires a remote buffer")
        if kind == CommKind.PUT and remote_comp is not None:
            kind = CommKind.PUT_SIGNAL
    else:
        raise FatalError(f"cannot batch {name!r}: only post_* operations "
                         "ride doorbells")
    runtime = b.get("runtime")
    if runtime is None:
        raise FatalError(f"{name}_x builder is missing its runtime")
    desc = CommDesc(kind=kind, rank=b.get("rank"), buf=b.get("buf"),
                    tag=b.get("tag", 0), size=b.get("size"),
                    local_comp=b.get("local_comp"), remote_buf=remote_buf,
                    remote_comp=remote_comp,
                    matching_policy=b.get("matching_policy",
                                          MatchingPolicy.RANK_TAG),
                    allow_retry=b.get("allow_retry", True),
                    user_context=b.get("user_context"))
    return runtime, b.get("endpoint"), b.get("device"), desc


def post_many(runtime, ops: Sequence, *, endpoint=None, device=None
              ) -> List[Status]:
    """Burst posting: post a sequence of operations (:class:`CommDesc`
    descriptors or unfired ``post_*_x`` builders) as coalesced per-device
    doorbells — one packet-pool ``get_n``, one stacked payload copy, one
    ``fabric.push_burst``, one telemetry bump per doorbell, instead of one
    of each per message (paper §4.3's batching insight at the device
    boundary).

    Ops are grouped by the device they resolve to (``endpoint=`` stripes
    each op exactly like scalar posting; a builder's own ``.endpoint()`` /
    ``.device()`` wins over the defaults).  Within a device group order is
    preserved and failure is prefix-accept: once one op retries, every
    later op of that group retries too, so per-stream FIFO survives a
    doorbell split.  Returns one Status per op, in input order."""
    n = len(ops)
    if endpoint is None:
        # plain-descriptor fast path: no endpoint striping means every op
        # rides ONE device — the group/resolve machinery below would
        # discover exactly that, one dict probe and list append per op.
        # The window-sized bursts of the mt hot loop live here.
        for op in ops:
            if isinstance(op, OffBuilder):
                break
            if op.size is None:
                op.size = payload_nbytes(op.buf)
        else:
            return runtime.engine.post_burst(
                ops if isinstance(ops, list) else list(ops),
                device or runtime.default_device)
    resolved = []                        # (device, desc) per op
    _MISS = object()
    burst_devs: dict[int, Any] = {}      # per-endpoint whole-burst device
    for op in ops:
        if isinstance(op, OffBuilder):
            rt_op, ep, dv, desc = _desc_of_builder(op)
            if rt_op is not runtime:
                raise FatalError("post_many: every op must ride the "
                                 "calling runtime")
            if ep is None and dv is None:   # no routing bound on the builder
                ep, dv = endpoint, device
        else:
            desc = op
            ep, dv = endpoint, device
        if desc.size is None:
            desc.size = payload_nbytes(desc.buf)
        if ep is not None:
            if dv is not None:
                raise FatalError("post_many: pass endpoint= or device=, "
                                 "not both")
            cached = burst_devs.get(id(ep), _MISS)
            if cached is _MISS:
                if ep.runtime is not runtime:   # validate once per endpoint
                    raise FatalError(
                        f"post_many: endpoint {ep.name!r} belongs to rank "
                        f"{ep.runtime.rank}, not rank {runtime.rank}")
                # round-robin endpoints stripe per doorbell, not per op
                # (Endpoint.select_burst_device): the batch's first op
                # fixes one device for the whole burst; by_peer/by_size
                # cache None and keep per-op selection
                cached = ep.select_burst_device(rank=desc.rank,
                                                size=desc.size)
                burst_devs[id(ep)] = cached
            dev = cached if cached is not None else \
                ep.select_device(rank=desc.rank, size=desc.size)
        else:
            dev = dv or runtime.default_device
        resolved.append((dev, desc))

    # group by device, preserving in-group (stream) order
    groups: dict[int, tuple[Any, List[int]]] = {}
    for i, (dev, _) in enumerate(resolved):
        entry = groups.get(id(dev))
        if entry is None:
            groups[id(dev)] = (dev, [i])
        else:
            entry[1].append(i)
    statuses: List[Optional[Status]] = [None] * n
    for dev, idxs in groups.values():
        sts = runtime.engine.post_burst([resolved[i][1] for i in idxs], dev)
        for i, st in zip(idxs, sts):
            statuses[i] = st
    return statuses


class PostBatch:
    """A doorbell under construction: collect deferred ops, then ``flush``.

    The OFF spelling builds one incrementally —
    ``batch = post_send_x(rt, peer, buf).endpoint(ep).batch()`` starts it,
    further ``.batch(batch)`` calls append, ``batch.flush()`` rings the
    doorbell(s) and returns the per-op statuses (input order).  ``add``
    also takes :class:`CommDesc` descriptors directly.  The batch is
    reusable after ``flush``."""

    def __init__(self, runtime=None, *, endpoint=None, device=None):
        self.runtime = runtime
        self.endpoint = endpoint
        self.device = device
        self._ops: List[Any] = []

    def add(self, op) -> "PostBatch":
        if self.runtime is None and isinstance(op, OffBuilder):
            self.runtime = op.get("runtime")
        self._ops.append(op)
        return self

    def __len__(self) -> int:
        return len(self._ops)

    def flush(self) -> List[Status]:
        if self.runtime is None:
            raise FatalError("PostBatch.flush: no runtime (add an op or "
                             "construct with PostBatch(runtime))")
        ops, self._ops = self._ops, []
        if not ops:
            return []
        return post_many(self.runtime, ops, endpoint=self.endpoint,
                         device=self.device)
