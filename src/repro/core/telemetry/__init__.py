"""Unified observability plane (DESIGN.md §15).

One :class:`Telemetry` object per cluster (shared by every rank's
runtime unless a rank overrides ``telemetry_level``) bundles the three
storage layers and the level gate:

* :mod:`.counters` — the typed metric registry: per-thread-sharded
  counters and log2 histograms merged on read, plus *collectors* that
  fold the runtime's long-standing per-resource counters (device
  posts/pushes, protocol stats, pool/matching/lock telemetry) into the
  same snapshot, so one read surfaces everything.
* :mod:`.timers` — stage-scoped nesting spans over every hot path.
* :mod:`.trace` — the bounded event trace with Chrome export.

Levels compose upward (``off < counters < timers < trace``); the level
is an ordinary attribute (``telemetry_level``, env spelling
``REPRO_ATTR_TELEMETRY_LEVEL``) resolved through the four-layer chain.
``off`` is the contract the overhead gate enforces: every instrumented
call site pays one attribute read and a branch — ``span()`` returns the
:data:`~.timers.NULL_SPAN` singleton, ``add()`` returns immediately —
and the legacy counters (always on, they predate this layer) remain the
only bookkeeping.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Tuple

from .counters import (Histogram, MetricRegistry, merge_counters,
                       merge_hists, merge_snapshots, quantile_bound,
                       record_burst_mix)
from .timers import NULL_SPAN, SPAN_PREFIX, Span, summarize_spans
from .trace import TraceBuffer

#: telemetry levels, cheapest first; each includes everything before it
LEVELS = ("off", "counters", "timers", "trace")


class Telemetry:
    """The attr-controlled observability hub for one cluster/runtime."""

    __slots__ = ("level", "counters_on", "timers_on", "trace_on",
                 "registry", "trace", "_depth", "_collectors")

    def __init__(self, level: str = "off", trace_capacity: int = 4096):
        if level not in LEVELS:
            raise ValueError(f"unknown telemetry level {level!r}; "
                             f"expected one of {LEVELS}")
        rank = LEVELS.index(level)
        self.level = level
        self.counters_on = rank >= 1
        self.timers_on = rank >= 2
        self.trace_on = rank >= 3
        self.registry = MetricRegistry()
        self.trace = TraceBuffer(trace_capacity) if self.trace_on else None
        self._depth = threading.local()
        # (prefix, fn) pairs; fn() -> {name: number}.  Many resources may
        # share a prefix (every device attaches under "device"); the
        # snapshot sums overlapping keys, which is the aggregation the
        # BENCH block wants.
        self._collectors: List[Tuple[str, object]] = []

    # -- write side (hot paths branch on the *_on booleans) ------------------
    def span(self, stage: str):
        """A stage-scoped timer context manager; the NULL_SPAN singleton
        when timers are off (the zero-allocation fast path)."""
        if not self.timers_on:
            return NULL_SPAN
        return Span(self, stage)

    def add(self, name: str, n: int = 1) -> None:
        if self.counters_on:
            self.registry.add(name, n)

    def observe(self, name: str, value: int) -> None:
        if self.counters_on:
            self.registry.observe(name, value)

    # -- unification ---------------------------------------------------------
    def attach(self, prefix: str, fn) -> None:
        """Fold a legacy counter source into every snapshot: ``fn()``
        returns ``{name: number}``, surfaced as ``<prefix>.<name>`` and
        summed across sources sharing the prefix."""
        self._collectors.append((prefix, fn))

    def snapshot(self) -> Dict:
        """The raw, mergeable telemetry document:
        ``{"level", "counters", "spans"}`` — registry shards merged,
        collectors sampled, span histograms keyed by stage name."""
        raw = self.registry.snapshot()
        counters = dict(raw["counters"])
        for prefix, fn in self._collectors:
            for name, value in fn().items():
                if not isinstance(value, (int, float)):
                    continue
                key = f"{prefix}.{name}"
                counters[key] = counters.get(key, 0) + value
        spans = {name[len(SPAN_PREFIX):]: h
                 for name, h in raw["hists"].items()
                 if name.startswith(SPAN_PREFIX)}
        return {"level": self.level, "counters": counters, "spans": spans}

    # -- export --------------------------------------------------------------
    def chrome_trace(self, pid: int = 0) -> Dict:
        if self.trace is None:
            return {"traceEvents": [], "displayTimeUnit": "ms"}
        return self.trace.chrome_trace(pid)

    def export_trace(self, path: str, pid: int = 0) -> str:
        """Dump the Chrome ``trace_event`` JSON; returns ``path``."""
        import json
        with open(path, "w") as f:
            json.dump(self.chrome_trace(pid), f)
        return path

    def __repr__(self) -> str:
        return f"Telemetry(level={self.level!r})"


#: the shared do-nothing instance resources fall back to when their
#: owner never wired telemetry (directly-constructed pools, engines...)
NULL_TELEMETRY = Telemetry("off")


def render_block(snapshot: Dict) -> Dict:
    """Render a raw snapshot into the BENCH-JSON ``telemetry`` block:
    merged counters plus summarized stage timers (count/total/p50/p99)."""
    return {"level": snapshot.get("level", "off"),
            "counters": {k: snapshot["counters"][k]
                         for k in sorted(snapshot.get("counters", {}))},
            "spans": summarize_spans(snapshot.get("spans", {}))}


__all__ = [
    "LEVELS", "NULL_SPAN", "NULL_TELEMETRY", "SPAN_PREFIX",
    "Histogram", "MetricRegistry", "Span", "Telemetry", "TraceBuffer",
    "merge_counters", "merge_hists", "merge_snapshots",
    "quantile_bound", "record_burst_mix", "render_block",
    "summarize_spans",
]
