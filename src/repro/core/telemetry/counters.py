"""Typed metric registry (DESIGN.md §15) — counters, gauges, histograms.

The registry is the unification point for the runtime's formerly
scattered telemetry (``Device.count_post``, ``rt.stats``, per-lock
contention counters, LCQ ``pop_yields``): hot paths increment
*per-thread shards* (a plain dict lookup, never a shared atomic or a
lock), and :meth:`MetricRegistry.snapshot` merges every shard on read.
A shard belongs to the thread that created it forever — dead threads'
shards stay in the merge, so no count is ever lost.

Histograms use fixed log2 buckets (bucket ``i`` holds values in
``[2^(i-1), 2^i)``), the classic HdrHistogram-lite shape: stage timers
record nanosecond durations and percentile *estimates* (p50/p99 as the
upper bound of the bucket where the cumulative count crosses the rank)
come out of 64 integers per stage — mergeable across threads, ranks and
processes by elementwise addition, which is exactly what the SPMD
fragment merge does.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional

#: log2 histogram buckets; bucket i counts values with bit_length() == i
#: (value 0 lands in bucket 0).  2^63 ns ≈ 292 years — nothing overflows.
N_BUCKETS = 64


class Histogram:
    """One log2 histogram: count, sum, and 64 bucket counters."""

    __slots__ = ("count", "sum", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0
        self.buckets: List[int] = [0] * N_BUCKETS

    def record(self, value: int) -> None:
        self.count += 1
        self.sum += value
        idx = value.bit_length() if value > 0 else 0
        self.buckets[idx if idx < N_BUCKETS else N_BUCKETS - 1] += 1

    def as_dict(self) -> Dict:
        """Sparse JSON form: only populated buckets travel."""
        return {"count": self.count, "sum": self.sum,
                "buckets": {str(i): n for i, n in enumerate(self.buckets)
                            if n}}


def quantile_bound(buckets: Dict[str, int], q: float) -> float:
    """Upper bound (in recorded units) of the bucket where the cumulative
    count crosses quantile ``q`` — the histogram percentile estimate."""
    total = sum(buckets.values())
    if total == 0:
        return 0.0
    rank = q * total
    seen = 0
    for i in sorted(buckets, key=int):
        seen += buckets[i]
        if seen >= rank:
            return float(2 ** int(i))
    return float(2 ** N_BUCKETS)


class _Shard:
    """One thread's private metric storage (uncontended by design)."""

    __slots__ = ("counters", "hists")

    def __init__(self):
        self.counters: Dict[str, int] = {}
        self.hists: Dict[str, Histogram] = {}


class MetricRegistry:
    """Per-thread-sharded counters + histograms, merged on read.

    Writers call :meth:`add` / :meth:`observe` (shard-local, no shared
    state touched); readers call :meth:`snapshot` (locks only the shard
    *list*, then reads each shard racily — a torn read costs at most the
    in-flight increment, never a lost one).  Gauges are read-side
    callables sampled at snapshot time.
    """

    def __init__(self):
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._shards: List[_Shard] = []
        self._gauges: Dict[str, object] = {}

    def _shard(self) -> _Shard:
        shard = getattr(self._tls, "shard", None)
        if shard is None:
            shard = _Shard()
            with self._lock:
                self._shards.append(shard)
            self._tls.shard = shard
        return shard

    # -- write side (hot path) ----------------------------------------------
    def add(self, name: str, n: int = 1) -> None:
        c = self._shard().counters
        c[name] = c.get(name, 0) + n

    def observe(self, name: str, value: int) -> None:
        hists = self._shard().hists
        h = hists.get(name)
        if h is None:
            h = hists[name] = Histogram()
        h.record(value)

    # -- read side -----------------------------------------------------------
    def register_gauge(self, name: str, fn) -> None:
        self._gauges[name] = fn

    def snapshot(self) -> Dict:
        """Merge every shard: ``{"counters": {...}, "hists": {...}}``."""
        with self._lock:
            shards = list(self._shards)
        counters: Dict[str, int] = {}
        hists: Dict[str, Dict] = {}
        for shard in shards:
            for name, n in list(shard.counters.items()):
                counters[name] = counters.get(name, 0) + n
            for name, h in list(shard.hists.items()):
                merged = hists.get(name)
                if merged is None:
                    hists[name] = h.as_dict()
                else:
                    hists[name] = merge_hists(merged, h.as_dict())
        for name, fn in self._gauges.items():
            counters[name] = fn()
        return {"counters": counters, "hists": hists}


def merge_hists(a: Dict, b: Dict) -> Dict:
    """Elementwise histogram merge (threads, ranks, processes alike)."""
    buckets = dict(a.get("buckets", {}))
    for i, n in b.get("buckets", {}).items():
        buckets[i] = buckets.get(i, 0) + n
    return {"count": a.get("count", 0) + b.get("count", 0),
            "sum": a.get("sum", 0) + b.get("sum", 0),
            "buckets": buckets}


def merge_counters(a: Dict, b: Dict) -> Dict:
    out = dict(a)
    for name, v in b.items():
        if isinstance(v, (int, float)) and isinstance(out.get(name), (int, float)):
            out[name] = out[name] + v
        else:
            out.setdefault(name, v)
    return out


def merge_snapshots(snaps: Iterable[Dict]) -> Dict:
    """Merge raw telemetry snapshots (one per rank/process): counters and
    span histograms add elementwise; the effective level is the deepest."""
    from . import LEVELS      # local import: avoid a cycle at module load
    out: Dict = {"level": "off", "counters": {}, "spans": {}}
    for snap in snaps:
        if not snap:
            continue
        if LEVELS.index(snap.get("level", "off")) > LEVELS.index(out["level"]):
            out["level"] = snap["level"]
        out["counters"] = merge_counters(out["counters"],
                                         snap.get("counters", {}))
        for stage, h in snap.get("spans", {}).items():
            prev = out["spans"].get(stage)
            out["spans"][stage] = merge_hists(prev, h) if prev else dict(h)
    return out


def record_burst_mix(stats, protos, sizes, n: int,
                     registry: Optional[MetricRegistry] = None) -> None:
    """The ONE per-protocol byte-accounting helper (satellite of the
    telemetry PR): record the accepted prefix ``[0, n)`` of a burst onto
    a :class:`~repro.core.protocol.ProtocolStats` — one ``record_many``
    bump per protocol class, identical arithmetic for the fused, scalar-
    burst and (via n=1) scalar paths, so the accounting can never drift
    between them.

    ``protos`` is a sequence of :class:`Protocol` (may be longer than
    ``n``); ``sizes`` is an int (uniform burst) or a per-row sequence.
    When ``registry`` is given the same totals are mirrored into the
    metric registry under ``proto.<name>.msgs`` / ``.bytes``.
    """
    if n <= 0:
        return
    first = protos[0]
    uniform = True
    for i in range(1, n):
        if protos[i] is not first:
            uniform = False
            break
    if uniform:
        total = sizes * n if isinstance(sizes, int) else sum(sizes[:n])
        stats.record_many(first, n, total)
        if registry is not None:
            registry.add(f"proto.{first.value}.msgs", n)
            registry.add(f"proto.{first.value}.bytes", total)
        return
    per: Dict = {}
    for i in range(n):
        proto = protos[i]
        size = sizes if isinstance(sizes, int) else sizes[i]
        msgs, nbytes = per.get(proto, (0, 0))
        per[proto] = (msgs + 1, nbytes + size)
    for proto, (msgs, nbytes) in per.items():
        stats.record_many(proto, msgs, nbytes)
        if registry is not None:
            registry.add(f"proto.{proto.value}.msgs", msgs)
            registry.add(f"proto.{proto.value}.bytes", nbytes)
