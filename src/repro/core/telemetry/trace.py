"""Ring-buffer event trace with Chrome ``trace_event`` export.

At ``telemetry_level=trace`` every stage span also emits one *complete*
event (name, start, duration) into a bounded per-thread ring: each
thread writes its own ring lock-free (the shard discipline of
:mod:`.counters`), capacity is ``trace_capacity`` events per thread, and
old events are overwritten in FIFO order — tracing a long run costs a
fixed amount of memory and keeps the *latest* window, which is the part
you want when something goes wrong at the end.

Export is the Chrome/Perfetto ``trace_event`` JSON array format
(load it at ``chrome://tracing`` or https://ui.perfetto.dev): one lane
(``tid``) per worker thread, one process group (``pid``) per rank, and
``"ph": "X"`` complete events whose stacking reconstructs span nesting.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, List, Optional


class _Ring:
    """One thread's bounded event ring (single-writer, wraparound)."""

    __slots__ = ("name", "capacity", "events", "next", "total")

    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = capacity
        self.events: List[Optional[tuple]] = [None] * capacity
        self.next = 0
        self.total = 0

    def emit(self, event: tuple) -> None:
        self.events[self.next] = event
        self.next = (self.next + 1) % self.capacity
        self.total += 1

    def ordered(self) -> List[tuple]:
        """Live events, oldest first (handles wraparound)."""
        if self.total < self.capacity:
            return [e for e in self.events[:self.next]]
        return ([e for e in self.events[self.next:]]
                + [e for e in self.events[:self.next]])


class TraceBuffer:
    """All threads' rings + the Chrome export."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("trace_capacity must be >= 1")
        self.capacity = capacity
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._rings: List[_Ring] = []

    def _ring(self) -> _Ring:
        ring = getattr(self._tls, "ring", None)
        if ring is None:
            ring = _Ring(threading.current_thread().name, self.capacity)
            with self._lock:
                self._rings.append(ring)
            self._tls.ring = ring
        return ring

    def emit(self, name: str, t0_ns: int, dur_ns: int,
             depth: int = 0) -> None:
        self._ring().emit((name, t0_ns, dur_ns, depth))

    def events(self) -> List[Dict]:
        """Merged view across lanes, sorted by start time."""
        with self._lock:
            rings = list(self._rings)
        out = []
        for ring in rings:
            for name, t0, dur, depth in ring.ordered():
                out.append({"name": name, "ts_ns": t0, "dur_ns": dur,
                            "lane": ring.name, "depth": depth})
        out.sort(key=lambda e: e["ts_ns"])
        return out

    def chrome_trace(self, pid: int = 0) -> Dict:
        """The ``trace_event`` document: one ``"X"`` (complete) event per
        span, lanes as ``tid``, timestamps in microseconds."""
        events = [{"name": e["name"], "ph": "X", "pid": pid,
                   "tid": e["lane"], "ts": e["ts_ns"] / 1e3,
                   "dur": e["dur_ns"] / 1e3} for e in self.events()]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str, pid: int = 0) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(pid), f)
        return path
