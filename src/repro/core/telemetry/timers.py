"""Stage-scoped timer spans (DESIGN.md §15).

``with tele.span("post_burst"): ...`` times one stage and records the
duration into the metric registry's log2 histogram for that stage
(key ``span:<stage>``).  Spans nest: each thread keeps a depth counter,
and at trace level every span also emits one complete event into the
trace ring, so the Chrome timeline shows the nesting as stacked slices.

The off-level fast path is the whole design: :meth:`Telemetry.span`
returns the module-level :data:`NULL_SPAN` singleton when timers are
disabled — no allocation, no clock read, nothing but one attribute
branch at the call site.

Stage taxonomy (what the hot paths are instrumented with):

========================  ====================================================
``post``                  one scalar ``ProgressEngine.post``
``post_burst``            one ``post_burst`` doorbell (fused or scalar runs)
``progress``              one full progress pass (outer span)
``progress.backlog``      backlog redelivery sub-stage
``progress.tx_sweep``     source-completion sweep sub-stage
``progress.drain``        fabric drain + reaction-chain sub-stage
``transport.push``        one fabric try_push/push_burst/push_packed
``transport.drain``       one fabric drain call (any backend)
``pool.get``              packet pool get/get_n (lane lock + steal)
``pool.put``              packet pool put/put_n
``match.now``             lock-free pre-posted-recv probe
``match.insert``          bucket-locked matching insert
``cq.pop``                one completion-queue pop
``signal``                one batched completion delivery (signal_many)
``worker.sweep``          one worker pass over its (engine, device) targets
``worker.nap``            one idle-backoff sleep in the worker loop
========================  ====================================================
"""
from __future__ import annotations

import time
from typing import Dict

from .counters import quantile_bound

#: histogram key prefix for stage spans
SPAN_PREFIX = "span:"


class _NullSpan:
    """The compiled-away span: a no-op context manager singleton."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One live stage measurement (constructed only when timers are on).
    The owning telemetry's ``_depth`` thread-local tracks nesting."""

    __slots__ = ("_tele", "stage", "_t0")

    def __init__(self, tele, stage: str):
        self._tele = tele
        self.stage = stage

    def __enter__(self):
        d = self._tele._depth
        d.depth = getattr(d, "depth", 0) + 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tele = self._tele
        tele._depth.depth -= 1
        dur = t1 - self._t0
        tele.registry.observe(SPAN_PREFIX + self.stage, dur)
        if tele.trace is not None:
            tele.trace.emit(self.stage, self._t0, dur,
                            depth=tele._depth.depth)
        return False


def summarize_spans(spans: Dict[str, Dict]) -> Dict[str, Dict]:
    """Render raw span histograms (``{stage: {count, sum, buckets}}``)
    into the BENCH-JSON summary: count, total time, and p50/p99 bucket
    estimates in microseconds; the sparse buckets ride along so merged
    documents stay re-mergeable."""
    out: Dict[str, Dict] = {}
    for stage, h in sorted(spans.items()):
        buckets = h.get("buckets", {})
        out[stage] = {
            "count": h.get("count", 0),
            "total_us": round(h.get("sum", 0) / 1e3, 3),
            "p50_us": round(quantile_bound(buckets, 0.50) / 1e3, 3),
            "p99_us": round(quantile_bound(buckets, 0.99) / 1e3, 3),
            "buckets": buckets,
        }
    return out
