"""Unified attribute system — layered, queryable fine-tuning controls.

The paper's abstract promises "flexible controls for incrementally
fine-tuning communication resources and runtime behavior"; in LCI that is
a uniform *attribute* mechanism: every resource is allocated with
named-argument overrides layered over environment and global defaults, and
every attribute is queryable at runtime (``get_attr_*``).  This module is
that mechanism for LCI-X:

* a **typed registry** (:data:`REGISTRY`) lists every tunable once — name,
  type, default, validation bounds, mutability, and which resource kinds
  expose it (``registry_table()`` renders the DESIGN.md §12 table);
* a **four-layer resolution chain** (:func:`resolve`), lowest to highest
  precedence::

      library defaults  →  REPRO_ATTR_* environment overrides
                        →  runtime-level config (LocalCluster(attrs=...),
                           explicit CommConfig fields)
                        →  per-resource named-argument overrides at alloc

  Every layer is validated with errors that *name the attribute*
  (:class:`AttrError`, both a ``ValueError`` and a ``FatalError``), so a
  bad knob fails at allocation time, not deep in a progress pass;
* an **introspection mixin** (:class:`AttrResource`) giving every resource
  object ``get_attr(name)`` / ``.attrs`` over both its resolved tunables
  and read-only *discovered* attributes (effective widths, contention
  telemetry) registered per instance with :meth:`AttrResource._export_attr`.

Mutability classes:

* ``alloc``    — settable through the full four-layer chain at alloc time;
* ``env``      — process-wide: only defaults and ``REPRO_ATTR_*`` apply
  (e.g. lock spin/backoff tuning, read at lock construction);
* ``readonly`` — runtime-discovered, never settable; served by per-instance
  providers.

Environment spelling: attribute ``eager_max_bytes`` reads
``REPRO_ATTR_EAGER_MAX_BYTES``.  Booleans accept 1/0/true/false/yes/no/
on/off.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import (Any, Callable, Dict, Iterable, Mapping, Optional,
                    Sequence, Tuple)

from .status import FatalError

ENV_PREFIX = "REPRO_ATTR_"

#: resolution layers, lowest to highest precedence
LAYERS = ("default", "env", "runtime", "resource")


class AttrError(FatalError, ValueError):
    """A bad attribute name or value.

    Subclasses both :class:`ValueError` (the natural Python spelling for
    argument validation) and :class:`~repro.core.status.FatalError` (the
    paper's fatal-error category, which pre-attr call sites already
    catch), so every historical ``except``/``pytest.raises`` keeps
    working.
    """


@dataclasses.dataclass(frozen=True)
class AttrSpec:
    """One registry row: everything there is to know about a tunable."""

    name: str
    type: type                      # int | float | bool | str
    default: Any
    mutability: str = "alloc"       # "alloc" | "env" | "readonly"
    resources: Tuple[str, ...] = ()
    doc: str = ""
    choices: Optional[Tuple[str, ...]] = None
    minimum: Optional[float] = None
    # meaning of the zero value for int attrs where 0 is a sentinel
    # ("unbounded", "auto", "derive"); purely documentation
    zero_means: Optional[str] = None

    @property
    def env_var(self) -> str:
        return ENV_PREFIX + self.name.upper()

    # -- parsing / validation ------------------------------------------------
    def parse(self, raw: str) -> Any:
        """Parse an environment-variable string into the attr's type."""
        if self.type is bool:
            low = raw.strip().lower()
            if low in ("1", "true", "yes", "on"):
                return True
            if low in ("0", "false", "no", "off"):
                return False
            raise AttrError(
                f"attribute {self.name!r}: cannot parse {self.env_var}="
                f"{raw!r} as bool (use 1/0/true/false/yes/no/on/off)")
        try:
            return self.type(raw)
        except (TypeError, ValueError) as e:
            raise AttrError(
                f"attribute {self.name!r}: cannot parse {self.env_var}="
                f"{raw!r} as {self.type.__name__}") from e

    def validate(self, value: Any) -> Any:
        """Check (and canonicalize) one value; raises naming the attr."""
        if self.type is float and isinstance(value, int) \
                and not isinstance(value, bool):
            value = float(value)
        if self.type is str:
            # enum-ish objects (CommMode) canonicalize through .value
            value = getattr(value, "value", value)
        if not isinstance(value, self.type) or (
                self.type is int and isinstance(value, bool)):
            raise AttrError(
                f"attribute {self.name!r} expects {self.type.__name__}, "
                f"got {value!r} ({type(value).__name__})")
        if self.choices is not None and value not in self.choices:
            raise AttrError(
                f"attribute {self.name!r}: unknown value {value!r}; pick "
                f"from {list(self.choices)}")
        if self.minimum is not None and value < self.minimum:
            raise AttrError(
                f"attribute {self.name!r} must be >= {self.minimum}, "
                f"got {value!r}")
        return value


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

REGISTRY: Dict[str, AttrSpec] = {}

#: deprecated spellings accepted (with a DeprecationWarning) in attr
#: mappings — the pre-attr kwarg names CommConfig/alloc_* used to take
ALIASES: Dict[str, str] = {
    "inject_max_bytes": "eager_max_bytes",
    "bufcopy_max_bytes": "rdv_threshold",
    "capacity": "cq_capacity",
    "burst": "worker_burst",
}


def register_attr(name: str, type: type, default: Any, *,
                  mutability: str = "alloc",
                  resources: Sequence[str] = (), doc: str = "",
                  choices: Optional[Sequence[str]] = None,
                  minimum: Optional[float] = None,
                  zero_means: Optional[str] = None) -> AttrSpec:
    """Register one tunable; re-registration with identical fields is a
    no-op (module reloads), anything else is an error."""
    spec = AttrSpec(name=name, type=type, default=default,
                    mutability=mutability, resources=tuple(resources),
                    doc=doc,
                    choices=tuple(choices) if choices is not None else None,
                    minimum=minimum, zero_means=zero_means)
    old = REGISTRY.get(name)
    if old is not None and old != spec:
        raise AttrError(f"attribute {name!r} already registered with "
                        f"different spec")
    REGISTRY[name] = spec
    return spec


def get_spec(name: str) -> AttrSpec:
    spec = REGISTRY.get(name)
    if spec is None:
        raise AttrError(
            f"unknown attribute {name!r}; known attributes: "
            f"{sorted(REGISTRY)}")
    return spec


def canonical_name(name: str, *, warn: bool = True) -> str:
    """Map a (possibly deprecated) spelling onto the canonical attr name."""
    if name in ALIASES:
        if warn:
            warnings.warn(
                f"attribute spelling {name!r} is deprecated; use "
                f"{ALIASES[name]!r}", DeprecationWarning, stacklevel=3)
        return ALIASES[name]
    return name


def _canonicalize(mapping: Optional[Mapping[str, Any]],
                  *, warn: bool = True) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for key, value in (mapping or {}).items():
        out[canonical_name(key, warn=warn)] = value
    return out


# -- the stock attribute set (DESIGN.md §12 table) --------------------------
# runtime-wide protocol / resource-geometry knobs (CommConfig's fields)
register_attr("mode", str, "lci_dedicated",
              resources=("runtime", "cluster"),
              choices=("bsp", "lci_shared", "lci_dedicated"),
              doc="collective schedule mode (paper §5.2 evaluation axes)")
register_attr("n_channels", int, 4, minimum=1,
              resources=("runtime", "cluster", "device"),
              doc="chunk-streams per device (paper: resource replication)")
register_attr("eager_max_bytes", int, 64 * 1024, minimum=0,
              resources=("runtime", "cluster"),
              doc="largest payload sent through the inject (eager "
                  "descriptor) protocol")
register_attr("rdv_threshold", int, 2 * 1024 * 1024, minimum=0,
              resources=("runtime", "cluster"),
              doc="largest payload staged through buffer-copy packets; "
                  "above this the zero-copy rendezvous protocol engages")
register_attr("wire_bf16", bool, False,
              resources=("runtime", "cluster"),
              doc="compress float32 payloads to bf16 on the wire (fused "
                  "doorbell copy; delivered payloads are restored to f32) "
                  "and cast reduce-ring accumulators to bf16 per hop")
register_attr("doorbell_fused", bool, True,
              resources=("runtime", "cluster"),
              doc="fuse eager doorbells into packed single-descriptor "
                  "bursts (one stage-copy-push per doorbell); off = the "
                  "per-op scalar-burst data plane (DESIGN.md §13)")
register_attr("fused_min_burst", int, 4, minimum=2,
              resources=("runtime", "cluster"),
              doc="smallest run of uniform eager ops worth packing into "
                  "a fused doorbell; shorter runs ride the scalar-burst "
                  "path")
register_attr("matching_buckets", int, 65536, minimum=1,
              resources=("runtime", "cluster", "matching"),
              doc="matching-engine hash buckets (paper §4.1.3 default)")
register_attr("matching_locks", int, 64, minimum=1,
              resources=("runtime", "cluster", "matching"),
              doc="bucket-lock stripes guarding matching inserts")
register_attr("packets_per_lane", int, 64, minimum=1,
              resources=("runtime", "cluster", "pool"),
              doc="pre-registered packets seeded per pool lane")
register_attr("packet_bytes", int, 8192, minimum=0, zero_means="id-only",
              resources=("runtime", "cluster", "pool"),
              doc="fixed packet size — the buffer-copy staging "
                  "granularity; 0 = id-only pool with no backing buffers "
                  "(the paged-KV allocator)")
register_attr("pool_lanes", int, 0, minimum=0, zero_means="derive",
              resources=("runtime", "cluster", "pool"),
              doc="packet-pool lanes; 0 derives max(1, n_channels)")
# fabric / cluster
register_attr("fabric_backend", str, "sim",
              resources=("cluster", "fabric"),
              choices=("sim", "shm", "socket"),
              doc="transport backend behind the Fabric surface "
                  "(DESIGN.md §14): sim = deterministic in-process "
                  "deques, shm = shared-memory SPSC rings between OS "
                  "processes, socket = Unix-domain stream fallback")
register_attr("shm_ring_bytes", int, 1 << 20, minimum=4096,
              resources=("cluster", "fabric"),
              doc="data-region capacity of each shm ring buffer; "
                  "payloads above half this spill to side files")
register_attr("fabric_depth", int, 4096, minimum=1,
              resources=("cluster", "fabric"),
              doc="bounded per-(dst, device) wire-queue depth; a full "
                  "queue is the paper's §4.4 back-pressure event")
register_attr("link_latency", float, 0.0, minimum=0.0,
              resources=("cluster", "fabric"),
              doc="simulated wire latency in seconds (0 = instant fabric)")
# chaos plane (DESIGN.md §16): fault injection on the drain side of any
# transport backend — all zero/off by default (no ChaosTransport wrap)
register_attr("chaos_seed", int, 0, minimum=0,
              resources=("cluster", "fabric"),
              doc="base seed for the per-stream fault RNGs — same seed, "
                  "same fault decision sequence per (dst, device)")
register_attr("chaos_drop", float, 0.0, minimum=0.0,
              resources=("cluster", "fabric"),
              doc="probability a retransmittable (seq-stamped) eager "
                  "message is dropped at drain time")
register_attr("chaos_dup", float, 0.0, minimum=0.0,
              resources=("cluster", "fabric"),
              doc="probability a drained eager message is delivered twice")
register_attr("chaos_reorder", float, 0.0, minimum=0.0,
              resources=("cluster", "fabric"),
              doc="probability a drained eager message is held back and "
                  "delivered after the following drain batch")
register_attr("chaos_delay_p", float, 0.0, minimum=0.0,
              resources=("cluster", "fabric"),
              doc="probability a drained message takes a latency spike "
                  "of chaos_delay_us before delivery")
register_attr("chaos_delay_us", float, 1000.0, minimum=0.0,
              resources=("cluster", "fabric"),
              doc="latency-spike magnitude (microseconds) for messages "
                  "selected by chaos_delay_p")
register_attr("chaos_kill_rank", int, -1, minimum=-1,
              resources=("cluster", "fabric"),
              doc="declare this rank dead at the transport: all traffic "
                  "from/to it is dropped (-1 = nobody dies)")
# reliability protocol (DESIGN.md §16): seq/epoch stamping, unacked
# windows, retransmit — 'auto' turns it on exactly when chaos faults are
# active, so the default data plane pays nothing
register_attr("reliability", str, "auto",
              resources=("runtime", "cluster"),
              choices=("auto", "on", "off"),
              doc="eager-send retransmit protocol: on = stamp (seq, "
                  "epoch), ack cumulatively, retransmit on timeout; "
                  "auto = on only when chaos fault attrs are nonzero")
register_attr("post_deadline_us", float, 0.0, minimum=0.0,
              zero_means="no deadline",
              resources=("runtime", "cluster"),
              doc="deadline for tracked posts (send ack / recv match): "
                  "past it the op completes with err(ERR_TIMEOUT)")
register_attr("retry_limit", int, 16, minimum=1,
              resources=("runtime", "cluster"),
              doc="retransmits per unacked send before it completes "
                  "with err(ERR_TIMEOUT)")
register_attr("retry_backoff", float, 2e-3, minimum=1e-6,
              resources=("runtime", "cluster"),
              doc="base seconds between retransmits of one unacked "
                  "send (doubles per retry, capped at 16x)")
# per-device queues
register_attr("backlog_capacity", int, 0, minimum=0, zero_means="unbounded",
              resources=("device",),
              doc="backlog-queue bound; push past it surfaces "
                  "retry(RETRY_BACKLOG_FULL)")
register_attr("cq_capacity", int, 0, minimum=0, zero_means="unbounded",
              resources=("comp", "device"),
              doc="completion-queue bound; a full queue rejects signals "
                  "with retry(RETRY_QUEUE_FULL)")
# endpoint shape
register_attr("n_devices", int, 1, minimum=1,
              resources=("endpoint",),
              doc="devices striped under one endpoint (effective width)")
register_attr("stripe", str, "round_robin",
              resources=("endpoint",),
              choices=("round_robin", "by_peer", "by_size"),
              doc="which device each posted op rides (DESIGN.md §8)")
register_attr("progress", str, "shared",
              resources=("endpoint",),
              choices=("shared", "dedicated", "workers"),
              doc="who drives the endpoint's devices (DESIGN.md §8)")
# serving subsystem (DESIGN.md §17): the continuous-batching engine's
# paged KV geometry, prefill chunking, and client drain shape
register_attr("kv_page_tokens", int, 16, minimum=1,
              resources=("serving",),
              doc="tokens per KV-cache page — the paged allocator's "
                  "fixed page size (the packet pool's packet_bytes, in "
                  "token units)")
register_attr("kv_slots", int, 8, minimum=1,
              resources=("serving",),
              doc="decode slots — concurrent requests resident in the "
                  "batch (JetStream-style slot array width)")
register_attr("kv_pages", int, 0, minimum=0, zero_means="8 * kv_slots",
              resources=("serving",),
              doc="total KV pages backing the slot array; 0 derives "
                  "8 pages per slot")
register_attr("kv_evict", str, "refuse",
              resources=("serving",),
              choices=("refuse", "preempt_longest"),
              doc="admission policy under page/slot exhaustion: refuse = "
                  "retry(RETRY_NOSLOT) and park in the backlog; "
                  "preempt_longest = evict the active request with the "
                  "largest footprint back to the backlog (its pages free, "
                  "its token stream resumes after re-prefill)")
register_attr("prefill_chunk", int, 32, minimum=1,
              resources=("serving",),
              doc="prompt tokens prefilled per completion-graph node — "
                  "bounds how long a long prompt can monopolize a tick "
                  "before decode interleaves")
register_attr("drain_workers", int, 2, minimum=1,
              resources=("serving",),
              doc="client-side ResultDrain worker threads popping the "
                  "thread-safe result CQ")
register_attr("max_batch", int, 0, minimum=0, zero_means="kv_slots",
              resources=("serving",),
              doc="admission bound on concurrently active requests; "
                  "0 derives kv_slots")
# progress workers
register_attr("n_workers", int, 0, minimum=0, zero_means="auto",
              resources=("endpoint", "workers"),
              doc="progress worker threads; 0 = one per device "
                  "(endpoint) / the pool default of 2")
register_attr("worker_burst", int, 64, minimum=0, zero_means="unbounded",
              resources=("endpoint", "workers"),
              doc="wire messages drained per progress-lock acquisition "
                  "(paper §4.3 burst progress)")
# observability (DESIGN.md §15)
register_attr("telemetry_level", str, "off",
              resources=("runtime", "cluster"),
              choices=("off", "counters", "timers", "trace"),
              doc="observability depth: counters = sharded metric "
                  "registry, timers = stage-scoped spans on every hot "
                  "path, trace = ring-buffer event trace with Chrome "
                  "export; off compiles the whole plane away")
register_attr("trace_capacity", int, 4096, minimum=1,
              resources=("runtime", "cluster"),
              doc="per-thread event capacity of the trace ring buffer "
                  "(old events are overwritten FIFO)")
# lock tuning — process-wide (read at lock construction): env mutability
register_attr("lock_spin_count", int, 4, minimum=0, mutability="env",
              resources=("lock",),
              doc="pure spins before a blocking acquire starts backing off")
register_attr("lock_backoff_max", float, 1e-3, minimum=0.0,
              mutability="env", resources=("lock",),
              doc="cap (seconds) of the blocking-acquire backoff sleep")

# read-only runtime-discovered attributes (served by per-instance
# providers; listed here so the registry table is the one place that
# names every attribute)
register_attr("width", int, None, mutability="readonly",
              resources=("endpoint", "device"),
              doc="effective width: devices in the bundle / channels on "
                  "the device")
register_attr("contention", dict, None, mutability="readonly",
              resources=("endpoint", "pool", "matching", "workers"),
              doc="aggregated lock telemetry (acquisitions/contentions/"
                  "spins)")
register_attr("free_packets", int, None, mutability="readonly",
              resources=("runtime", "pool"),
              doc="packets currently available across all pool lanes")
register_attr("in_flight", int, None, mutability="readonly",
              resources=("fabric",),
              doc="wire messages queued (including not-yet-drainable)")
register_attr("rank_me", int, None, mutability="readonly",
              resources=("runtime",), doc="this runtime's rank")
register_attr("rank_n", int, None, mutability="readonly",
              resources=("runtime", "cluster"),
              doc="total ranks in the cluster")
register_attr("telemetry", dict, None, mutability="readonly",
              resources=("runtime", "cluster", "device", "endpoint",
                         "pool", "matching", "comp", "workers", "fabric"),
              doc="live telemetry snapshot for this resource (merged "
                  "counters; runtimes/clusters add stage-span histograms)")


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

class ResolvedAttrs(Mapping):
    """The outcome of one resolution: value + provenance per attribute.

    Mapping-like over the resolved values; :meth:`source` reports which
    layer won (``default``/``env``/``runtime``/``resource``).
    """

    __slots__ = ("_values", "_sources")

    def __init__(self, values: Dict[str, Any], sources: Dict[str, str]):
        self._values = dict(values)
        self._sources = dict(sources)

    def __getitem__(self, name: str) -> Any:
        try:
            return self._values[name]
        except KeyError:
            raise AttrError(
                f"unknown attribute {name!r}; resolved attributes: "
                f"{sorted(self._values)}") from None

    def __contains__(self, name: object) -> bool:
        return name in self._values

    def __iter__(self):
        return iter(self._values)

    def __len__(self) -> int:
        return len(self._values)

    def source(self, name: str) -> str:
        self[name]                       # raise the naming error on unknown
        return self._sources[name]

    def as_dict(self) -> Dict[str, Any]:
        return dict(self._values)

    def echo(self) -> Dict[str, Dict[str, Any]]:
        """JSON-serializable {values, sources} block — every BENCH_*.json
        carries one so perf numbers always name their configuration."""
        return {"values": {k: _jsonable(v) for k, v in self._values.items()},
                "sources": dict(self._sources)}

    def merged(self, other: "ResolvedAttrs") -> "ResolvedAttrs":
        values = {**self._values, **other._values}
        sources = {**self._sources, **other._sources}
        return ResolvedAttrs(values, sources)

    def subset(self, names: Iterable[str]) -> "ResolvedAttrs":
        """Restrict to ``names`` (provenance preserved) — hands a child
        resource its slice of a wider resolution."""
        names = [n for n in names if n in self._values]
        return ResolvedAttrs({n: self._values[n] for n in names},
                             {n: self._sources[n] for n in names})

    def __repr__(self) -> str:
        rows = ", ".join(f"{k}={self._values[k]!r}<-{self._sources[k]}"
                         for k in sorted(self._values))
        return f"ResolvedAttrs({rows})"


def _jsonable(v: Any) -> Any:
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return getattr(v, "value", str(v))


def resolve(names: Optional[Iterable[str]] = None, *,
            runtime: Optional[Mapping[str, Any]] = None,
            overrides: Optional[Mapping[str, Any]] = None,
            env: Optional[Mapping[str, str]] = None) -> ResolvedAttrs:
    """Run the four-layer chain for ``names`` (default: every ``alloc``
    attr).

    ``runtime`` is the runtime-level config layer (e.g. the merged
    ``LocalCluster(attrs=...)`` mapping) — keys outside ``names`` are
    ignored (they belong to other resources) but must exist in the
    registry.  ``overrides`` are per-resource alloc-time arguments — every
    key must be in ``names`` (an unknown override is a caller bug and
    raises, naming the attribute).  ``env`` defaults to ``os.environ``;
    pass a mapping to make resolution hermetic (tests).
    """
    if names is None:
        names = [n for n, s in REGISTRY.items() if s.mutability == "alloc"]
    names = list(names)
    env = os.environ if env is None else env
    runtime = _canonicalize(runtime)
    overrides = _canonicalize(overrides)

    for key in runtime:
        if get_spec(key).mutability != "alloc":    # unknown -> AttrError
            raise AttrError(
                f"attribute {key!r} is {get_spec(key).mutability}; it "
                "cannot be set through the runtime config layer")
    for key in overrides:
        if key not in names:
            valid = sorted(n for n in names
                           if get_spec(n).mutability == "alloc")
            raise AttrError(
                f"unknown attribute override {key!r} for this resource; "
                f"valid attributes: {valid}")
        if get_spec(key).mutability != "alloc":
            raise AttrError(
                f"attribute {key!r} is {get_spec(key).mutability}; it "
                "cannot be overridden at alloc time")

    values: Dict[str, Any] = {}
    sources: Dict[str, str] = {}
    for name in names:
        spec = get_spec(name)
        if spec.mutability == "readonly":
            raise AttrError(
                f"attribute {name!r} is read-only (runtime-discovered); "
                "query it on a live resource with get_attr")
        value, source = spec.default, "default"
        raw = env.get(spec.env_var)
        if raw is not None:
            value, source = spec.parse(raw), "env"
        if spec.mutability == "alloc":
            if name in runtime:
                value, source = runtime[name], "runtime"
            if name in overrides:
                value, source = overrides[name], "resource"
        values[name] = spec.validate(value)
        sources[name] = source
    return ResolvedAttrs(values, sources)


_RESOLVE_ONE_MEMO: Dict[Tuple[str, Optional[str]], Any] = {}


def resolve_one(name: str, *, runtime: Optional[Mapping[str, Any]] = None,
                overrides: Optional[Mapping[str, Any]] = None,
                env: Optional[Mapping[str, str]] = None) -> Any:
    """Shorthand: run the chain for one attribute, return its value.

    The bare defaults+env form is memoized per (attr, raw env string) —
    it sits on construction paths that run hundreds of times per cluster
    (every :class:`TryLock` reads the lock tuning), and re-running the
    chain there only produces allocation churn.  A changed env var still
    takes effect (it changes the memo key)."""
    if runtime is None and overrides is None and env is None:
        key = (name, os.environ.get(ENV_PREFIX + name.upper()))
        if key not in _RESOLVE_ONE_MEMO:
            _RESOLVE_ONE_MEMO[key] = resolve([name])[name]
        return _RESOLVE_ONE_MEMO[key]
    return resolve([name], runtime=runtime, overrides=overrides, env=env)[name]


def resolved_from_values(values: Mapping[str, Any],
                         source: str = "resource") -> ResolvedAttrs:
    """Wrap already-final values (a directly-constructed resource that
    bypassed the chain) so introspection still works, with validation."""
    out: Dict[str, Any] = {}
    for key, value in _canonicalize(values, warn=False).items():
        out[key] = get_spec(key).validate(value)
    return ResolvedAttrs(out, {k: source for k in out})


# ---------------------------------------------------------------------------
# the introspection mixin
# ---------------------------------------------------------------------------

class AttrResource:
    """Gives a resource object the LCI ``get_attr`` surface.

    Call :meth:`_init_attrs` once during construction with the resolved
    tunables; register read-only discovered attributes (effective widths,
    telemetry) with :meth:`_export_attr`.  ``get_attr(name)`` serves
    providers first (they shadow nothing — readonly names are distinct by
    convention), then resolved tunables; ``.attrs`` snapshots everything.
    """

    _resolved_attrs: ResolvedAttrs
    _attr_providers: Dict[str, Callable[[], Any]]

    def _init_attrs(self, resolved: Optional[ResolvedAttrs] = None) -> None:
        # object.__setattr__: some resources are frozen dataclasses
        # (CommConfig, EndpointSpec) wiring this up from __post_init__
        object.__setattr__(self, "_resolved_attrs",
                           resolved or ResolvedAttrs({}, {}))
        object.__setattr__(self, "_attr_providers", {})

    def _ensure_attrs(self) -> None:
        """Lazy init: a subclass that never called :meth:`_init_attrs`
        (e.g. a bare completion object) still introspects cleanly."""
        if not hasattr(self, "_attr_providers"):
            self._init_attrs()

    def _export_attr(self, name: str, provider: Callable[[], Any]) -> None:
        """Register one read-only runtime-discovered attribute."""
        self._ensure_attrs()
        self._attr_providers[name] = provider

    def get_attr(self, name: str) -> Any:
        """Query one attribute by name (LCI's ``get_attr_*`` surface)."""
        self._ensure_attrs()
        name = canonical_name(name)
        provider = self._attr_providers.get(name)
        if provider is not None:
            return provider()
        if name in self._resolved_attrs:
            return self._resolved_attrs[name]
        raise AttrError(
            f"{type(self).__name__} has no attribute {name!r}; available: "
            f"{sorted([*self._resolved_attrs, *self._attr_providers])}")

    def attr_source(self, name: str) -> str:
        """Which layer produced an attribute ("discovered" = readonly)."""
        self._ensure_attrs()
        name = canonical_name(name)
        if name in self._attr_providers:
            return "discovered"
        return self._resolved_attrs.source(name)

    @property
    def attrs(self) -> Dict[str, Any]:
        """Snapshot of every attribute this resource exposes."""
        self._ensure_attrs()
        out = self._resolved_attrs.as_dict()
        for name, provider in self._attr_providers.items():
            out[name] = provider()
        return out

    def attrs_echo(self) -> Dict[str, Dict[str, Any]]:
        """The BENCH-JSON echo block: tunables with provenance, plus the
        discovered attributes under source "discovered"."""
        self._ensure_attrs()
        echo = self._resolved_attrs.echo()
        for name, provider in self._attr_providers.items():
            echo["values"][name] = _jsonable(provider())
            echo["sources"][name] = "discovered"
        return echo


def parse_attr_args(pairs: Iterable[str]) -> Dict[str, Any]:
    """Parse CLI ``name=value`` pairs into a validated attrs mapping
    (launchers' ``--attr`` flag).  Values parse like env overrides."""
    out: Dict[str, Any] = {}
    for pair in pairs:
        name, sep, raw = pair.partition("=")
        if not sep:
            raise AttrError(f"--attr expects name=value, got {pair!r}")
        spec = get_spec(canonical_name(name.strip()))
        out[spec.name] = spec.validate(spec.parse(raw.strip()))
    return out


# ---------------------------------------------------------------------------
# documentation helper
# ---------------------------------------------------------------------------

def registry_table() -> str:
    """Render the registry as the DESIGN.md §12 markdown table."""
    rows = ["| attribute | type | default | mutability | resources | "
            "meaning |",
            "|---|---|---|---|---|---|"]
    for name in sorted(REGISTRY):
        s = REGISTRY[name]
        default = repr(s.default)
        if s.zero_means:
            default += f" (0 = {s.zero_means})"
        doc = s.doc
        if s.choices:
            doc += f" — one of {'/'.join(s.choices)}"
        rows.append(f"| `{name}` | {s.type.__name__} | {default} | "
                    f"{s.mutability} | {', '.join(s.resources)} | {doc} |")
    return "\n".join(rows)
