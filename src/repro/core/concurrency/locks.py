"""Non-blocking try-locks (paper §4.1.1) with contention telemetry.

The paper: "LCI uses fine-grained non-blocking locks (try-locks) to
protect shared resources.  A thread that fails to acquire a lock does not
wait: it either returns a retry status to the user or moves on to other
work."  Blocking acquisition exists only as a fallback for paths that
cannot fail (e.g. a matching-engine insert), and even there it spins with
exponential backoff rather than parking the thread.

:class:`TryLock` is that lock, instrumented: every acquisition, failed
try, and backoff spin is counted, so benchmarks can emit the per-lock
contention telemetry the paper uses to argue the runtime is
threading-efficient (Figs 2/3).  ``reentrant=True`` backs the lock with
an RLock — used for the per-device progress lock, where a progress pass
may be re-entered by the same thread through a completion callback.
"""
from __future__ import annotations

import threading
import time

from .. import attrs as _attrs
from .atomics import AtomicCounter

# backoff schedule for the blocking fallback: a few pure spins (cheap,
# catches short critical sections), then sleeps doubling up to 1 ms.
# Attribute-tunable (env mutability: process-wide, read at construction):
# lock_spin_count / lock_backoff_max in the DESIGN.md §12 registry.
_BACKOFF_MIN = 1e-6


class TryLock:
    """A non-blocking lock with acquisition/contention counters.

    * ``try_acquire()`` — the paper's primary operation: never blocks,
      returns False immediately when the lock is held.
    * ``acquire()`` — spin-backoff blocking fallback for must-succeed
      paths; also the context-manager entry.
    * ``release()`` / context-manager exit.

    Counters: ``acquisitions`` (successful acquires, exact — only the
    holder increments), ``contentions`` (failed try-acquires, atomic),
    ``spins`` (backoff iterations inside blocking acquires, atomic).
    """

    def __init__(self, name: str = "lock", reentrant: bool = False,
                 spin_count: int = None, backoff_max: float = None):
        self.name = name
        self._lock = threading.RLock() if reentrant else threading.Lock()
        # spin/backoff tuning resolves through the attribute system
        # (default -> REPRO_ATTR_LOCK_*); explicit args win
        self.spin_count = (spin_count if spin_count is not None
                           else _attrs.resolve_one("lock_spin_count"))
        self.backoff_max = (backoff_max if backoff_max is not None
                            else _attrs.resolve_one("lock_backoff_max"))
        self.acquisitions = 0
        self._contentions = AtomicCounter()
        self._spins = AtomicCounter()

    @property
    def contentions(self) -> int:
        return self._contentions.load()

    @property
    def spins(self) -> int:
        return self._spins.load()

    def try_acquire(self) -> bool:
        """One non-blocking attempt; a failure is a counted contention."""
        if self._lock.acquire(blocking=False):
            self.acquisitions += 1
            return True
        self._contentions.fetch_add(1)
        return False

    def acquire(self) -> None:
        """Blocking fallback: spin, then exponential backoff."""
        if self._lock.acquire(blocking=False):
            self.acquisitions += 1
            return
        self._contentions.fetch_add(1)
        delay = _BACKOFF_MIN
        spins = 0
        while True:
            spins += 1
            if spins > self.spin_count:
                time.sleep(delay)
                delay = min(delay * 2, self.backoff_max)
            if self._lock.acquire(blocking=False):
                self._spins.fetch_add(spins)
                self.acquisitions += 1
                return

    def release(self) -> None:
        self._lock.release()

    def __enter__(self) -> "TryLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def stats(self) -> dict:
        """Per-lock telemetry row (benchmarks aggregate these)."""
        return {"name": self.name, "acquisitions": self.acquisitions,
                "contentions": self.contentions, "spins": self.spins}

    def __repr__(self) -> str:
        return (f"TryLock({self.name!r}, acq={self.acquisitions}, "
                f"contended={self.contentions})")


def aggregate_lock_stats(locks) -> dict:
    """Sum telemetry over a group of locks (one benchmark JSON cell)."""
    total = {"locks": 0, "acquisitions": 0, "contentions": 0, "spins": 0}
    for lk in locks:
        total["locks"] += 1
        total["acquisitions"] += lk.acquisitions
        total["contentions"] += lk.contentions
        total["spins"] += lk.spins
    return total
