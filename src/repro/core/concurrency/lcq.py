"""LCQ (paper §4.1.4) — a fixed-size FAA-ticket MPMC queue.

The paper ships two completion-queue backends: "one based on the
state-of-the-art LCRQ and the other on a hand-written Fetch-And-Add-based
fix-sized array".  :class:`LCQ` is the second one: a fixed-size slot array
with monotone head/tail ticket counters.  Each slot carries a sequence
number; a producer claims a ticket from ``tail`` (CAS-guarded FAA — the
CPython stand-in for the x86 ``lock xadd``/CAS pair, see
:mod:`.atomics`), writes its payload, and publishes by bumping the slot
sequence.  A consumer symmetrically claims from ``head``.  The sequence
numbers are what make the design safe for *multiple* producers and
consumers: a ticket holder can always tell whether its slot is still
occupied by a straggling peer from the previous lap.

Both operations are non-blocking, per the paper's discipline: a full
queue surfaces ``retry(RETRY_QUEUE_FULL)`` to the producer (the progress
engine parks the completion in the backlog) and an empty queue surfaces
``retry`` to the consumer — nothing ever blocks or is dropped.

:class:`ThreadSafeCompletionQueue` wraps an LCQ in the unified ``comp``
protocol so it is a drop-in, thread-safe replacement for the host
:class:`~repro.core.completion.CompletionQueue` — allocate one with
``Runtime.alloc_cq(threadsafe=True)`` when worker threads will signal or
drain it concurrently.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional

from ..completion import CompletionObject
from ..status import ErrorCode, Status, done, retry
from .atomics import AtomicCounter

_EMPTY = object()          # slot sentinel distinct from any user payload

# shared signal ack: Status is immutable, and signalers only ever branch
# on is_retry()/code — one object serves every accepted delivery instead
# of a constructor call per completion on the hot path
_ACCEPTED = done()

# pop-side liveness bound: when the queue *looks* non-empty (a producer
# claimed a ticket) but nothing is published yet, spin at most this many
# failed pops before yielding the core to the mid-ticket producer
_POP_SPIN_LIMIT = 16
_POP_YIELD_SLEEP = 1e-5


class _Slot:
    __slots__ = ("seq", "data")

    def __init__(self, seq: int):
        self.seq = seq
        self.data = _EMPTY


class LCQ:
    """Fixed-size FAA-ticket MPMC queue of arbitrary Python objects.

    ``push``/``pop`` return in-graph-style int statuses alongside their
    results so hot loops can branch cheaply; the completion-queue wrapper
    translates them into the ternary Status protocol.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError("LCQ capacity must be >= 1")
        self.capacity = capacity
        self._slots = [_Slot(i) for i in range(capacity)]
        self._head = AtomicCounter()
        self._tail = AtomicCounter()
        # telemetry: ticket races lost (the "contention" of a lock-free
        # structure — a CAS that failed and had to re-read)
        self.push_races = AtomicCounter()
        self.pop_races = AtomicCounter()

    def push(self, item: Any) -> bool:
        """Non-blocking enqueue; False when the queue is full."""
        cap = self.capacity
        while True:
            pos = self._tail.load()
            slot = self._slots[pos % cap]
            dif = slot.seq - pos
            if dif == 0:
                # slot free for this lap: claim the ticket
                if self._tail.compare_exchange(pos, pos + 1):
                    slot.data = item
                    slot.seq = pos + 1        # publish
                    return True
                self.push_races.fetch_add(1)  # lost the ticket race
            elif dif < 0:
                return False                  # a full lap behind: full
            # dif > 0: a racing producer claimed pos but the counter
            # already moved on — re-read the tail

    def push_many(self, items: List[Any]) -> int:
        """Bulk enqueue: claim a run of tickets with ONE tail CAS.

        Scans the free-slot prefix for this lap, then advances ``tail``
        by the whole run at once — K messages pay one ticket claim
        instead of K (the FAA-amortization the fused doorbells already
        apply to pool lanes and the fabric, here on the completion
        queue).  The scan-then-CAS is safe: a scanned-free slot can only
        change state via a producer *publish*, and publishing requires a
        ticket from the very CAS we are about to attempt — if any racing
        producer got in first, our CAS fails and we re-scan.

        Returns the number of items accepted (always a prefix; 0 when
        full).  A short count means the queue ran out of free slots —
        the caller retries the remainder, exactly like a failed
        ``push``."""
        cap = self.capacity
        n = len(items)
        while True:
            pos = self._tail.load()
            k = 0
            while k < n:
                slot = self._slots[(pos + k) % cap]
                if slot.seq != pos + k:
                    break
                k += 1
            if k == 0:
                if self._slots[pos % cap].seq - pos < 0:
                    return 0                  # a full lap behind: full
                continue                      # stale tail: re-read
            if self._tail.compare_exchange(pos, pos + k):
                for i in range(k):
                    slot = self._slots[(pos + i) % cap]
                    slot.data = items[i]
                    slot.seq = pos + i + 1    # publish
                return k
            self.push_races.fetch_add(1)

    def pop(self) -> tuple[Any, bool]:
        """Non-blocking dequeue; (None, False) when empty."""
        cap = self.capacity
        while True:
            pos = self._head.load()
            slot = self._slots[pos % cap]
            dif = slot.seq - (pos + 1)
            if dif == 0:
                if self._head.compare_exchange(pos, pos + 1):
                    item = slot.data
                    slot.data = _EMPTY
                    slot.seq = pos + cap      # free the slot for next lap
                    return item, True
                self.pop_races.fetch_add(1)
            elif dif < 0:
                return None, False            # nothing published yet: empty
            # dif > 0: re-read the head

    def pop_many(self, limit: int = 0) -> List[Any]:
        """Bulk dequeue: claim a run of published slots with ONE head
        CAS (mirror of :meth:`push_many`; same scan-then-CAS argument —
        a scanned-published slot can only be consumed via a head ticket,
        and a racing consumer fails our CAS).  Returns up to ``limit``
        items (all published when 0); ``[]`` when empty."""
        cap = self.capacity
        lim = min(limit, cap) if limit else cap
        while True:
            pos = self._head.load()
            k = 0
            while k < lim:
                slot = self._slots[(pos + k) % cap]
                if slot.seq != pos + k + 1:
                    break
                k += 1
            if k == 0:
                if self._slots[pos % cap].seq - (pos + 1) < 0:
                    return []                 # nothing published: empty
                continue                      # stale head: re-read
            if self._head.compare_exchange(pos, pos + k):
                out: List[Any] = []
                for i in range(k):
                    slot = self._slots[(pos + i) % cap]
                    out.append(slot.data)
                    slot.data = _EMPTY
                    slot.seq = pos + i + cap  # free for the next lap
                return out
            self.pop_races.fetch_add(1)

    def __len__(self) -> int:
        return max(0, self._tail.load() - self._head.load())

    @property
    def pushes(self) -> int:
        """Total accepted pushes (the tail ticket counter)."""
        return self._tail.load()

    @property
    def pops(self) -> int:
        return self._head.load()

    def __repr__(self) -> str:
        return f"LCQ(cap={self.capacity}, live={len(self)})"


class ThreadSafeCompletionQueue(CompletionObject):
    """The LCQ as a completion object — a thread-safe ``alloc_cq`` result.

    Same surface as the host :class:`~repro.core.completion.CompletionQueue`
    (``signal``/``pop``/``test``/``wait``/``len``), but every method is
    safe under concurrent signalers *and* concurrent poppers: the serving
    scheduler drains client CQs from worker threads through exactly this
    object.
    """

    def __init__(self, capacity: Optional[int] = None, resolved=None,
                 tele=None):
        self._q = LCQ(capacity or 4096)
        self.capacity = capacity
        self._pop_yields = AtomicCounter()
        from .. import attrs as _attrs
        from ..telemetry import NULL_TELEMETRY
        self.tele = tele if tele is not None else NULL_TELEMETRY
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"cq_capacity": capacity or 0}))
        self._export_attr("depth", lambda: len(self._q))
        self._export_attr("pop_yields", lambda: self.pop_yields)
        self._export_attr("threadsafe", lambda: True)
        self._export_attr("telemetry", self._telemetry_block)

    def _telemetry_block(self) -> dict:
        races = self.races()
        return {"level": self.tele.level,
                "counters": {"cq.pushes": self.pushes,
                             "cq.pops": self.pops,
                             "cq.depth": len(self._q),
                             "cq.pop_yields": self.pop_yields,
                             "cq.push_races": races["push_races"],
                             "cq.pop_races": races["pop_races"]}}

    def signal(self, status: Status) -> Status:
        if self._q.push(status):
            return _ACCEPTED
        return retry(ErrorCode.RETRY_QUEUE_FULL)

    def signal_many(self, statuses: List[Status]) -> List[Status]:
        """Bulk admission through :meth:`LCQ.push_many`: the whole burst
        claims its tickets with one tail CAS, and the ack statuses are a
        shared immutable ``done()`` — K completions, O(1) atomics and
        zero per-row constructions.  Acceptance stays a prefix (the LCQ
        accepts a free-slot prefix), matching the base contract."""
        n = self._q.push_many(statuses) if statuses else 0
        if n == len(statuses):
            return [_ACCEPTED] * n
        return ([_ACCEPTED] * n
                + [retry(ErrorCode.RETRY_QUEUE_FULL)] * (len(statuses) - n))

    def pop(self) -> Status:
        tele = self.tele
        if tele.timers_on:
            with tele.span("cq.pop"):
                return self._pop()
        return self._pop()

    def _pop(self) -> Status:
        item, ok = self._q.pop()
        if not ok:
            return retry(ErrorCode.RETRY_LOCKED)
        return item

    def pop_many(self, limit: int = 0) -> List[Status]:
        """Bulk drain through :meth:`LCQ.pop_many`: one head CAS claims
        every published completion (up to ``limit``).  ``[]`` when
        empty — the consumer-side mirror of :meth:`signal_many`."""
        return self._q.pop_many(limit)

    def test(self) -> tuple[bool, Optional[Status]]:
        """Non-destructive probe: under concurrency the front item may be
        popped by a peer between test() and pop() — ready=True only means
        the queue *was* non-empty."""
        return len(self._q) > 0, None

    def wait(self, progress=None, max_rounds: int = 100_000) -> Status:
        spins = 0
        while True:
            super().wait(progress, max_rounds)
            st = self.pop()
            if not st.is_retry():
                return st
            # pop failed even though test() saw the queue non-empty.
            # Either a concurrent popper won the race for that item, or —
            # under burst signaling — a producer holds a claimed-but-
            # unpublished ticket (len() counts the ticket, pop() sees an
            # unpublished slot).  In the latter case looping here would
            # busy-spin exactly as long as the producer stays descheduled,
            # so: bounded spin, then yield the core to let it publish.
            spins += 1
            if spins > _POP_SPIN_LIMIT:
                self._pop_yields.fetch_add(1)
                time.sleep(_POP_YIELD_SLEEP)

    @property
    def pushes(self) -> int:
        return self._q.pushes

    @property
    def pops(self) -> int:
        return self._q.pops

    @property
    def pop_yields(self) -> int:
        """Times a ``wait`` pop spun out against a mid-ticket producer
        and yielded (liveness telemetry for the spin-bound regression)."""
        return self._pop_yields.load()

    def races(self) -> dict:
        return {"push_races": self._q.push_races.load(),
                "pop_races": self._q.pop_races.load()}

    def __len__(self) -> int:
        return len(self._q)


def drain(cq, limit: int = 0) -> List[Status]:
    """Pop done-statuses until empty (or ``limit``); never blocks."""
    pop_many = getattr(cq, "pop_many", None)
    if pop_many is not None:                  # bulk claim: one head CAS
        return pop_many(limit)
    out: List[Status] = []
    while not limit or len(out) < limit:
        st = cq.pop()
        if st.is_retry():
            break
        out.append(st)
    return out
