"""Threading-efficient runtime primitives (paper §4.1–§4.2).

The paper's headline: a runtime "built on atomic data structures,
fine-grained non-blocking locks, and low-level network insights".  This
package is that machinery with real Python threads:

* :mod:`.locks`   — :class:`TryLock`, the non-blocking lock with
  contention counters and a spin-backoff blocking fallback (§4.1.1).
* :mod:`.atomics` — atomic counter / flag / bounded-credit primitives
  behind one lock-free-style API.
* :mod:`.lcq`     — the Fetch-And-Add fixed-size MPMC queue (§4.1.4) and
  the thread-safe completion-queue backend built on it.
* :mod:`.workers` — :class:`ProgressWorkerPool`: N threads driving
  progress engines through per-device try-locks (§4.2.3: a thread that
  fails the try-lock moves on).

The structures it hardens live next door: the packet pool's per-lane
deques with try-lock steal-half, the matching engine's per-bucket locks,
and the backlog queue's atomic empty flag.  DESIGN.md §10 maps which
structure holds which lock and where the GIL caveats apply.
"""
from .atomics import AtomicCounter, AtomicCredit, AtomicFlag
from .lcq import LCQ, ThreadSafeCompletionQueue, drain
from .locks import TryLock, aggregate_lock_stats
from .workers import ProgressWorkerPool

__all__ = [
    "AtomicCounter", "AtomicCredit", "AtomicFlag",
    "LCQ", "ThreadSafeCompletionQueue", "drain",
    "TryLock", "aggregate_lock_stats",
    "ProgressWorkerPool",
]
