"""Atomic primitives (paper §4.1) — counters, flags, bounded credits.

The paper's runtime is "built on atomic data structures": C++ atomics
(fetch-and-add tickets, test-and-set flags, compare-exchange loops) show
up in the completion queue (§4.1.4), the backlog queue's empty flag
(§4.1.5), and the MPMC registry (§4.1.1).  CPython has no public atomic
ints, so every primitive here presents the *lock-free-style API* (``load``
/ ``store`` / ``fetch_add`` / ``compare_exchange`` / ``test_and_set``)
while internally sequencing writers with one tiny ``threading.Lock`` per
object.  Reads are deliberately lock-free: under the GIL a plain attribute
read is atomic and always observes a fully written value, which is exactly
the paper's "write under a lock, read lock-free" MPMC-array discipline.

GIL caveat (see DESIGN.md §10): these objects provide *correctness*
(linearizable updates, exact counters), not hardware parallelism.  The
contention behaviour they expose — try-lock failure rates, FAA ticket
races — is real, because the GIL preempts between bytecodes.
"""
from __future__ import annotations

import threading


class AtomicCounter:
    """An atomic integer: FAA tickets, exact multi-writer telemetry.

    ``fetch_add`` returns the *old* value (the FAA ticket); ``add``
    returns the new one.  ``compare_exchange`` is the CAS used by the
    LCQ head/tail loops.
    """

    __slots__ = ("_value", "_lock")

    def __init__(self, value: int = 0):
        self._value = value
        self._lock = threading.Lock()

    def load(self) -> int:
        return self._value            # GIL: reads never tear

    @property
    def value(self) -> int:
        return self._value

    def store(self, value: int) -> None:
        with self._lock:
            self._value = value

    def fetch_add(self, n: int = 1) -> int:
        with self._lock:
            old = self._value
            self._value = old + n
            return old

    def add(self, n: int = 1) -> int:
        return self.fetch_add(n) + n

    def compare_exchange(self, expected: int, desired: int) -> bool:
        """CAS: if the value equals ``expected``, set ``desired``."""
        with self._lock:
            if self._value != expected:
                return False
            self._value = desired
            return True

    def __int__(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"AtomicCounter({self._value})"


class AtomicFlag:
    """Test-and-set flag — the backlog queue's §4.1.5 empty-flag analogue."""

    __slots__ = ("_set", "_lock")

    def __init__(self, init: bool = False):
        self._set = init
        self._lock = threading.Lock()

    def test_and_set(self) -> bool:
        """Set the flag; returns the *previous* value."""
        with self._lock:
            old = self._set
            self._set = True
            return old

    def clear(self) -> None:
        with self._lock:
            self._set = False

    def is_set(self) -> bool:
        return self._set              # lock-free read

    def __bool__(self) -> bool:
        return self._set

    def __repr__(self) -> str:
        return f"AtomicFlag({self._set})"


class AtomicCredit:
    """Bounded credit counter: non-blocking acquire against a capacity.

    The atomic analogue of a counting semaphore whose ``acquire`` never
    blocks — a full resource surfaces *retry* to the caller (the paper's
    back-pressure discipline) instead of a wait.  Used to bound
    completion-queue and backlog capacities under concurrent writers
    without a full lock around the data structure.
    """

    __slots__ = ("limit", "_used", "_lock")

    def __init__(self, limit: int):
        if limit < 1:
            raise ValueError("credit limit must be >= 1")
        self.limit = limit
        self._used = 0
        self._lock = threading.Lock()

    def try_acquire(self, n: int = 1) -> bool:
        with self._lock:
            if self._used + n > self.limit:
                return False
            self._used += n
            return True

    def release(self, n: int = 1) -> None:
        with self._lock:
            self._used = max(0, self._used - n)

    @property
    def used(self) -> int:
        return self._used

    @property
    def available(self) -> int:
        return self.limit - self._used

    def __repr__(self) -> str:
        return f"AtomicCredit({self._used}/{self.limit})"
