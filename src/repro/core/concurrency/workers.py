"""Multithreaded progress workers (paper §4.2.3).

The paper's discipline for driving progress from many threads: every
device carries a try-lock; any number of threads may call ``progress``,
and "a thread that fails the try-lock simply moves on" — to the next
device, or back to useful work — instead of waiting.  One device is
therefore never progressed by two threads at once (the engine's reaction
chain stays single-writer per device), yet progress work is *shared*:
whichever thread gets there first drains everyone's traffic, and the
others skip the redundant pass.

:class:`ProgressWorkerPool` packages that loop: N daemon threads sweep a
list of ``(engine, device)`` targets through
:meth:`~repro.core.progress.engine.ProgressEngine.try_progress`, with a
per-worker rotation offset so workers start on different devices, and an
idle backoff so a quiet fabric doesn't spin the GIL.  Construct one
directly, from a runtime/cluster, or implicitly through
``EndpointSpec(progress="workers", n_workers=K)``.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence, Tuple

from .. import attrs as _attrs
from ..attrs import AttrError
from ..status import FatalError
from ..telemetry import NULL_SPAN as _NO_SPAN
from ..telemetry import NULL_TELEMETRY
from .atomics import AtomicCounter, AtomicFlag
from .locks import aggregate_lock_stats

_IDLE_SLEEP_MIN = 1e-5
_IDLE_SLEEP_MAX = 1e-3


class ProgressWorkerPool(_attrs.AttrResource):
    """N threads cooperatively driving progress over a set of devices.

    ``targets`` is a sequence of ``(engine, device)`` pairs; a device may
    appear under at most one engine (the usual dedicated split) or many
    devices under one shared engine — the per-device try-lock makes both
    safe.  Lifecycle: ``start()`` spawns daemon workers, ``stop()`` joins
    them (with a timeout so a wedged worker fails fast instead of hanging
    the caller), and the pool is reusable after ``stop()``.
    """

    def __init__(self, targets: Sequence[Tuple[object, object]],
                 n_workers: int = 2, name: str = "workers",
                 burst: Optional[int] = None,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 tele=None):
        if burst is None:
            burst = _attrs.resolve_one("worker_burst")
        if n_workers < 1:
            raise AttrError(
                f"attribute 'n_workers' must be >= 1 for a worker pool, "
                f"got {n_workers}")
        if not targets:
            raise FatalError("worker pool needs at least one "
                             "(engine, device) target")
        if burst < 0:
            raise AttrError("attribute 'worker_burst' must be >= 0 "
                            f"(0 = unbounded drain), got {burst}")
        self.targets = list(targets)
        self.n_workers = n_workers
        self.name = name
        self.tele = tele if tele is not None else NULL_TELEMETRY
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"n_workers": n_workers, "worker_burst": burst}))
        self._export_attr("n_targets", lambda: len(self.targets))
        self._export_attr("running", lambda: self.running)
        self._export_attr("lock_skips", lambda: self.lock_skips.load())
        self._export_attr("idle_naps", lambda: self.idle_naps.load())
        self._export_attr("contention", lambda: aggregate_lock_stats(
            dev.progress_lock for _, dev in self.targets))
        self._export_attr("telemetry", self._telemetry_block)
        # wire messages drained per try-lock acquisition: bounds how long
        # one worker holds a device's progress lock (a busy stream is
        # swept in bursts, not monopolized), while still amortizing the
        # lock + backlog sweep across the whole burst (paper §4.3)
        self.burst = burst
        self._threads: List[threading.Thread] = []
        self._stop = AtomicFlag()
        # telemetry
        self.worker_passes = [AtomicCounter() for _ in range(n_workers)]
        self.lock_skips = AtomicCounter()    # try-lock failures -> moved on
        self.idle_naps = AtomicCounter()

    # -- construction helpers ------------------------------------------------
    @classmethod
    def for_runtime(cls, runtime, n_workers: int = 2,
                    name: Optional[str] = None,
                    burst: Optional[int] = None) -> "ProgressWorkerPool":
        """Workers over every device of one runtime, via its shared engine."""
        return cls([(runtime.engine, d) for d in runtime.devices],
                   n_workers, name or f"rank{runtime.rank}/workers",
                   burst=burst, tele=getattr(runtime, "tele", None))

    @classmethod
    def for_cluster(cls, cluster, n_workers: int = 2,
                    name: str = "cluster/workers",
                    burst: Optional[int] = None) -> "ProgressWorkerPool":
        """Workers over every device of every rank (thread-mode testbed)."""
        targets = [(rt.engine, d) for rt in cluster.runtimes
                   for d in rt.devices]
        return cls(targets, n_workers, name, burst=burst,
                   tele=getattr(cluster, "tele", None))

    # -- lifecycle -----------------------------------------------------------
    @property
    def running(self) -> bool:
        return bool(self._threads)

    def start(self) -> "ProgressWorkerPool":
        if self._threads:
            raise FatalError(f"worker pool {self.name!r} already started")
        self._stop.clear()
        self._threads = [
            threading.Thread(target=self._run, args=(w,), daemon=True,
                             name=f"{self.name}/{w}")
            for w in range(self.n_workers)
        ]
        for t in self._threads:
            t.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        """Signal and join all workers; a worker that fails to exit within
        ``timeout`` raises (deadlock should fail fast, not hang CI)."""
        if not self._threads:
            return
        self._stop.test_and_set()
        deadline = time.monotonic() + timeout
        stuck = []
        for t in self._threads:
            t.join(max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                stuck.append(t.name)
        self._threads = []
        if stuck:
            raise FatalError(f"progress workers failed to stop: {stuck}")

    def __enter__(self) -> "ProgressWorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- the worker loop -----------------------------------------------------
    def _run(self, wid: int) -> None:
        targets = self.targets
        n = len(targets)
        passes = self.worker_passes[wid]
        tele = self.tele
        delay = _IDLE_SLEEP_MIN
        while not self._stop.is_set():
            did = False
            # rotation offset decorrelates workers: worker w starts its
            # sweep w targets in, so two workers rarely hit the same
            # device's try-lock back to back
            with tele.span("worker.sweep") if tele.timers_on else _NO_SPAN:
                for i in range(n):
                    eng, dev = targets[(i + wid) % n]
                    r = eng.try_progress(dev, self.burst)
                    if r is None:
                        self.lock_skips.fetch_add(1)   # contended: move on
                    elif r:
                        passes.fetch_add(1)
                        did = True
            if did:
                delay = _IDLE_SLEEP_MIN
            else:
                self.idle_naps.fetch_add(1)
                with (tele.span("worker.nap") if tele.timers_on
                      else _NO_SPAN):
                    time.sleep(delay)              # quiet fabric: back off
                delay = min(delay * 2, _IDLE_SLEEP_MAX)

    # -- telemetry -----------------------------------------------------------
    def _telemetry_block(self) -> dict:
        return {"level": self.tele.level,
                "counters": {
                    "workers.passes": sum(c.load()
                                          for c in self.worker_passes),
                    "workers.lock_skips": self.lock_skips.load(),
                    "workers.idle_naps": self.idle_naps.load()}}

    def counters(self) -> dict:
        """Worker passes + the per-device progress-lock contention map."""
        return {
            "name": self.name,
            "n_workers": self.n_workers,
            "burst": self.burst,
            "worker_passes": [c.load() for c in self.worker_passes],
            "lock_skips": self.lock_skips.load(),
            "idle_naps": self.idle_naps.load(),
            "device_locks": aggregate_lock_stats(
                dev.progress_lock for _, dev in self.targets),
        }

    def __repr__(self) -> str:
        state = "running" if self.running else "stopped"
        return (f"ProgressWorkerPool({self.name!r}, n_workers="
                f"{self.n_workers}, targets={len(self.targets)}, {state})")
