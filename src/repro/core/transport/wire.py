"""The wire format — messages every transport backend carries.

:class:`WireMsg` is the unit of transfer between ranks: eager payloads,
rendezvous handshakes (RTS/CTS/RDMA), and RMA put/get all ride it.  A
:class:`PackedBurst` is one fused doorbell's wire image (DESIGN.md §13):
K eager payload rows packed into one 2-D byte matrix so the whole burst
weighs ``count`` messages but pays descriptor costs once.

These types used to live in ``repro.core.progress.fabric`` next to the
simulated fabric; the transport subsystem (DESIGN.md §14) hoists them
here so the shm and socket backends — and the stable binary codec in
:mod:`.codec` — share one definition.  ``progress.fabric`` re-exports
everything for compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import ml_dtypes
import numpy as np

from ..matching import MatchingPolicy


class WireKind:
    EAGER_SEND = "eager_send"      # send-recv eager payload
    EAGER_AM = "eager_am"          # active-message eager payload
    # fused doorbells (DESIGN.md §13): ONE descriptor carries a whole
    # burst's payloads as a packed 2-D byte array
    EAGER_PACKED_SEND = "eager_packed_send"
    EAGER_PACKED_AM = "eager_packed_am"
    RTS = "rts"                    # rendezvous request-to-send
    CTS = "cts"                    # rendezvous clear-to-send
    RDMA_PAYLOAD = "rdma_payload"  # rendezvous data movement (zero-copy)
    PUT = "put"                    # RMA put (optionally with signal)
    GET_REQ = "get_req"            # RMA get request
    GET_RESP = "get_resp"          # RMA get response
    ACK = "ack"                    # reliability cumulative ack (§16)


#: packed wire kinds — each such message weighs ``payload.count`` toward
#: the stream depth bound (and every message-counting telemetry)
PACKED_KINDS = frozenset((WireKind.EAGER_PACKED_SEND,
                          WireKind.EAGER_PACKED_AM))


@dataclasses.dataclass
class WireMsg:
    kind: str
    src: int
    dst: int
    tag: int = 0
    payload: Any = None
    size: int = 0
    rcomp: Optional[int] = None
    matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG
    # rendezvous bookkeeping
    op_id: int = -1                # source-side pending-op id
    remote_buf: Any = None         # (region_id, offset) for RMA
    device_index: int = 0          # which device stream this rides
    ready_at: float = 0.0          # wire-latency model: drainable after this
    # reliability protocol (DESIGN.md §16): per-(dst, device) stream
    # sequence number for retransmit/dedup; -1 = untracked control
    # traffic (rides the reliable connection, never chaos-faulted)
    seq: int = -1
    epoch: int = 0                 # bumps on elastic shrink / peer restart


def msg_weight(msg: WireMsg) -> int:
    """How many messages ``msg`` weighs toward depth accounting — a
    packed doorbell counts its row count, everything else counts 1."""
    if msg.kind in PACKED_KINDS:
        return msg.payload.count
    return 1


@dataclasses.dataclass
class PackedBurst:
    """One fused eager doorbell's wire image (DESIGN.md §13).

    The whole burst rides a single :class:`WireMsg` whose payload is this
    descriptor: ``data`` holds the K wire rows as one packed 2-D byte
    array (one stacked copy staged them), ``sizes[i]`` is row *i*'s
    delivered payload size in bytes, and ``tags[i]`` its message tag.
    ``wire_dtype == "bf16"`` marks rows carrying bf16-compressed float32
    payloads — :meth:`delivered_payloads` restores them to f32 bytes, so
    receivers observe flat uint8 arrays exactly like the scalar path.
    """

    data: np.ndarray               # (count, row_bytes) uint8 wire bytes
    sizes: np.ndarray              # (count,) delivered bytes per row
    tags: List[int]                # per-row message tags
    count: int
    wire_dtype: Optional[str] = None

    def prefix(self, n: int) -> "PackedBurst":
        """The first ``n`` rows — a fabric prefix-accept split point."""
        return PackedBurst(self.data[:n], self.sizes[:n], self.tags[:n],
                           n, self.wire_dtype)

    def delivered_payloads(self) -> List[np.ndarray]:
        """Per-row payload byte arrays as the receiver must observe them
        (bf16 rows decompressed back to float32 bytes in ONE vectorized
        cast for the whole burst)."""
        if self.wire_dtype == "bf16":
            # order="C": astype's default order='K' keeps a broadcast
            # row's degenerate strides, which the uint8 view rejects
            rows = (self.data.view(ml_dtypes.bfloat16)
                    .astype(np.float32, order="C").view(np.uint8))
        else:
            rows = self.data
        width = rows.shape[1]
        sizes = self.sizes
        if sizes.size and int(sizes[0]) == width \
                and bool((sizes == width).all()):
            return list(rows)              # uniform full-width: row views
        return [rows[i, :int(s)] for i, s in enumerate(sizes)]
