"""The ``sim`` backend — the deterministic in-process fabric.

The original simulated NIC/ICI: per ``(dst-rank, device-stream)`` bounded
FIFO deques in one address space.  A full queue surfaces ``retry`` — the
same back-pressure path a full ibv send queue triggers in the paper
(§4.4) — and the progress engine moves such requests through the backlog
queue.  Messages are keyed by the *sender's* device index, so each device
stream is an independent, ordered channel: replicating devices replicates
streams, which is exactly the paper's resource-replication story (§3.2.3).

This is the default backend for tests: no OS resources, byte-exact
determinism, and an optional latency model (``link_latency``) for the
multithreaded benchmarks.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .. import attrs as _attrs
from .base import Transport
from .wire import PACKED_KINDS, PackedBurst, WireMsg


class Fabric(Transport):
    """Bounded per-(dst, device) FIFO deques; the NIC send-queue stand-in.

    ``depth`` bounds each queue row-weighted — a packed doorbell occupies
    one deque slot but weighs ``payload.count`` messages.  ``latency``
    (seconds) models the wire: a pushed message only becomes drainable
    ``latency`` after its push; the default (0) keeps the historical
    instantly-visible behaviour.  Thread-safety per the Transport
    contract: streams are single-consumer, concurrent producers ride the
    GIL-atomic deque append, so the depth bound is approximate by at most
    the number of racing posters.
    """

    backend = "sim"

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 **_ignored):
        super().__init__(n_ranks, depth, latency, resolved)
        self._queues: Dict[Tuple[int, int], collections.deque] = {}
        # per-stream weight beyond len(queue): a packed doorbell occupies
        # one deque slot but weighs payload.count messages toward the
        # depth bound, so _extra holds sum(count - 1) per stream.  Same
        # approximate-under-races contract as the depth bound itself.
        self._extra: Dict[Tuple[int, int], int] = {}

    def _q(self, dst: int, device_index: int) -> collections.deque:
        return self._queues.setdefault((dst, device_index),
                                       collections.deque())

    def try_push(self, msg: WireMsg) -> bool:
        q = self._q(msg.dst, msg.device_index)
        if len(q) + self._extra.get((msg.dst, msg.device_index), 0) \
                >= self.depth:
            self._full_events.fetch_add(1)
            return False
        if self.latency:
            msg.ready_at = time.perf_counter() + self.latency
        q.append(msg)
        self._pushes.fetch_add(1)
        return True

    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        """One doorbell: push a burst of messages bound for the SAME
        ``(dst, device_index)`` stream.  Accepts the longest prefix that
        fits under the depth bound (never a subsequence — accepting
        message k+1 after rejecting k would break stream FIFO) and
        returns how many were accepted.  Per-burst costs are paid once:
        one queue lookup, one latency stamp, one deque extend, one
        telemetry FAA — the paper's §4.3 amortization at the device
        boundary."""
        if not msgs:
            return 0
        dst, didx = self.check_stream(msgs)
        q = self._q(dst, didx)
        n = min(len(msgs), max(0, self.depth - len(q)
                               - self._extra.get((dst, didx), 0)))
        if n < len(msgs):
            self._full_events.fetch_add(1)
        if n == 0:
            return 0
        accepted = msgs[:n]
        if self.latency:
            ready = time.perf_counter() + self.latency
            for m in accepted:
                m.ready_at = ready
        q.extend(accepted)
        self._pushes.fetch_add(n)
        return n

    def push_packed(self, msg: WireMsg) -> int:
        """Ring a fused doorbell: ONE descriptor whose :class:`PackedBurst`
        payload carries the whole burst.  The burst weighs ``count``
        messages toward the stream depth bound — split points are
        identical to pushing the rows through :meth:`push_burst` — and
        accepts the longest row prefix that fits (the rejected suffix is
        the caller's to retry).  Per-doorbell costs collapse to one queue
        lookup, one latency stamp, one append, one telemetry FAA.
        Returns the number of rows accepted."""
        burst: PackedBurst = msg.payload
        key = (msg.dst, msg.device_index)
        q = self._q(*key)
        n = min(burst.count,
                max(0, self.depth - len(q) - self._extra.get(key, 0)))
        if n < burst.count:
            self._full_events.fetch_add(1)
        if n == 0:
            return 0
        if n < burst.count:                  # prefix-accept split
            pb = burst.prefix(n)
            msg = dataclasses.replace(msg, payload=pb,
                                      size=int(pb.data.nbytes))
        if self.latency:
            msg.ready_at = time.perf_counter() + self.latency
        q.append(msg)
        if n > 1:
            self._extra[key] = self._extra.get(key, 0) + n - 1
        self._pushes.fetch_add(n)
        return n

    def ready(self, dst: int, device_index: int) -> bool:
        """Cheap unlocked readiness probe: is at least one message on
        this stream due for delivery?  The poll-before-lock doorbell
        check — idle progress passes branch on this instead of paying
        the lock + telemetry + drain machinery to discover nothing.
        Safe without the stream lock: a stale True costs one full pass,
        a stale False is indistinguishable from polling a hair earlier."""
        q = self._queues.get((dst, device_index))
        if not q:
            return False
        if not self.latency:
            return True
        try:
            return q[0].ready_at <= time.perf_counter()
        except IndexError:            # racing drain emptied the stream
            return False

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        """Pop ready messages from one stream.  ``limit`` bounds the
        burst *row-weighted* (``limit == 0`` = drain all): a packed
        doorbell counts its row count toward the cap but is popped whole
        — the limit is a burst bound, not a split point — so
        ``stream_depth`` drops by exactly the weight of what was
        returned.  ``limit < 0`` is an error."""
        if limit < 0:
            raise ValueError(f"drain: limit must be >= 0 (0 = drain all), "
                             f"got {limit}")
        q = self._q(dst, device_index)
        out: List[WireMsg] = []
        weight = 0
        budget = len(q)               # snapshot: never chase racing pushes
        now = time.perf_counter() if self.latency else 0.0
        while budget > 0 and q and (limit == 0 or weight < limit):
            if self.latency and q[0].ready_at > now:
                break                 # FIFO: stop at the first on-the-wire
            msg = q.popleft()
            out.append(msg)
            budget -= 1
            weight += (msg.payload.count if msg.kind in PACKED_KINDS else 1)
        # settle the packed-weight surplus — only streams that actually
        # carried fused doorbells pay the scan (scalar drains skip it)
        key = (dst, device_index)
        ex = self._extra.get(key)
        if ex:
            dec = sum(m.payload.count - 1 for m in out
                      if m.kind in PACKED_KINDS)
            if dec:
                self._extra[key] = ex - dec
        return out

    def stream_depth(self, dst: int, device_index: int) -> int:
        """Queued messages on one stream (including not-yet-drainable
        ones; a packed doorbell counts its row count) — the lock-free
        idle probe progress drivers use to skip a quiet device without
        paying for a full locked pass."""
        q = self._queues.get((dst, device_index))
        if q is None:
            return 0
        return len(q) + self._extra.get((dst, device_index), 0)

    def in_flight(self) -> int:
        """Total queued messages (including not-yet-drainable ones);
        packed doorbells count their row counts."""
        return (sum(len(q) for q in self._queues.values())
                + sum(self._extra.values()))

    def pending_to(self, dst: int) -> int:
        return sum(len(q) + self._extra.get(k, 0)
                   for k, q in self._queues.items() if k[0] == dst)

    def pending_streams(self, dst: int) -> List[int]:
        """Device-stream indices with traffic queued toward ``dst``."""
        return sorted(i for (d, i), q in self._queues.items()
                      if d == dst and q)
