"""Chaos plane: deterministic fault injection over any transport backend.

:class:`ChaosTransport` composes over a registered backend (sim / shm /
socket) and injects faults on the **drain side** — the consumer's view of
the wire — driven by the ``chaos_*`` attrs through the four-layer chain
(DESIGN.md §16).  Draining rather than pushing keeps the producer-side
contracts honest: prefix-accept, depth accounting, and back-pressure all
belong to the real backend; chaos only decides what the consumer
*observes*.

Fault model:

* **drop** — a drained message is discarded.  Only retransmittable
  messages (``seq >= 0``, i.e. reliability-stamped eager traffic) are
  eligible: control traffic (RTS/CTS/RDMA, RMA, ACKs) rides the reliable
  connection, exactly like verbs RC transports under packet loss.
* **dup** — a drained message is delivered twice (receiver-side dedup by
  seq must swallow the second copy).
* **reorder** — a drained message is held back and delivered after the
  *next* drain batch, scrambling stream FIFO.
* **delay** — a drained message matures only after ``chaos_delay_us``
  (a latency spike, not a loss).
* **rank death** — traffic from/to a killed rank vanishes; pushes toward
  it are swallowed-and-counted so producers never wedge on a corpse's
  full ring.

Held-back messages stay part of the observable queue: ``ready`` /
``stream_depth`` / ``in_flight`` include the stash, so quiesce loops and
idle fast paths keep driving progress until chaos lets go.

Every decision comes from a per-stream ``random.Random`` seeded from
``(chaos_seed, dst, device)`` — the same seed replays the same fault
sequence for a given drain pattern.  Per-fault counters attach to the
telemetry hub under the ``chaos.`` prefix.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

from random import Random

from .. import attrs as _attrs
from ..concurrency.atomics import AtomicCounter
from .base import Transport
from .wire import WireMsg, msg_weight

#: attrs the chaos plane resolves at cluster construction
CHAOS_ATTRS = ("chaos_seed", "chaos_drop", "chaos_dup", "chaos_reorder",
               "chaos_delay_p", "chaos_delay_us", "chaos_kill_rank")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Resolved fault-injection knobs (one per ``chaos_*`` attr)."""

    seed: int = 0
    drop: float = 0.0
    dup: float = 0.0
    reorder: float = 0.0
    delay_p: float = 0.0
    delay_us: float = 1000.0
    kill_rank: int = -1

    @classmethod
    def from_resolved(cls, r) -> "ChaosConfig":
        return cls(seed=r["chaos_seed"], drop=r["chaos_drop"],
                   dup=r["chaos_dup"], reorder=r["chaos_reorder"],
                   delay_p=r["chaos_delay_p"], delay_us=r["chaos_delay_us"],
                   kill_rank=r["chaos_kill_rank"])

    @property
    def active(self) -> bool:
        """Does this config fault anything at all?  Inactive configs
        skip the ChaosTransport wrap entirely (zero-cost default)."""
        return (self.drop > 0 or self.dup > 0 or self.reorder > 0
                or self.delay_p > 0 or self.kill_rank >= 0)

    @property
    def faults_messages(self) -> bool:
        return self.drop > 0 or self.dup > 0 or self.reorder > 0 \
            or self.delay_p > 0


class ChaosTransport(Transport):
    """Fault-injecting wrapper around a real backend (DESIGN.md §16).

    Producer-side calls delegate to the wrapped transport unchanged
    (except traffic involving a dead rank, which is swallowed).  The
    consumer-side ``drain`` filters the wrapped backend's batch through
    the fault model, keeping held-back messages in a per-stream stash
    that still counts toward every depth probe.
    """

    def __init__(self, inner: Transport, cfg: ChaosConfig,
                 resolved: Optional[_attrs.ResolvedAttrs] = None):
        self.inner = inner
        self.cfg = cfg
        self.backend = inner.backend          # instance shadow: echo inner
        # share the wrapped backend's resolved attrs: the wrapper must be
        # introspection-transparent (get_attr / attr_source / provenance
        # answer exactly as the real backend would)
        super().__init__(inner.n_ranks, inner.depth, inner.latency,
                         resolved=resolved or inner._resolved_attrs)
        self._dead: set = set()
        if cfg.kill_rank >= 0:
            self._dead.add(cfg.kill_rank)
        # per-(dst, device) fault state — mutated only by the stream's
        # single consumer (drain); probes read unlocked (stale is fine)
        self._rngs: Dict[Tuple[int, int], Random] = {}
        self._held: Dict[Tuple[int, int], List[WireMsg]] = {}
        self._delayed: Dict[Tuple[int, int],
                            List[Tuple[float, WireMsg]]] = {}
        self._stash_weight: Dict[Tuple[int, int], int] = {}
        # per-fault counters (atomic: dead-rank swallows happen on
        # producer threads)
        self.dropped = AtomicCounter()
        self.duped = AtomicCounter()
        self.reordered = AtomicCounter()
        self.delayed = AtomicCounter()
        self.dead_dropped = AtomicCounter()
        self._export_attr("chaos", self.fault_counters)

    # -- rank death ----------------------------------------------------------
    def kill(self, rank: int) -> None:
        """Declare ``rank`` dead at the wire from now on (idempotent)."""
        self._dead.add(rank)

    def rank_dead(self, rank: int) -> bool:
        return rank in self._dead

    @property
    def dead_ranks(self) -> frozenset:
        return frozenset(self._dead)

    def _swallow(self, msg: WireMsg) -> None:
        self.dead_dropped.add(msg_weight(msg))

    # -- producer side (delegated) -------------------------------------------
    def try_push(self, msg: WireMsg) -> bool:
        if self._dead and (msg.dst in self._dead or msg.src in self._dead):
            self._swallow(msg)
            return True
        return self.inner.try_push(msg)

    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        if self._dead and msgs and (msgs[0].dst in self._dead
                                    or msgs[0].src in self._dead):
            for m in msgs:
                self._swallow(m)
            return len(msgs)
        return self.inner.push_burst(msgs)

    def push_packed(self, msg: WireMsg) -> int:
        if self._dead and (msg.dst in self._dead or msg.src in self._dead):
            self._swallow(msg)
            return msg.payload.count
        return self.inner.push_packed(msg)

    # -- consumer side (the fault model) -------------------------------------
    def _rng(self, key: Tuple[int, int]) -> Random:
        rng = self._rngs.get(key)
        if rng is None:
            rng = self._rngs[key] = Random(
                (self.cfg.seed + 1) * 0x9E3779B1 ^ (key[0] << 16) ^ key[1])
        return rng

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        if dst in self._dead:
            # a corpse never drains; flush its streams so rings drain
            for m in self.inner.drain(dst, device_index, limit):
                self._swallow(m)
            return []
        key = (dst, device_index)
        batch = self.inner.drain(dst, device_index, limit)
        cfg = self.cfg
        out: List[WireMsg] = []
        stash_delta = 0
        # matured latency spikes deliver first — they are the oldest
        delayed = self._delayed.get(key)
        if delayed:
            now = time.monotonic()
            still: List[Tuple[float, WireMsg]] = []
            for due, m in delayed:
                if due <= now:
                    out.append(m)
                    stash_delta -= msg_weight(m)
                else:
                    still.append((due, m))
            self._delayed[key] = still
        prev_held = self._held.pop(key, [])
        new_held: List[WireMsg] = []
        rng = self._rng(key)
        for m in batch:
            if self._dead and m.src in self._dead:
                self._swallow(m)
                continue
            if m.seq < 0 or not cfg.faults_messages:
                out.append(m)                  # control traffic: reliable
                continue
            if cfg.drop and rng.random() < cfg.drop:
                self.dropped.add(1)
                continue
            if cfg.delay_p and rng.random() < cfg.delay_p:
                self._delayed.setdefault(key, []).append(
                    (time.monotonic() + cfg.delay_us * 1e-6, m))
                stash_delta += msg_weight(m)
                self.delayed.add(1)
                continue
            if cfg.reorder and rng.random() < cfg.reorder:
                new_held.append(m)
                stash_delta += msg_weight(m)
                self.reordered.add(1)
                continue
            out.append(m)
            if cfg.dup and rng.random() < cfg.dup:
                out.append(m)                  # receiver dedups by seq
                self.duped.add(1)
        # messages held back last drain land AFTER this batch (reordered)
        for m in prev_held:
            out.append(m)
            stash_delta -= msg_weight(m)
        if new_held:
            self._held[key] = new_held
        if stash_delta:
            self._stash_weight[key] = \
                self._stash_weight.get(key, 0) + stash_delta
        return out

    # -- probes (stash-aware) ------------------------------------------------
    def _stash_ready(self, key: Tuple[int, int]) -> bool:
        if self._held.get(key):
            return True
        delayed = self._delayed.get(key)
        if delayed:
            now = time.monotonic()
            return any(due <= now for due, _ in delayed)
        return False

    def ready(self, dst: int, device_index: int) -> bool:
        return self.inner.ready(dst, device_index) \
            or self._stash_ready((dst, device_index))

    def stream_depth(self, dst: int, device_index: int) -> int:
        return self.inner.stream_depth(dst, device_index) \
            + self._stash_weight.get((dst, device_index), 0)

    def in_flight(self) -> int:
        return self.inner.in_flight() + sum(self._stash_weight.values())

    def pending_to(self, dst: int) -> int:
        extra = sum(w for (d, _), w in self._stash_weight.items()
                    if d == dst)
        return self.inner.pending_to(dst) + extra

    def pending_streams(self, dst: int) -> List[int]:
        streams = set(self.inner.pending_streams(dst))
        streams.update(di for (d, di), w in self._stash_weight.items()
                       if d == dst and w > 0)
        return sorted(streams)

    # -- introspection transparency ------------------------------------------
    def get_attr(self, name: str):
        try:
            return super().get_attr(name)
        except _attrs.AttrError:
            return self.inner.get_attr(name)   # inner-exported readonly attrs

    def attr_source(self, name: str) -> str:
        try:
            return super().attr_source(name)
        except _attrs.AttrError:
            return self.inner.attr_source(name)

    @property
    def attrs(self) -> dict:
        out = dict(self.inner.attrs)
        out.update(_attrs.AttrResource.attrs.fget(self))
        return out

    # -- telemetry / lifecycle -----------------------------------------------
    def fault_counters(self) -> dict:
        return {"dropped": self.dropped.load(),
                "duped": self.duped.load(),
                "reordered": self.reordered.load(),
                "delayed": self.delayed.load(),
                "dead_dropped": self.dead_dropped.load(),
                "dead_ranks": sorted(self._dead)}

    def set_telemetry(self, tele) -> None:
        self.inner.set_telemetry(tele)
        self.tele = tele
        tele.attach("chaos", lambda: {
            k: v for k, v in self.fault_counters().items()
            if k != "dead_ranks"})

    def _telemetry_block(self) -> dict:
        block = self.inner._telemetry_block()
        block["counters"].update(
            {f"chaos.{k}": v for k, v in self.fault_counters().items()
             if k != "dead_ranks"})
        return block

    @property
    def pushes(self) -> int:
        return self.inner.pushes

    @property
    def full_events(self) -> int:
        return self.inner.full_events

    def close(self) -> None:
        self.inner.close()


def maybe_wrap_chaos(fabric: Transport, resolved) -> Transport:
    """Wrap ``fabric`` in a :class:`ChaosTransport` when the resolved
    ``chaos_*`` attrs fault anything; otherwise return it untouched."""
    cfg = ChaosConfig.from_resolved(resolved)
    if not cfg.active:
        return fabric
    return ChaosTransport(fabric, cfg)
