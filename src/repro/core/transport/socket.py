"""The ``socket`` backend — stream-socket fallback transport.

Where shm rings need a shared ``/dev/shm``, sockets only need a path (or
a host:port), so this backend is the cross-host fallback in the backend
matrix (DESIGN.md §14).  Unix-domain sockets with deterministic names::

    {session}/rank{r}.sock

Each rank process listens on its own socket; producers connect lazily on
first push and send length-prefixed codec frames (``[u32 len][frame]``).
The consumer side pumps ``accept``/``recv`` non-blocking from the probe
and drain calls themselves — no extra threads, matching the paper's
explicit-progress model (§3.2.4): the network only moves when somebody
calls progress.

Depth semantics differ from the in-memory backends where they must: the
producer cannot observe the remote queue, so the row-weighted ``depth``
bound applies to *locally buffered* (not-yet-flushed) messages per
stream — kernel socket buffers provide the rest of the back-pressure.
``ready``/``stream_depth`` report the local inbox after a non-blocking
pump, which keeps the unlocked idle-probe contract (a stale answer costs
one extra poll).  The wire-latency model is ignored: sockets have real
latency.  In solo mode (all ranks in one process) the backend still
works — the process owns every listener and messages loop through the
kernel — which keeps the backend testable single-process.
"""
from __future__ import annotations

import collections
import errno
import os
import socket as _socket
import struct
import tempfile
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .. import attrs as _attrs
from ..status import FatalError
from .base import Transport
from .codec import decode_msg, encode_msg
from .wire import PackedBurst, WireMsg, msg_weight

_LEN = struct.Struct("<I")
_SPMD_RANK_ENV = "REPRO_SPMD_RANK"
_SPMD_SESSION_ENV = "REPRO_SPMD_SESSION"
_CONNECT_RETRY_S = 5.0


class SocketTransport(Transport):
    """Unix-domain socket transport (see module docstring)."""

    backend = "socket"

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 rank: Optional[int] = None,
                 session: Optional[str] = None, **_ignored):
        super().__init__(n_ranks, depth, latency, resolved)
        env_rank = os.environ.get(_SPMD_RANK_ENV)
        self.rank = rank if rank is not None else (
            int(env_rank) if env_rank is not None else None)
        self.spmd = self.rank is not None
        session = session or os.environ.get(_SPMD_SESSION_ENV)
        if session:
            self._dir = (session if os.path.isabs(session)
                         else os.path.join(tempfile.gettempdir(), session))
            os.makedirs(self._dir, exist_ok=True)
            self._owns_dir = False
        else:
            self._dir = tempfile.mkdtemp(prefix="repro-sock-")
            self._owns_dir = True
        self._lock = threading.Lock()
        # listeners: my rank in spmd mode, every rank in solo mode
        self._listeners: Dict[int, _socket.socket] = {}
        for r in ([self.rank] if self.spmd else range(n_ranks)):
            srv = _socket.socket(_socket.AF_UNIX, _socket.SOCK_STREAM)
            srv.setblocking(False)
            srv.bind(self._sock_path(r))
            srv.listen(2 * n_ranks)
            self._listeners[r] = srv
        self._conns: List[Tuple[_socket.socket, bytearray]] = []
        self._out: Dict[int, _socket.socket] = {}       # dst -> client sock
        # producer-side local buffering, row-weighted per stream
        self._txq: Dict[int, collections.deque] = {}    # dst -> frames
        self._tx_weight: Dict[Tuple[int, int], int] = {}
        # consumer-side inbox per (dst, device) stream
        self._inbox: Dict[Tuple[int, int], collections.deque] = {}
        self._closed = False
        self._dead_dsts: set = set()
        self._tx_flushes = 0             # coalesced kernel sends
        self._tx_flush_frames = 0        # frames those sends carried
        self._export_attr("socket_session_dir", lambda: self._dir)
        self._export_attr("socket_dead_dsts",
                          lambda: sorted(self._dead_dsts))
        self._export_attr("socket_flush_batches", lambda: self._tx_flushes)
        self._export_attr("socket_flush_frames",
                          lambda: self._tx_flush_frames)

    def _sock_path(self, rank: int) -> str:
        return os.path.join(self._dir, f"rank{rank}.sock")

    # -- producer side ----------------------------------------------------
    def _connect(self, dst: int) -> _socket.socket:
        sock = self._out.get(dst)
        if sock is not None:
            return sock
        path = self._sock_path(dst)
        deadline = time.monotonic() + _CONNECT_RETRY_S
        while True:
            try:
                sock = _socket.socket(_socket.AF_UNIX, _socket.SOCK_STREAM)
                sock.connect(path)
                break
            except OSError:
                sock.close()
                if time.monotonic() >= deadline:
                    raise FatalError(
                        f"socket transport: cannot connect to rank {dst} "
                        f"at {path} after {_CONNECT_RETRY_S}s")
                time.sleep(0.01)         # peer may not have bound yet
        sock.setblocking(False)
        self._out[dst] = sock
        return sock

    def _mark_dst_dead(self, dst: int) -> None:
        """A hard socket error (EPIPE/ECONNRESET/refused) means the peer
        process is gone: its frames can never be delivered.  Drop the
        stream instead of wedging or crashing the survivor — rank-death
        *semantics* (ERR_PEER_DEAD on outstanding ops) belong to the
        failure detector and reliability layer above (DESIGN.md §16);
        the transport's job is merely to stay alive."""
        self._dead_dsts.add(dst)
        q = self._txq.pop(dst, None)
        if q:
            for _frame, key, weight in q:
                self._tx_weight[key] = self._tx_weight.get(key, 0) - weight
        sock = self._out.pop(dst, None)
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    #: frames coalesced per kernel send — bounds the join copy while a
    #: deep queue still drains in a handful of syscalls
    _FLUSH_COALESCE = 64

    def _flush(self, dst: int) -> None:
        """Push buffered frames into the kernel; stops when it would
        block (the kernel buffer is the real back-pressure).

        Frames queued for ``dst`` coalesce into one contiguous send — a
        writev-style flush: a burst of K messages costs one syscall, not
        K.  Depth accounting walks the accepted byte count afterwards:
        fully-sent frames pop and decrement their stream's row weight, a
        partially-sent head frame is re-sliced in place."""
        q = self._txq.get(dst)
        if not q:
            return
        try:
            sock = self._connect(dst)
        except FatalError:
            self._mark_dst_dead(dst)     # connect refused past the grace
            return
        while q:
            chunk = [q[i][0] for i in range(min(len(q),
                                               self._FLUSH_COALESCE))]
            blob = chunk[0] if len(chunk) == 1 else b"".join(chunk)
            try:
                sent = sock.send(blob)
            except OSError as e:
                if e.errno in (errno.EAGAIN, errno.EWOULDBLOCK):
                    return
                self._mark_dst_dead(dst)
                return
            self._tx_flushes += 1
            for frame in chunk:
                if sent >= len(frame):
                    sent -= len(frame)
                    _f, key, weight = q.popleft()
                    self._tx_weight[key] = \
                        self._tx_weight.get(key, 0) - weight
                    self._tx_flush_frames += 1
                else:
                    if sent:                   # partial head: re-slice
                        head, key, weight = q[0]
                        q[0] = (head[sent:], key, weight)
                    return

    def _enqueue(self, msg: WireMsg, weight: int) -> bool:
        if msg.dst in self._dead_dsts:
            # accepted-and-dropped: the peer is gone, back-pressure would
            # never clear; liveness for the caller, loss handled above
            self._pushes.fetch_add(weight)
            return True
        key = (msg.dst, msg.device_index)
        if self._tx_weight.get(key, 0) + weight > self.depth:
            self._flush(msg.dst)
            if self._tx_weight.get(key, 0) + weight > self.depth:
                self._full_events.fetch_add(1)
                return False
        body = encode_msg(msg)
        frame = _LEN.pack(len(body)) + body
        self._txq.setdefault(msg.dst, collections.deque()).append(
            (frame, key, weight))
        self._tx_weight[key] = self._tx_weight.get(key, 0) + weight
        self._pushes.fetch_add(weight)
        return True

    def try_push(self, msg: WireMsg) -> bool:
        with self._lock:
            ok = self._enqueue(msg, 1)
            if ok:
                self._flush(msg.dst)
            return ok

    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        if not msgs:
            return 0
        dst, _didx = self.check_stream(msgs)
        accepted = 0
        with self._lock:
            for m in msgs:
                if not self._enqueue(m, 1):
                    break                # prefix stands, never a subsequence
                accepted += 1
            self._flush(dst)
        return accepted

    def push_packed(self, msg: WireMsg) -> int:
        burst: PackedBurst = msg.payload
        key = (msg.dst, msg.device_index)
        with self._lock:
            self._flush(msg.dst)
            room = self.depth - self._tx_weight.get(key, 0)
            n = min(burst.count, max(0, room))
            if n < burst.count:
                self._full_events.fetch_add(1)
            if n == 0:
                return 0
            if n < burst.count:
                import dataclasses
                pb = burst.prefix(n)
                msg = dataclasses.replace(msg, payload=pb,
                                          size=int(pb.data.nbytes))
            if not self._enqueue(msg, n):
                return 0
            self._flush(msg.dst)
            return n

    # -- consumer side ----------------------------------------------------
    def _pump(self) -> None:
        """Non-blocking accept + recv + frame demux into the inbox."""
        for srv in self._listeners.values():
            while True:
                try:
                    conn, _ = srv.accept()
                except OSError:
                    break
                conn.setblocking(False)
                self._conns.append((conn, bytearray()))
        live: List[Tuple[_socket.socket, bytearray]] = []
        for conn, buf in self._conns:
            eof = False
            while True:
                try:
                    chunk = conn.recv(1 << 16)
                except OSError as e:
                    if e.errno in (errno.EAGAIN, errno.EWOULDBLOCK):
                        break
                    eof = True
                    break
                if not chunk:
                    eof = True
                    break
                buf.extend(chunk)
            off = 0
            while len(buf) - off >= _LEN.size:
                (nbytes,) = _LEN.unpack_from(buf, off)
                if len(buf) - off - _LEN.size < nbytes:
                    break
                msg, _ = decode_msg(
                    memoryview(buf)[off + _LEN.size:off + _LEN.size + nbytes])
                self._inbox.setdefault(
                    (msg.dst, msg.device_index),
                    collections.deque()).append(msg)
                off += _LEN.size + nbytes
            if off:
                del buf[:off]
            if not eof or buf:
                live.append((conn, buf))
            else:
                conn.close()
        self._conns = live
        # opportunistic producer flush: a pump is a progress call
        for dst in list(self._txq):
            if self._txq[dst]:
                try:
                    self._flush(dst)
                except FatalError:
                    pass                 # peer not up yet; next pump retries

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        if limit < 0:
            raise ValueError(f"drain: limit must be >= 0 (0 = drain all), "
                             f"got {limit}")
        out: List[WireMsg] = []
        weight = 0
        with self._lock:
            self._pump()
            q = self._inbox.get((dst, device_index))
            while q and (limit == 0 or weight < limit):
                msg = q.popleft()
                out.append(msg)
                weight += msg_weight(msg)
        return out

    def ready(self, dst: int, device_index: int) -> bool:
        return self.stream_depth(dst, device_index) > 0

    def stream_depth(self, dst: int, device_index: int) -> int:
        q = self._inbox.get((dst, device_index))
        if q:
            return sum(msg_weight(m) for m in q)
        # empty inbox: pump once so idle probes observe arrivals
        if self._lock.acquire(blocking=False):
            try:
                self._pump()
                q = self._inbox.get((dst, device_index))
            finally:
                self._lock.release()
        return sum(msg_weight(m) for m in q) if q else 0

    def in_flight(self) -> int:
        """Locally observable: inbox rows + not-yet-flushed tx rows."""
        return (sum(msg_weight(m) for q in self._inbox.values() for m in q)
                + sum(max(0, w) for w in self._tx_weight.values()))

    def pending_to(self, dst: int) -> int:
        return (sum(msg_weight(m) for (d, _i), q in self._inbox.items()
                    if d == dst for m in q)
                + sum(max(0, w) for (d, _i), w in self._tx_weight.items()
                      if d == dst))

    def pending_streams(self, dst: int) -> List[int]:
        with self._lock:
            self._pump()
            return sorted(i for (d, i), q in self._inbox.items()
                          if d == dst and q)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for sock in self._out.values():
            try:
                sock.close()
            except OSError:
                pass
        for conn, _buf in self._conns:
            try:
                conn.close()
            except OSError:
                pass
        for r, srv in self._listeners.items():
            try:
                srv.close()
            except OSError:
                pass
            try:
                os.unlink(self._sock_path(r))
            except OSError:
                pass
        if self._owns_dir:
            import shutil
            shutil.rmtree(self._dir, ignore_errors=True)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
