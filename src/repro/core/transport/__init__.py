"""Pluggable transport backends behind the Fabric surface (DESIGN.md §14).

One ABC (:class:`Transport`), three backends selected through the
``fabric_backend`` attr:

========  =====================  ==========================================
backend   processes              mechanism
========  =====================  ==========================================
sim       one (deterministic)    in-process bounded deques, latency model
shm       one host, N processes  SPSC shared-memory rings in ``/dev/shm``
socket    cross-host fallback    Unix-domain stream sockets, codec frames
========  =====================  ==========================================

Backends register lazily: importing this package never touches mmap or
socket machinery until a backend is actually constructed.
"""
from .base import (FABRIC_ATTRS, Transport, backend_class, make_transport,
                   register_backend)
from .chaos import (CHAOS_ATTRS, ChaosConfig, ChaosTransport,
                    maybe_wrap_chaos)
from .codec import CodecError, decode_msg, encode_msg
from .wire import PACKED_KINDS, PackedBurst, WireKind, WireMsg, msg_weight

__all__ = [
    "FABRIC_ATTRS",
    "Transport",
    "backend_class",
    "make_transport",
    "register_backend",
    "CHAOS_ATTRS",
    "ChaosConfig",
    "ChaosTransport",
    "maybe_wrap_chaos",
    "CodecError",
    "decode_msg",
    "encode_msg",
    "PACKED_KINDS",
    "PackedBurst",
    "WireKind",
    "WireMsg",
    "msg_weight",
]


def _load_sim():
    from .sim import Fabric
    return Fabric


def _load_shm():
    from .shm import ShmTransport
    return ShmTransport


def _load_socket():
    from .socket import SocketTransport
    return SocketTransport


register_backend("sim", _load_sim)
register_backend("shm", _load_shm)
register_backend("socket", _load_socket)
