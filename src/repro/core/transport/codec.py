"""Stable binary wire codec for :class:`WireMsg` / :class:`PackedBurst`.

The shm and socket backends move messages between OS processes, so the
in-memory dataclasses need a stable byte encoding.  Pickle would work
but pins the wire format to Python internals; instead the codec writes
an explicit little-endian layout (struct header + raw numpy row bytes)
that a reader in any process — or any language — can parse:

    [u16 magic][u8 version][u8 kind-code]
    [i32 src][i32 dst][i64 tag][i64 size][i64 op_id]
    [i32 rcomp+1 (0 = None)][u8 matching-code][i32 device_index]
    [f64 ready_at]
    [u8 remote-buf-tag][i64 region_id][i64 offset]      (tag 0 = None)
    [i64 seq][i32 epoch][u32 body-crc32]
    [u8 payload-tag][...payload body...]

Payload bodies by tag:

* ``_P_NONE``   — empty;
* ``_P_BYTES``  — ``[i64 nbytes][raw bytes]`` (flat uint8 eager payload);
* ``_P_INTS``   — ``[i32 n][n × i64]`` (tuple-of-ints, e.g. the CTS
  landing-count handshake payload);
* ``_P_PACKED`` — a :class:`PackedBurst`: ``[i32 count][i32 row_bytes]``
  ``[u8 wire-dtype-code][count × i64 sizes][count × i64 tags]``
  ``[count*row_bytes raw row bytes]``.

Round-tripping preserves delivered semantics exactly: flat uint8 views
come back as flat uint8 arrays, packed bursts keep their per-row sizes,
tags, and bf16 wire dtype (``delivered_payloads`` equality is the
contract the property test pins).  Broadcast stride-0 rows are
materialized on encode — the wire carries bytes, not strides.

Version 2 hardens the decoder for the chaos plane (DESIGN.md §16): the
header carries the reliability (seq, epoch) stamp plus a CRC32 over the
payload body, and every malformed input — truncated header or body, bad
magic, wrong version, unknown codes, negative lengths, bit-flipped
bytes — raises the typed :class:`CodecError` instead of leaking a bare
``struct.error`` / ``IndexError`` out of the parser.
"""
from __future__ import annotations

import struct
import zlib
from typing import Any, Tuple

import numpy as np

from ..matching import MatchingPolicy
from ..status import FatalError
from .wire import PackedBurst, WireKind, WireMsg


class CodecError(FatalError):
    """A wire frame failed to parse or verify — torn, foreign, or
    corrupted bytes.  Typed so transports can fail the *stream* (not the
    process) and the chaos tests can assert on it."""


_MAGIC = 0x5C17          # "LCI7"-ish; catches torn/foreign frames early
_VERSION = 2             # v2: (seq, epoch) stamp + body crc32

# stable one-byte codes; append only — never renumber a released code
_KIND_TO_CODE = {
    WireKind.EAGER_SEND: 1,
    WireKind.EAGER_AM: 2,
    WireKind.EAGER_PACKED_SEND: 3,
    WireKind.EAGER_PACKED_AM: 4,
    WireKind.RTS: 5,
    WireKind.CTS: 6,
    WireKind.RDMA_PAYLOAD: 7,
    WireKind.PUT: 8,
    WireKind.GET_REQ: 9,
    WireKind.GET_RESP: 10,
    WireKind.ACK: 11,
}
_CODE_TO_KIND = {v: k for k, v in _KIND_TO_CODE.items()}

_POLICY_TO_CODE = {
    MatchingPolicy.RANK_TAG: 1,
    MatchingPolicy.RANK_ONLY: 2,
    MatchingPolicy.TAG_ONLY: 3,
}
_CODE_TO_POLICY = {v: k for k, v in _POLICY_TO_CODE.items()}

# payload body tags
_P_NONE = 0
_P_BYTES = 1
_P_INTS = 2
_P_PACKED = 3

# packed-burst wire dtypes
_WD_TO_CODE = {None: 0, "bf16": 1}
_CODE_TO_WD = {v: k for k, v in _WD_TO_CODE.items()}

_HDR = struct.Struct("<HBB iiqqq iBi d Bqq qiI B")


def _payload_bytes(payload: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(payload)
    if arr.dtype != np.uint8:
        arr = arr.view(np.uint8)
    return arr.tobytes()


def encode_msg(msg: WireMsg) -> bytes:
    """Serialize one :class:`WireMsg` to a self-delimiting byte frame."""
    kind_code = _KIND_TO_CODE.get(msg.kind)
    if kind_code is None:
        raise FatalError(f"codec: unknown wire kind {msg.kind!r}")
    if msg.remote_buf is None:
        rb_tag, rb0, rb1 = 0, 0, 0
    else:
        rb_tag, (rb0, rb1) = 1, msg.remote_buf

    payload = msg.payload
    if payload is None:
        p_tag, body = _P_NONE, b""
    elif isinstance(payload, PackedBurst):
        p_tag = _P_PACKED
        rows = np.ascontiguousarray(payload.data)   # materialize stride-0
        if rows.dtype != np.uint8:
            rows = rows.view(np.uint8)
        count, row_bytes = (int(rows.shape[0]),
                            int(rows.shape[1]) if rows.ndim > 1 else 0)
        body = struct.pack("<iiB", count, row_bytes,
                           _WD_TO_CODE[payload.wire_dtype])
        body += np.asarray(payload.sizes, dtype="<i8").tobytes()
        body += np.asarray(payload.tags, dtype="<i8").tobytes()
        body += rows.tobytes()
    elif isinstance(payload, tuple):
        p_tag = _P_INTS
        body = struct.pack("<i", len(payload))
        body += np.asarray(payload, dtype="<i8").tobytes()
    else:
        p_tag = _P_BYTES
        raw = _payload_bytes(payload)
        body = struct.pack("<q", len(raw)) + raw

    hdr = _HDR.pack(_MAGIC, _VERSION, kind_code,
                    msg.src, msg.dst, msg.tag, msg.size, msg.op_id,
                    0 if msg.rcomp is None else msg.rcomp + 1,
                    _POLICY_TO_CODE[msg.matching_policy],
                    msg.device_index, msg.ready_at,
                    rb_tag, rb0, rb1,
                    msg.seq, msg.epoch, zlib.crc32(body) & 0xFFFFFFFF,
                    p_tag)
    return hdr + body


def _need(view: memoryview, off: int, n: int, what: str) -> None:
    if n < 0 or off + n > len(view):
        raise CodecError(f"codec: truncated frame ({what}: need {n} bytes "
                         f"at offset {off}, have {len(view) - off})")


def decode_msg(buf: Any, offset: int = 0) -> Tuple[WireMsg, int]:
    """Parse one frame from ``buf`` at ``offset``; returns the message
    and the offset one past its last byte.  Malformed or corrupted
    frames raise :class:`CodecError` — never a bare struct/IndexError."""
    view = memoryview(buf)
    _need(view, offset, _HDR.size, "header")
    (magic, version, kind_code, src, dst, tag, size, op_id,
     rcomp1, policy_code, device_index, ready_at,
     rb_tag, rb0, rb1, seq, epoch, crc, p_tag) = \
        _HDR.unpack_from(view, offset)
    if magic != _MAGIC:
        raise CodecError(f"codec: bad frame magic 0x{magic:04x}")
    if version != _VERSION:
        raise CodecError(f"codec: unsupported wire version {version}")
    kind = _CODE_TO_KIND.get(kind_code)
    if kind is None:
        raise CodecError(f"codec: unknown wire kind code {kind_code}")
    policy = _CODE_TO_POLICY.get(policy_code)
    if policy is None:
        raise CodecError(f"codec: unknown matching code {policy_code}")
    off = body_start = offset + _HDR.size

    if p_tag == _P_NONE:
        payload: Any = None
    elif p_tag == _P_BYTES:
        _need(view, off, 8, "bytes length")
        (nbytes,) = struct.unpack_from("<q", view, off)
        off += 8
        _need(view, off, nbytes, "bytes body")
        payload = np.frombuffer(view, np.uint8, nbytes, off).copy()
        off += nbytes
    elif p_tag == _P_INTS:
        _need(view, off, 4, "ints count")
        (n,) = struct.unpack_from("<i", view, off)
        off += 4
        _need(view, off, 8 * n if n >= 0 else -1, "ints body")
        payload = tuple(
            int(v) for v in np.frombuffer(view, "<i8", n, off))
        off += 8 * n
    elif p_tag == _P_PACKED:
        _need(view, off, 9, "packed header")
        count, row_bytes, wd_code = struct.unpack_from("<iiB", view, off)
        off += 9
        if count < 0 or row_bytes < 0:
            raise CodecError(f"codec: negative packed dims "
                             f"({count}, {row_bytes})")
        if wd_code not in _CODE_TO_WD:
            raise CodecError(f"codec: unknown wire dtype code {wd_code}")
        _need(view, off, 16 * count + count * row_bytes, "packed body")
        sizes = np.frombuffer(view, "<i8", count, off).copy()
        off += 8 * count
        tags = [int(t) for t in np.frombuffer(view, "<i8", count, off)]
        off += 8 * count
        rows = (np.frombuffer(view, np.uint8, count * row_bytes, off)
                .copy().reshape(count, row_bytes))
        off += count * row_bytes
        payload = PackedBurst(rows, sizes, tags, count,
                              _CODE_TO_WD[wd_code])
    else:
        raise CodecError(f"codec: unknown payload tag {p_tag}")

    body_crc = zlib.crc32(view[body_start:off]) & 0xFFFFFFFF
    if body_crc != crc:
        raise CodecError(f"codec: payload crc mismatch "
                         f"(frame 0x{crc:08x} != body 0x{body_crc:08x})")

    msg = WireMsg(kind=kind, src=src, dst=dst,
                  tag=tag, payload=payload, size=size,
                  rcomp=None if rcomp1 == 0 else rcomp1 - 1,
                  matching_policy=policy,
                  op_id=op_id,
                  remote_buf=None if rb_tag == 0 else (rb0, rb1),
                  device_index=device_index, ready_at=ready_at,
                  seq=seq, epoch=epoch)
    return msg, off
