"""The Transport ABC — the pluggable data plane behind the Fabric surface.

The paper's evaluation (Figures 2/3) compares the multithreaded runtime
against the *multi-process* execution mode; reproducing that comparison
needs the wire to be pluggable.  :class:`Transport` pins down the surface
every backend must provide — exactly the contract the progress engine,
endpoints and worker pools were already written against:

* ``try_push`` / ``push_burst`` / ``push_packed`` — post wire messages to
  a ``(dst, device)`` stream; a full stream surfaces back-pressure by
  accepting only a prefix (never a subsequence: accepting message k+1
  after rejecting k would break stream FIFO);
* ``drain`` — pop ready messages from one stream (the consumer side of
  the Figure-1 reaction chain); ``limit`` is **row-weighted**: a packed
  doorbell counts its row count toward the bound but is never split;
* ``ready`` / ``stream_depth`` — cheap *unlocked* probes the idle fast
  paths branch on (``Endpoint.progress`` skips quiet devices without
  paying for a locked pass);
* depth accounting is row-weighted everywhere: a packed doorbell weighs
  ``payload.count`` messages toward ``stream_depth`` / ``in_flight`` /
  the depth bound.

Backends register under a name (``sim`` / ``shm`` / ``socket``) and are
selected through the attribute chain (``fabric_backend``, env spelling
``REPRO_ATTR_FABRIC_BACKEND``) — every consumer works unchanged on top
of any backend.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Dict, List, Optional, Sequence

from .. import attrs as _attrs
from ..concurrency.atomics import AtomicCounter
from ..status import FatalError
from ..telemetry import NULL_TELEMETRY
from .wire import WireMsg

#: attrs a transport resolves at alloc time (the fabric's registry slice)
FABRIC_ATTRS = ("fabric_backend", "fabric_depth", "link_latency",
                "shm_ring_bytes")


class Transport(_attrs.AttrResource, abc.ABC):
    """Per-(dst, device) FIFO streams with bounded depth; the NIC stand-in.

    ``depth`` bounds each stream in *messages* (row-weighted) — a full
    stream is the paper's "underlying network send queue is full" event
    and surfaces ``retry``.  ``latency`` (seconds) models the wire where
    the backend can honor it (the sim backend always does; shm honors it
    on one host; sockets have real latency and ignore the model).

    Thread-safety contract (DESIGN.md §10): streams are single-consumer
    (the consumer device's progress try-lock serializes ``drain``);
    producers may race, and the depth bound is approximate by at most
    the number of racing posters — back-pressure, not an invariant.
    ``ready`` / ``stream_depth`` must be safe to call unlocked from any
    thread: a stale answer costs one wasted (or one late) pass, nothing
    more.
    """

    #: registry name of the backend (subclasses override)
    backend = "abstract"

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None):
        self.n_ranks = n_ranks
        self.depth = depth
        self.latency = latency
        # atomic: producers on any thread bump these concurrently
        self._pushes = AtomicCounter()
        self._full_events = AtomicCounter()
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"fabric_backend": self.backend, "fabric_depth": depth,
             "link_latency": latency}))
        self.tele = NULL_TELEMETRY
        self._export_attr("in_flight", self.in_flight)
        self._export_attr("pushes", lambda: self.pushes)
        self._export_attr("full_events", lambda: self.full_events)
        self._export_attr("telemetry", self._telemetry_block)

    # -- telemetry -----------------------------------------------------------
    @property
    def pushes(self) -> int:
        return self._pushes.load()

    @property
    def full_events(self) -> int:
        return self._full_events.load()

    def set_telemetry(self, tele) -> None:
        """Attach the owning cluster's hub (transport spans are timed at
        the engine call sites; the hub folds these counters in)."""
        self.tele = tele
        tele.attach("fabric", lambda: {"pushes": self.pushes,
                                       "full_events": self.full_events})

    def _telemetry_block(self) -> dict:
        return {"level": self.tele.level,
                "counters": {"fabric.pushes": self.pushes,
                             "fabric.full_events": self.full_events,
                             "fabric.in_flight": self.in_flight()}}

    # -- producer side -------------------------------------------------------
    @abc.abstractmethod
    def try_push(self, msg: WireMsg) -> bool:
        """Push one message; ``False`` = stream full (back-pressure)."""

    @abc.abstractmethod
    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        """One doorbell: a burst bound for the SAME ``(dst, device)``
        stream.  Accepts the longest prefix that fits under the depth
        bound and returns how many messages were accepted."""

    @abc.abstractmethod
    def push_packed(self, msg: WireMsg) -> int:
        """Ring a fused doorbell: ONE descriptor whose ``PackedBurst``
        payload carries the whole burst.  The burst weighs ``count``
        messages toward the depth bound; accepts the longest row prefix
        that fits and returns the number of rows accepted."""

    # -- consumer side -------------------------------------------------------
    @abc.abstractmethod
    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        """Pop ready messages from one stream.  ``limit`` bounds the
        burst row-weighted: ``limit == 0`` means "drain all", ``limit >
        0`` stops once the popped row weight reaches the cap (a packed
        doorbell is popped whole, so one doorbell may overshoot);
        ``limit < 0`` is an error."""

    @abc.abstractmethod
    def ready(self, dst: int, device_index: int) -> bool:
        """Cheap unlocked readiness probe: is at least one message on
        this stream due for delivery?"""

    @abc.abstractmethod
    def stream_depth(self, dst: int, device_index: int) -> int:
        """Queued messages on one stream (row-weighted, including
        not-yet-drainable ones) — the lock-free idle probe."""

    @abc.abstractmethod
    def in_flight(self) -> int:
        """Total queued messages this transport can observe
        (row-weighted).  Cross-process backends report what is visible
        from this process (shm rings are globally visible on one host;
        sockets only count locally buffered frames)."""

    @abc.abstractmethod
    def pending_to(self, dst: int) -> int:
        """Queued messages bound for rank ``dst`` across all streams."""

    @abc.abstractmethod
    def pending_streams(self, dst: int) -> List[int]:
        """Device-stream indices with traffic queued toward ``dst``."""

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Release OS resources (shm files, sockets).  Idempotent; the
        in-process sim backend has nothing to release."""

    @staticmethod
    def check_stream(msgs: Sequence[WireMsg]) -> tuple:
        """Validate a burst rides one stream; returns ``(dst, device)``."""
        dst, didx = msgs[0].dst, msgs[0].device_index
        for m in msgs[1:]:
            if m.dst != dst or m.device_index != didx:
                raise FatalError("push_burst: a doorbell rides one "
                                 "(dst, device) stream; got mixed streams")
        return dst, didx


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

#: backend name -> lazy factory returning the Transport subclass
_BACKENDS: Dict[str, Callable[[], type]] = {}


def register_backend(name: str, loader: Callable[[], type]) -> None:
    """Register a transport backend under ``name``.  ``loader`` is lazy
    (called at first use) so registering the stock backends does not
    import their OS machinery up front."""
    _BACKENDS[name] = loader


def backend_class(name: str) -> type:
    loader = _BACKENDS.get(name)
    if loader is None:
        raise _attrs.AttrError(
            f"unknown fabric backend {name!r}; registered backends: "
            f"{sorted(_BACKENDS)}")
    return loader()


def make_transport(backend: str, n_ranks: int, **kwargs: Any) -> Transport:
    """Construct the selected backend.  ``kwargs`` are the union of every
    backend's knobs; each constructor takes what it understands (they all
    accept ``depth`` / ``latency`` / ``resolved``)."""
    return backend_class(backend)(n_ranks, **kwargs)
