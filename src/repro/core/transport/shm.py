"""The ``shm`` backend — shared-memory ring buffers between OS processes.

This is the transport that makes the paper's central comparison real:
N *processes* on one host exchanging wire messages through per-stream
shared-memory rings instead of N threads sharing one address space.

Layout.  Each stream is one (or, cross-process, ``n_ranks``) ring
file(s) under a session directory in ``/dev/shm``::

    ring_p{producer}_d{dst}_{device}     (header page + data region)

Rings are strict SPSC per the paper's §4.1 atomics discipline: the
producer *process* owns the write cursor and the pushed counter, the
consumer process owns the read cursor and the drained counter, and no
cross-process read-modify-write ever happens — depth is computed as
``pushed − drained`` from two single-writer counters.  The counters sit
on separate 64-byte lines of the header page (no false sharing), and
``stream_depth`` is exactly the ISSUE's "unlocked head peek": two loads,
no locks, so ``Endpoint.progress`` idle-skip works unchanged.

Two deployment modes share the code path:

* **solo** (default, e.g. tier-1 under ``REPRO_ATTR_FABRIC_BACKEND=shm``):
  all ranks live in one process, which is therefore both producer and
  consumer of every ring — producer id 0, one ring per ``(dst, device)``
  stream, a per-ring ``threading.Lock`` serializing in-process
  multithreaded producers (the SPSC discipline is per *process*, not per
  thread).
* **spmd** (under ``launch/spmd.py``): each rank process produces into
  its own ring per ``(dst, device)`` and consumes the ``n_ranks``
  producer rings addressed to it.  Ring creation is idempotent
  (fixed-size, zero-initialized), so whichever side touches a stream
  first creates the file and the other side attaches.

Records never wrap: if the space left before the end of the data region
cannot hold a record, the producer writes a PAD record (or, below one
header, skips implicitly) and continues at offset 0.  Payloads larger
than half the ring (rendezvous RDMA payloads run to megabytes) spill to
a side file and ride the ring as an 8-byte reference — back-pressure
still applies, the bytes just live outside the ring.
"""
from __future__ import annotations

import os
import shutil
import struct
import tempfile
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from .. import attrs as _attrs
from ..status import FatalError
from .base import Transport
from .codec import decode_msg, encode_msg
from .wire import PackedBurst, WireMsg, msg_weight

# header-page slots (one per 64-byte cache line; u64 little-endian).
# pushed/tail are producer-owned, drained/head consumer-owned — the
# single-writer discipline that lets the other side read them unlocked.
_OFF_PUSHED = 0
_OFF_TAIL = 64
_OFF_DRAINED = 128
_OFF_HEAD = 192
_HEADER_BYTES = 4096

# record header: [u32 span][u8 flags][u32 weight][f64 ready_at]
_REC = struct.Struct("<IBId")
_REC_SIZE = _REC.size
_F_PAD = 1
_F_SPILL = 2

_SPMD_RANK_ENV = "REPRO_SPMD_RANK"
_SPMD_SESSION_ENV = "REPRO_SPMD_SESSION"


def _shm_dir() -> str:
    return "/dev/shm" if os.path.isdir("/dev/shm") else tempfile.gettempdir()


class _Ring:
    """One mmap'd SPSC ring file (create-or-attach, idempotent)."""

    def __init__(self, path: str, capacity: int):
        self.path = path
        self.capacity = capacity
        size = _HEADER_BYTES + capacity
        fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o600)
        try:
            os.ftruncate(fd, size)       # idempotent: fixed deterministic size
            import mmap
            self.mm = mmap.mmap(fd, size)
        finally:
            os.close(fd)

    # -- counter slots (8-byte aligned; effectively atomic on this ABI) --
    def _get(self, off: int) -> int:
        return struct.unpack_from("<Q", self.mm, off)[0]

    def _put(self, off: int, value: int) -> None:
        struct.pack_into("<Q", self.mm, off, value)

    @property
    def pushed(self) -> int:
        return self._get(_OFF_PUSHED)

    @property
    def drained(self) -> int:
        return self._get(_OFF_DRAINED)

    def depth(self) -> int:
        """Row-weighted occupancy: two unlocked loads, never negative
        (a racing consumer can only make the stream look fuller)."""
        return max(0, self.pushed - self.drained)

    # -- producer side ----------------------------------------------------
    def try_write(self, body: bytes, weight: int, ready_at: float,
                  flags: int = 0) -> bool:
        """Append one record; ``False`` = not enough free bytes."""
        span = _REC_SIZE + len(body)
        tail = self._get(_OFF_TAIL)
        head = self._get(_OFF_HEAD)
        free = self.capacity - (tail - head)
        pos = tail % self.capacity
        rem = self.capacity - pos
        pad = rem if rem < span else 0     # wrap cost if the record won't fit
        if span + pad > free:
            return False
        if pad:
            if rem >= _REC_SIZE:           # explicit PAD record
                _REC.pack_into(self.mm, _HEADER_BYTES + pos, rem, _F_PAD,
                               0, 0.0)
            # rem < _REC_SIZE: implicit skip — consumer applies the same rule
            tail += pad
            pos = 0
        base = _HEADER_BYTES + pos
        _REC.pack_into(self.mm, base, span, flags, weight, ready_at)
        self.mm[base + _REC_SIZE:base + span] = body
        # publish AFTER the record bytes are in place (x86-TSO store order;
        # the GIL serializes the in-process case)
        self._put(_OFF_TAIL, tail + span)
        self._put(_OFF_PUSHED, self.pushed + weight)
        return True

    # -- consumer side ----------------------------------------------------
    def _skip_pads(self, head: int, tail: int) -> int:
        """Resolve ``head`` past pad/skip space to a real record (or tail)."""
        while head != tail:
            pos = head % self.capacity
            rem = self.capacity - pos
            if rem < _REC_SIZE:
                head += rem
                continue
            span, flags, _w, _r = _REC.unpack_from(
                self.mm, _HEADER_BYTES + pos)
            if flags & _F_PAD:
                head += span
                continue
            break
        return head

    def peek(self) -> Optional[Tuple[int, int, int, float]]:
        """Head record's ``(pos, span, flags, ready_at)`` without
        consuming — pure, safe from any thread (stale, never corrupt)."""
        tail = self._get(_OFF_TAIL)
        head = self._skip_pads(self._get(_OFF_HEAD), tail)
        if head == tail:
            return None
        pos = head % self.capacity
        span, flags, _w, ready_at = _REC.unpack_from(
            self.mm, _HEADER_BYTES + pos)
        return pos, span, flags, ready_at

    def read(self) -> Optional[Tuple[bytes, int, int, float]]:
        """Consume the head record: ``(body, weight, flags, ready_at)``."""
        tail = self._get(_OFF_TAIL)
        head = self._skip_pads(self._get(_OFF_HEAD), tail)
        if head == tail:
            if head != self._get(_OFF_HEAD):   # persist pad skips
                self._put(_OFF_HEAD, head)
            return None
        pos = head % self.capacity
        base = _HEADER_BYTES + pos
        span, flags, weight, ready_at = _REC.unpack_from(self.mm, base)
        body = bytes(self.mm[base + _REC_SIZE:base + span])
        self._put(_OFF_HEAD, head + span)
        self._put(_OFF_DRAINED, self.drained + weight)
        return body, weight, flags, ready_at

    def close(self) -> None:
        try:
            self.mm.close()
        except (BufferError, ValueError):
            pass


class ShmTransport(Transport):
    """Shared-memory ring transport (see module docstring for layout)."""

    backend = "shm"

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None,
                 ring_bytes: int = 1 << 20,
                 rank: Optional[int] = None,
                 session: Optional[str] = None, **_ignored):
        super().__init__(n_ranks, depth, latency, resolved)
        if resolved is not None and "shm_ring_bytes" in resolved:
            ring_bytes = resolved["shm_ring_bytes"]
        self.ring_bytes = ring_bytes
        # deployment mode: spmd (one process per rank) when a rank id is
        # given or the launcher's env is present, else solo (all ranks
        # in-process, single producer id 0)
        env_rank = os.environ.get(_SPMD_RANK_ENV)
        self.rank = rank if rank is not None else (
            int(env_rank) if env_rank is not None else None)
        self.spmd = self.rank is not None
        session = session or os.environ.get(_SPMD_SESSION_ENV)
        if session:
            self._dir = (session if os.path.isabs(session)
                         else os.path.join(_shm_dir(), session))
            os.makedirs(self._dir, exist_ok=True)
            self._owns_dir = False       # the launcher reaps the session
        else:
            self._dir = tempfile.mkdtemp(prefix="repro-shm-",
                                         dir=_shm_dir())
            self._owns_dir = True
        self._producer_id = self.rank if self.spmd else 0
        self._producer_ids = (tuple(range(n_ranks)) if self.spmd else (0,))
        self._rings: Dict[Tuple[int, int, int], _Ring] = {}
        self._plocks: Dict[Tuple[int, int], threading.Lock] = {}
        self._spill_seq: Dict[Tuple[int, int], int] = {}
        self._lock = threading.Lock()    # guards the maps, not the rings
        self._closed = False
        self._export_attr("shm_ring_bytes", lambda: self.ring_bytes)
        self._export_attr("shm_session_dir", lambda: self._dir)

    # -- ring bookkeeping -------------------------------------------------
    def _ring(self, producer: int, dst: int, didx: int) -> _Ring:
        key = (producer, dst, didx)
        ring = self._rings.get(key)
        if ring is None:
            with self._lock:
                ring = self._rings.get(key)
                if ring is None:
                    path = os.path.join(
                        self._dir, f"ring_p{producer}_d{dst}_{didx}")
                    ring = _Ring(path, self.ring_bytes)
                    self._rings[key] = ring
        return ring

    def _plock(self, dst: int, didx: int) -> threading.Lock:
        key = (dst, didx)
        lock = self._plocks.get(key)
        if lock is None:
            with self._lock:
                lock = self._plocks.setdefault(key, threading.Lock())
        return lock

    def _stamp(self) -> float:
        # monotonic: comparable across processes on one Linux host
        return time.monotonic() + self.latency if self.latency else 0.0

    # -- producer side ----------------------------------------------------
    def _write_msg(self, ring: _Ring, msg: WireMsg, weight: int,
                   dst: int, didx: int) -> bool:
        body = encode_msg(msg)
        flags = 0
        if _REC_SIZE + len(body) > self.ring_bytes // 2:
            # oversized (rendezvous payloads): spill to a side file, ride
            # the ring as an 8-byte reference so FIFO order is preserved
            key = (dst, didx)
            seq = self._spill_seq.get(key, 0)
            path = self._spill_path(self._producer_id, dst, didx, seq)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(body)
            os.rename(tmp, path)         # atomic publish
            probe = struct.pack("<Q", seq)
            if not ring.try_write(probe, weight, self._stamp(), _F_SPILL):
                os.unlink(path)
                self._full_events.fetch_add(1)
                return False
            self._spill_seq[key] = seq + 1
            self._pushes.fetch_add(weight)
            return True
        if not ring.try_write(body, weight, self._stamp(), flags):
            self._full_events.fetch_add(1)
            return False
        self._pushes.fetch_add(weight)
        return True

    def _spill_path(self, producer: int, dst: int, didx: int,
                    seq: int) -> str:
        return os.path.join(self._dir,
                            f"spill_p{producer}_d{dst}_{didx}_{seq}.bin")

    def _room(self, ring: _Ring, want: int) -> int:
        """How many of ``want`` rows fit under the row-weighted depth
        bound right now (byte capacity is checked at write time)."""
        return min(want, max(0, self.depth - ring.depth()))

    def try_push(self, msg: WireMsg) -> bool:
        dst, didx = msg.dst, msg.device_index
        ring = self._ring(self._producer_id, dst, didx)
        with self._plock(dst, didx):
            if self._room(ring, 1) < 1:
                self._full_events.fetch_add(1)
                return False
            return self._write_msg(ring, msg, 1, dst, didx)

    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        if not msgs:
            return 0
        dst, didx = self.check_stream(msgs)
        ring = self._ring(self._producer_id, dst, didx)
        accepted = 0
        with self._plock(dst, didx):
            n = self._room(ring, len(msgs))
            if n < len(msgs):
                self._full_events.fetch_add(1)
            for m in msgs[:n]:
                if not self._write_msg(ring, m, 1, dst, didx):
                    break                # ring bytes full: prefix stands
                accepted += 1
        return accepted

    def push_packed(self, msg: WireMsg) -> int:
        burst: PackedBurst = msg.payload
        dst, didx = msg.dst, msg.device_index
        ring = self._ring(self._producer_id, dst, didx)
        with self._plock(dst, didx):
            n = self._room(ring, burst.count)
            if n < burst.count:
                self._full_events.fetch_add(1)
            if n == 0:
                return 0
            if n < burst.count:          # prefix-accept split
                pb = burst.prefix(n)
                import dataclasses
                msg = dataclasses.replace(msg, payload=pb,
                                          size=int(pb.data.nbytes))
            if not self._write_msg(ring, msg, n, dst, didx):
                return 0                 # ring bytes full: whole doorbell
            return n

    # -- consumer side ----------------------------------------------------
    def _read_record(self, producer: int, dst: int, didx: int,
                     ring: _Ring) -> Optional[WireMsg]:
        rec = ring.read()
        if rec is None:
            return None
        body, _weight, flags, _ready = rec
        if flags & _F_SPILL:
            (seq,) = struct.unpack("<Q", body)
            path = self._spill_path(producer, dst, didx, seq)
            with open(path, "rb") as f:
                body = f.read()
            os.unlink(path)
        msg, _ = decode_msg(body)
        return msg

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        if limit < 0:
            raise ValueError(f"drain: limit must be >= 0 (0 = drain all), "
                             f"got {limit}")
        out: List[WireMsg] = []
        weight = 0
        now = time.monotonic() if self.latency else 0.0
        for producer in self._producer_ids:
            ring = self._ring(producer, dst, device_index)
            while limit == 0 or weight < limit:
                head = ring.peek()
                if head is None:
                    break
                _pos, _span, _flags, ready_at = head
                if ready_at and ready_at > now:
                    break                # FIFO: stop at the on-the-wire head
                msg = self._read_record(producer, dst, device_index, ring)
                if msg is None:
                    break
                out.append(msg)
                weight += msg_weight(msg)
        return out

    def ready(self, dst: int, device_index: int) -> bool:
        if not self.latency:
            return self.stream_depth(dst, device_index) > 0
        now = time.monotonic()
        for producer in self._producer_ids:
            head = self._ring(producer, dst, device_index).peek()
            if head is not None and head[3] <= now:
                return True
        return False

    def stream_depth(self, dst: int, device_index: int) -> int:
        # the ISSUE's unlocked head peek: two counter loads per ring
        return sum(self._ring(p, dst, device_index).depth()
                   for p in self._producer_ids)

    def in_flight(self) -> int:
        """Row-weighted occupancy of every ring this process has touched
        (solo mode sees everything; spmd ranks see their own streams)."""
        with self._lock:
            rings = list(self._rings.values())
        return sum(r.depth() for r in rings)

    def pending_to(self, dst: int) -> int:
        with self._lock:
            items = list(self._rings.items())
        return sum(r.depth() for (p, d, i), r in items if d == dst)

    def pending_streams(self, dst: int) -> List[int]:
        # scan the session dir too: a producer in another process may
        # have created streams this process never touched
        didxs = set()
        try:
            names = os.listdir(self._dir)
        except OSError:
            names = []
        for name in names:
            if not name.startswith("ring_p"):
                continue
            try:
                p, d, i = name[6:].split("_")
                producer, d, i = int(p), int(d[1:]), int(i)
            except ValueError:
                continue
            if d == dst and producer in self._producer_ids:
                if self._ring(producer, d, i).depth() > 0:
                    didxs.add(i)
        for (p, d, i), r in list(self._rings.items()):
            if d == dst and r.depth() > 0:
                didxs.add(i)
        return sorted(didxs)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with self._lock:
            rings, self._rings = list(self._rings.values()), {}
        for ring in rings:
            ring.close()
        if self._owns_dir:
            shutil.rmtree(self._dir, ignore_errors=True)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
