"""The simulated interconnect: wire messages, queues, registered memory.

The :class:`Fabric` stands in for the NIC/ICI: per ``(dst-rank,
device-stream)`` bounded FIFO queues.  A full queue surfaces ``retry`` —
the same back-pressure path a full ibv send queue triggers in the paper
(§4.4) — and the progress engine moves such requests through the backlog
queue.  Messages are keyed by the *sender's* device index, so each device
stream is an independent, ordered channel: replicating devices replicates
streams, which is exactly the paper's resource-replication story (§3.2.3).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..completion import CompletionObject
from ..concurrency.atomics import AtomicCounter
from ..matching import MatchingPolicy
from ..post import CommKind
from ..status import FatalError


class WireKind:
    EAGER_SEND = "eager_send"      # send-recv eager payload
    EAGER_AM = "eager_am"          # active-message eager payload
    RTS = "rts"                    # rendezvous request-to-send
    CTS = "cts"                    # rendezvous clear-to-send
    RDMA_PAYLOAD = "rdma_payload"  # rendezvous data movement (zero-copy)
    PUT = "put"                    # RMA put (optionally with signal)
    GET_REQ = "get_req"            # RMA get request
    GET_RESP = "get_resp"          # RMA get response


@dataclasses.dataclass
class WireMsg:
    kind: str
    src: int
    dst: int
    tag: int = 0
    payload: Any = None
    size: int = 0
    rcomp: Optional[int] = None
    matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG
    # rendezvous bookkeeping
    op_id: int = -1                # source-side pending-op id
    remote_buf: Any = None         # (region_id, offset) for RMA
    device_index: int = 0          # which device stream this rides
    ready_at: float = 0.0          # wire-latency model: drainable after this


@dataclasses.dataclass
class PendingOp:
    """Source-side state for a posted (not yet complete) operation."""
    kind: CommKind
    buf: Any
    size: int
    tag: int
    peer: int
    local_comp: Optional[CompletionObject]
    packet: int = -1               # bufcopy: packet id to return to the pool
    lane: int = 0
    user_context: Any = None


_op_ids = itertools.count()


def next_op_id() -> int:
    return next(_op_ids)


class Fabric:
    """Bounded per-(dst, device) FIFO queues; the NIC send-queue stand-in.

    ``depth`` bounds each queue — a full queue is the paper's "underlying
    network send queue is full" event and surfaces ``retry``.

    ``latency`` (seconds) models the wire: a pushed message only becomes
    drainable ``latency`` after its push.  The default (0) keeps the
    historical instantly-visible behaviour; the multithreaded message-rate
    benchmark uses a nonzero latency so that completion-window waits are
    real and threads can overlap them — the paper's core asynchrony
    argument.  Thread-safety note (DESIGN.md §10): streams are
    single-consumer (the consumer device's progress try-lock serializes
    ``drain``); concurrent producers ride the GIL-atomic deque append, so
    the depth bound is approximate by at most the number of racing
    posters — back-pressure, not an invariant.
    """

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0):
        self.n_ranks = n_ranks
        self.depth = depth
        self.latency = latency
        self._queues: Dict[Tuple[int, int], collections.deque] = {}
        # atomic: producers on any thread bump these concurrently
        self._pushes = AtomicCounter()
        self._full_events = AtomicCounter()

    @property
    def pushes(self) -> int:
        return self._pushes.load()

    @property
    def full_events(self) -> int:
        return self._full_events.load()

    def _q(self, dst: int, device_index: int) -> collections.deque:
        return self._queues.setdefault((dst, device_index),
                                       collections.deque())

    def try_push(self, msg: WireMsg) -> bool:
        q = self._q(msg.dst, msg.device_index)
        if len(q) >= self.depth:
            self._full_events.fetch_add(1)
            return False
        if self.latency:
            msg.ready_at = time.perf_counter() + self.latency
        q.append(msg)
        self._pushes.fetch_add(1)
        return True

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        q = self._q(dst, device_index)
        n = len(q) if limit <= 0 else min(limit, len(q))
        if not self.latency:
            return [q.popleft() for _ in range(n)]
        # latency model: streams are FIFO, so stop at the first message
        # still "on the wire"
        now = time.perf_counter()
        out: List[WireMsg] = []
        while len(out) < n and q and q[0].ready_at <= now:
            out.append(q.popleft())
        return out

    def in_flight(self) -> int:
        """Total queued messages (including not-yet-drainable ones)."""
        return sum(len(q) for q in self._queues.values())

    def pending_to(self, dst: int) -> int:
        return sum(len(q) for (d, _), q in self._queues.items() if d == dst)

    def pending_streams(self, dst: int) -> List[int]:
        """Device-stream indices with traffic queued toward ``dst``."""
        return sorted(i for (d, i), q in self._queues.items()
                      if d == dst and q)


# ---------------------------------------------------------------------------
# memory registration (paper §3.3.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemoryRegion:
    """Registered memory: mandatory for remote buffers (RMA targets)."""
    rid: int
    buf: np.ndarray                # 1-D uint8 view of the registered range


def as_bytes_view(buf: Any) -> np.ndarray:
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8)
    if isinstance(buf, (bytearray, memoryview)):
        return np.frombuffer(buf, dtype=np.uint8)
    raise FatalError(f"cannot register memory of type {type(buf)}")


def payload_to_bytes(buf: Any) -> np.ndarray:
    """Materialize a payload (or buffer list, §3.3.1) as bytes."""
    if isinstance(buf, (list, tuple)):
        parts = [payload_to_bytes(b) for b in buf]
        return (np.concatenate(parts) if parts
                else np.zeros(0, np.uint8))
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8).copy()
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(buf), dtype=np.uint8)
    raise FatalError(f"unsupported payload type {type(buf)}")
