"""Fabric-facing state: pending ops, registered memory, payload staging.

The wire types (:class:`WireMsg`, :class:`PackedBurst`, :data:`WireKind`)
and the fabric implementation itself now live in
:mod:`repro.core.transport` (DESIGN.md §14) — the simulated in-process
fabric is the ``sim`` backend of the pluggable :class:`Transport` ABC,
and ``shm``/``socket`` backends carry the same messages between OS
processes.  This module keeps the *progress-engine side* of the story —
source-side pending state, memory registration (§3.3.1), and the payload
staging helpers for doorbell fusion (§4.3) — and re-exports the moved
names so every existing import keeps working.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional, Sequence, Tuple

import ml_dtypes
import numpy as np

from ..completion import CompletionObject
from ..post import CommKind
from ..status import FatalError
from ..transport import (FABRIC_ATTRS, PACKED_KINDS, PackedBurst, WireKind,
                         WireMsg, msg_weight)
from ..transport.sim import Fabric

__all__ = [
    "FABRIC_ATTRS", "PACKED_KINDS", "PackedBurst", "WireKind", "WireMsg",
    "msg_weight", "Fabric", "PendingOp", "PendingBurst", "next_op_id",
    "MemoryRegion", "as_bytes_view", "payload_to_bytes",
    "payloads_to_bytes", "pack_payloads",
]


@dataclasses.dataclass
class PendingOp:
    """Source-side state for a posted (not yet complete) operation."""
    kind: CommKind
    buf: Any
    size: int
    tag: int
    peer: int
    local_comp: Optional[CompletionObject]
    packet: int = -1               # bufcopy: packet id to return to the pool
    lane: int = 0
    user_context: Any = None


@dataclasses.dataclass
class PendingBurst:
    """Source-side state for ONE fused bufcopy doorbell: K packets and K
    deferred completions under a single pending-op id.  The progress
    sweep returns all packets with one ``put_n`` and signals the
    completions in row (FIFO) order, matching the per-op scalar path.
    ``comps`` is either one completion object shared by every row or a
    per-row list aligned with ``tags``."""
    kind: CommKind
    peer: int
    lane: int
    packets: List[int]
    tags: List[int]
    comps: Any = None


_op_ids = itertools.count()


def next_op_id() -> int:
    return next(_op_ids)


# ---------------------------------------------------------------------------
# memory registration (paper §3.3.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemoryRegion:
    """Registered memory: mandatory for remote buffers (RMA targets)."""
    rid: int
    buf: np.ndarray                # 1-D uint8 view of the registered range


def as_bytes_view(buf: Any) -> np.ndarray:
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8)
    if isinstance(buf, (bytearray, memoryview)):
        return np.frombuffer(buf, dtype=np.uint8)
    raise FatalError(f"cannot register memory of type {type(buf)}")


def payload_to_bytes(buf: Any) -> np.ndarray:
    """Materialize a payload (or buffer list, §3.3.1) as bytes."""
    if isinstance(buf, (list, tuple)):
        parts = [payload_to_bytes(b) for b in buf]
        return (np.concatenate(parts) if parts
                else np.zeros(0, np.uint8))
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8).copy()
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(buf), dtype=np.uint8)
    raise FatalError(f"unsupported payload type {type(buf)}")


def payloads_to_bytes(bufs: Sequence[Any]) -> List[np.ndarray]:
    """Stage a burst's payloads — ONE stacked copy instead of K.

    When every payload is an ``np.ndarray`` sharing one dtype and shape
    (the windowed-benchmark common case), the whole burst is materialized
    with a single ``np.stack(bufs)`` — one vectorized memcpy, no
    per-element Python conversion at all — and each message gets a row
    view of the stacked array (rows are independent snapshots, so source
    buffers stay reusable exactly like :func:`payload_to_bytes`).
    Same-sized arrays of *mixed* dtype stack through per-item flat byte
    views (still one burst-sized copy, byte-exact per payload); ragged
    or non-array bursts fall back to per-payload copies."""
    if len(bufs) <= 1:
        return [payload_to_bytes(b) for b in bufs]
    first = bufs[0]
    if isinstance(first, np.ndarray):
        dt, shape, nbytes = first.dtype, first.shape, first.nbytes
        if all(isinstance(b, np.ndarray) and b.dtype == dt
               and b.shape == shape for b in bufs):
            stacked = np.stack(bufs)                  # the ONE copy
            return list(stacked.reshape(len(bufs), -1).view(np.uint8))
        if all(isinstance(b, np.ndarray) and b.nbytes == nbytes
               for b in bufs):
            # mixed dtype/shape but same byte size: np.stack reads
            # per-item flat byte views and performs the single copy
            stacked = np.stack([
                b if b.dtype == np.uint8 and b.ndim == 1
                else b.reshape(-1).view(np.uint8)
                for b in bufs])
            return list(stacked)                      # row views, no copy
    return [payload_to_bytes(b) for b in bufs]


def pack_payloads(bufs: Sequence[Any], wire_bf16: bool = False
                  ) -> Tuple[np.ndarray, np.ndarray, Optional[str]]:
    """Stage a fused doorbell: ONE dtype-normalized copy builds the
    packed wire image (DESIGN.md §13).  Returns ``(data, sizes,
    wire_dtype)`` for a :class:`PackedBurst`: ``data`` is ``(K,
    row_bytes)`` uint8, ``sizes[i]`` the delivered byte size of row
    ``i``.

    Fast paths, in order:

    * every element is the SAME array object (a repeated payload — the
      message-rate hot loop): one row snapshot, broadcast K ways with no
      further copying;
    * uniform dtype+shape ndarrays: one ``np.stack``;
    * anything else: per-row byte staging into a zero-padded matrix.

    ``wire_bf16`` compresses float32 bursts to bf16 on the wire at zero
    marginal cost (the cast IS the staging copy); it applies only on the
    uniform-f32 fast paths — mixed bursts ship uncompressed — and
    ``sizes`` always reports the *delivered* (f32) byte size."""
    k = len(bufs)
    first = bufs[0]
    if isinstance(first, np.ndarray):
        # identity probe runs at C speed: 64-element bursts are common
        # and a Python-level ``all(b is first ...)`` genexpr shows up in
        # the message-rate profile
        if len(set(map(id, bufs))) == 1:
            flat = first.reshape(-1)
            if wire_bf16 and first.dtype == np.float32:
                row = flat.astype(ml_dtypes.bfloat16).view(np.uint8)
                wire_dtype = "bf16"
            else:
                row = flat.view(np.uint8).copy()      # the one snapshot
                wire_dtype = None
            data = np.broadcast_to(row, (k, row.size))
            return data, np.full(k, first.nbytes, np.int64), wire_dtype
        dt, shape = first.dtype, first.shape
        if all(isinstance(b, np.ndarray) and b.dtype == dt
               and b.shape == shape for b in bufs):
            flat = np.stack(bufs).reshape(k, -1)      # the ONE copy
            if wire_bf16 and dt == np.float32:
                return (flat.astype(ml_dtypes.bfloat16).view(np.uint8),
                        np.full(k, first.nbytes, np.int64), "bf16")
            return (flat.view(np.uint8),
                    np.full(k, first.nbytes, np.int64), None)
    rows = [payload_to_bytes(b) for b in bufs]
    sizes = np.fromiter((r.nbytes for r in rows), np.int64, k)
    data = np.zeros((k, int(sizes.max(initial=0))), np.uint8)
    for i, r in enumerate(rows):
        data[i, :r.nbytes] = r
    return data, sizes, None
