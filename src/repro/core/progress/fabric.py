"""The simulated interconnect: wire messages, queues, registered memory.

The :class:`Fabric` stands in for the NIC/ICI: per ``(dst-rank,
device-stream)`` bounded FIFO queues.  A full queue surfaces ``retry`` —
the same back-pressure path a full ibv send queue triggers in the paper
(§4.4) — and the progress engine moves such requests through the backlog
queue.  Messages are keyed by the *sender's* device index, so each device
stream is an independent, ordered channel: replicating devices replicates
streams, which is exactly the paper's resource-replication story (§3.2.3).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import ml_dtypes
import numpy as np

from .. import attrs as _attrs
from ..completion import CompletionObject
from ..concurrency.atomics import AtomicCounter
from ..matching import MatchingPolicy
from ..post import CommKind
from ..status import FatalError

#: attrs the fabric resolves at alloc time
FABRIC_ATTRS = ("fabric_depth", "link_latency")


class WireKind:
    EAGER_SEND = "eager_send"      # send-recv eager payload
    EAGER_AM = "eager_am"          # active-message eager payload
    # fused doorbells (DESIGN.md §13): ONE descriptor carries a whole
    # burst's payloads as a packed 2-D byte array
    EAGER_PACKED_SEND = "eager_packed_send"
    EAGER_PACKED_AM = "eager_packed_am"
    RTS = "rts"                    # rendezvous request-to-send
    CTS = "cts"                    # rendezvous clear-to-send
    RDMA_PAYLOAD = "rdma_payload"  # rendezvous data movement (zero-copy)
    PUT = "put"                    # RMA put (optionally with signal)
    GET_REQ = "get_req"            # RMA get request
    GET_RESP = "get_resp"          # RMA get response


#: packed wire kinds — each such message weighs ``payload.count`` toward
#: the stream depth bound (and every message-counting telemetry)
PACKED_KINDS = frozenset((WireKind.EAGER_PACKED_SEND,
                          WireKind.EAGER_PACKED_AM))


@dataclasses.dataclass
class WireMsg:
    kind: str
    src: int
    dst: int
    tag: int = 0
    payload: Any = None
    size: int = 0
    rcomp: Optional[int] = None
    matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG
    # rendezvous bookkeeping
    op_id: int = -1                # source-side pending-op id
    remote_buf: Any = None         # (region_id, offset) for RMA
    device_index: int = 0          # which device stream this rides
    ready_at: float = 0.0          # wire-latency model: drainable after this


@dataclasses.dataclass
class PendingOp:
    """Source-side state for a posted (not yet complete) operation."""
    kind: CommKind
    buf: Any
    size: int
    tag: int
    peer: int
    local_comp: Optional[CompletionObject]
    packet: int = -1               # bufcopy: packet id to return to the pool
    lane: int = 0
    user_context: Any = None


@dataclasses.dataclass
class PackedBurst:
    """One fused eager doorbell's wire image (DESIGN.md §13).

    The whole burst rides a single :class:`WireMsg` whose payload is this
    descriptor: ``data`` holds the K wire rows as one packed 2-D byte
    array (one stacked copy staged them), ``sizes[i]`` is row *i*'s
    delivered payload size in bytes, and ``tags[i]`` its message tag.
    ``wire_dtype == "bf16"`` marks rows carrying bf16-compressed float32
    payloads — :meth:`delivered_payloads` restores them to f32 bytes, so
    receivers observe flat uint8 arrays exactly like the scalar path.
    """

    data: np.ndarray               # (count, row_bytes) uint8 wire bytes
    sizes: np.ndarray              # (count,) delivered bytes per row
    tags: List[int]                # per-row message tags
    count: int
    wire_dtype: Optional[str] = None

    def prefix(self, n: int) -> "PackedBurst":
        """The first ``n`` rows — a fabric prefix-accept split point."""
        return PackedBurst(self.data[:n], self.sizes[:n], self.tags[:n],
                           n, self.wire_dtype)

    def delivered_payloads(self) -> List[np.ndarray]:
        """Per-row payload byte arrays as the receiver must observe them
        (bf16 rows decompressed back to float32 bytes in ONE vectorized
        cast for the whole burst)."""
        if self.wire_dtype == "bf16":
            # order="C": astype's default order='K' keeps a broadcast
            # row's degenerate strides, which the uint8 view rejects
            rows = (self.data.view(ml_dtypes.bfloat16)
                    .astype(np.float32, order="C").view(np.uint8))
        else:
            rows = self.data
        width = rows.shape[1]
        sizes = self.sizes
        if sizes.size and int(sizes[0]) == width \
                and bool((sizes == width).all()):
            return list(rows)              # uniform full-width: row views
        return [rows[i, :int(s)] for i, s in enumerate(sizes)]


@dataclasses.dataclass
class PendingBurst:
    """Source-side state for ONE fused bufcopy doorbell: K packets and K
    deferred completions under a single pending-op id.  The progress
    sweep returns all packets with one ``put_n`` and signals the
    completions in row (FIFO) order, matching the per-op scalar path.
    ``comps`` is either one completion object shared by every row or a
    per-row list aligned with ``tags``."""
    kind: CommKind
    peer: int
    lane: int
    packets: List[int]
    tags: List[int]
    comps: Any = None


_op_ids = itertools.count()


def next_op_id() -> int:
    return next(_op_ids)


class Fabric(_attrs.AttrResource):
    """Bounded per-(dst, device) FIFO queues; the NIC send-queue stand-in.

    ``depth`` bounds each queue — a full queue is the paper's "underlying
    network send queue is full" event and surfaces ``retry``.

    ``latency`` (seconds) models the wire: a pushed message only becomes
    drainable ``latency`` after its push.  The default (0) keeps the
    historical instantly-visible behaviour; the multithreaded message-rate
    benchmark uses a nonzero latency so that completion-window waits are
    real and threads can overlap them — the paper's core asynchrony
    argument.  Thread-safety note (DESIGN.md §10): streams are
    single-consumer (the consumer device's progress try-lock serializes
    ``drain``); concurrent producers ride the GIL-atomic deque append, so
    the depth bound is approximate by at most the number of racing
    posters — back-pressure, not an invariant.
    """

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None):
        self.n_ranks = n_ranks
        self.depth = depth
        self.latency = latency
        self._queues: Dict[Tuple[int, int], collections.deque] = {}
        # per-stream weight beyond len(queue): a packed doorbell occupies
        # one deque slot but weighs payload.count messages toward the
        # depth bound, so _extra holds sum(count - 1) per stream.  Same
        # approximate-under-races contract as the depth bound itself.
        self._extra: Dict[Tuple[int, int], int] = {}
        # atomic: producers on any thread bump these concurrently
        self._pushes = AtomicCounter()
        self._full_events = AtomicCounter()
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"fabric_depth": depth, "link_latency": latency}))
        self._export_attr("in_flight", self.in_flight)
        self._export_attr("pushes", lambda: self.pushes)
        self._export_attr("full_events", lambda: self.full_events)

    @property
    def pushes(self) -> int:
        return self._pushes.load()

    @property
    def full_events(self) -> int:
        return self._full_events.load()

    def _q(self, dst: int, device_index: int) -> collections.deque:
        return self._queues.setdefault((dst, device_index),
                                       collections.deque())

    def try_push(self, msg: WireMsg) -> bool:
        q = self._q(msg.dst, msg.device_index)
        if len(q) + self._extra.get((msg.dst, msg.device_index), 0) \
                >= self.depth:
            self._full_events.fetch_add(1)
            return False
        if self.latency:
            msg.ready_at = time.perf_counter() + self.latency
        q.append(msg)
        self._pushes.fetch_add(1)
        return True

    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        """One doorbell: push a burst of messages bound for the SAME
        ``(dst, device_index)`` stream.  Accepts the longest prefix that
        fits under the depth bound (never a subsequence — accepting
        message k+1 after rejecting k would break stream FIFO) and
        returns how many were accepted.  Per-burst costs are paid once:
        one queue lookup, one latency stamp, one deque extend, one
        telemetry FAA — the paper's §4.3 amortization at the device
        boundary."""
        if not msgs:
            return 0
        dst, didx = msgs[0].dst, msgs[0].device_index
        for m in msgs[1:]:
            if m.dst != dst or m.device_index != didx:
                raise FatalError("push_burst: a doorbell rides one "
                                 "(dst, device) stream; got mixed streams")
        q = self._q(dst, didx)
        n = min(len(msgs), max(0, self.depth - len(q)
                               - self._extra.get((dst, didx), 0)))
        if n < len(msgs):
            self._full_events.fetch_add(1)
        if n == 0:
            return 0
        accepted = msgs[:n]
        if self.latency:
            ready = time.perf_counter() + self.latency
            for m in accepted:
                m.ready_at = ready
        q.extend(accepted)
        self._pushes.fetch_add(n)
        return n

    def push_packed(self, msg: WireMsg) -> int:
        """Ring a fused doorbell: ONE descriptor whose :class:`PackedBurst`
        payload carries the whole burst.  The burst weighs ``count``
        messages toward the stream depth bound — split points are
        identical to pushing the rows through :meth:`push_burst` — and
        accepts the longest row prefix that fits (the rejected suffix is
        the caller's to retry).  Per-doorbell costs collapse to one queue
        lookup, one latency stamp, one append, one telemetry FAA.
        Returns the number of rows accepted."""
        burst: PackedBurst = msg.payload
        key = (msg.dst, msg.device_index)
        q = self._q(*key)
        n = min(burst.count,
                max(0, self.depth - len(q) - self._extra.get(key, 0)))
        if n < burst.count:
            self._full_events.fetch_add(1)
        if n == 0:
            return 0
        if n < burst.count:                  # prefix-accept split
            pb = burst.prefix(n)
            msg = dataclasses.replace(msg, payload=pb,
                                      size=int(pb.data.nbytes))
        if self.latency:
            msg.ready_at = time.perf_counter() + self.latency
        q.append(msg)
        if n > 1:
            self._extra[key] = self._extra.get(key, 0) + n - 1
        self._pushes.fetch_add(n)
        return n

    def ready(self, dst: int, device_index: int) -> bool:
        """Cheap unlocked readiness probe: is at least one message on
        this stream due for delivery?  The poll-before-lock doorbell
        check — idle progress passes branch on this instead of paying
        the lock + telemetry + drain machinery to discover nothing.
        Safe without the stream lock: a stale True costs one full pass,
        a stale False is indistinguishable from polling a hair earlier."""
        q = self._queues.get((dst, device_index))
        if not q:
            return False
        if not self.latency:
            return True
        try:
            return q[0].ready_at <= time.perf_counter()
        except IndexError:            # racing drain emptied the stream
            return False

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        """Pop ready messages from one stream.  ``limit`` bounds the
        burst: ``limit == 0`` means "drain all" (every currently-ready
        message), ``limit > 0`` caps the batch at that many messages per
        call; ``limit < 0`` is an error."""
        if limit < 0:
            raise ValueError(f"drain: limit must be >= 0 (0 = drain all), "
                             f"got {limit}")
        q = self._q(dst, device_index)
        n = len(q) if limit == 0 else min(limit, len(q))
        if not self.latency:
            out = [q.popleft() for _ in range(n)]
        else:
            # latency model: streams are FIFO, so stop at the first message
            # still "on the wire"
            now = time.perf_counter()
            out = []
            while len(out) < n and q and q[0].ready_at <= now:
                out.append(q.popleft())
        # settle the packed-weight surplus — only streams that actually
        # carried fused doorbells pay the scan (scalar drains skip it)
        key = (dst, device_index)
        ex = self._extra.get(key)
        if ex:
            dec = sum(m.payload.count - 1 for m in out
                      if m.kind in PACKED_KINDS)
            if dec:
                self._extra[key] = ex - dec
        return out

    def stream_depth(self, dst: int, device_index: int) -> int:
        """Queued messages on one stream (including not-yet-drainable
        ones; a packed doorbell counts its row count) — the lock-free
        idle probe progress drivers use to skip a quiet device without
        paying for a full locked pass."""
        q = self._queues.get((dst, device_index))
        if q is None:
            return 0
        return len(q) + self._extra.get((dst, device_index), 0)

    def in_flight(self) -> int:
        """Total queued messages (including not-yet-drainable ones);
        packed doorbells count their row counts."""
        return (sum(len(q) for q in self._queues.values())
                + sum(self._extra.values()))

    def pending_to(self, dst: int) -> int:
        return sum(len(q) + self._extra.get(k, 0)
                   for k, q in self._queues.items() if k[0] == dst)

    def pending_streams(self, dst: int) -> List[int]:
        """Device-stream indices with traffic queued toward ``dst``."""
        return sorted(i for (d, i), q in self._queues.items()
                      if d == dst and q)


# ---------------------------------------------------------------------------
# memory registration (paper §3.3.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemoryRegion:
    """Registered memory: mandatory for remote buffers (RMA targets)."""
    rid: int
    buf: np.ndarray                # 1-D uint8 view of the registered range


def as_bytes_view(buf: Any) -> np.ndarray:
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8)
    if isinstance(buf, (bytearray, memoryview)):
        return np.frombuffer(buf, dtype=np.uint8)
    raise FatalError(f"cannot register memory of type {type(buf)}")


def payload_to_bytes(buf: Any) -> np.ndarray:
    """Materialize a payload (or buffer list, §3.3.1) as bytes."""
    if isinstance(buf, (list, tuple)):
        parts = [payload_to_bytes(b) for b in buf]
        return (np.concatenate(parts) if parts
                else np.zeros(0, np.uint8))
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8).copy()
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(buf), dtype=np.uint8)
    raise FatalError(f"unsupported payload type {type(buf)}")


def payloads_to_bytes(bufs: Sequence[Any]) -> List[np.ndarray]:
    """Stage a burst's payloads — ONE stacked copy instead of K.

    When every payload is an ``np.ndarray`` sharing one dtype and shape
    (the windowed-benchmark common case), the whole burst is materialized
    with a single ``np.stack(bufs)`` — one vectorized memcpy, no
    per-element Python conversion at all — and each message gets a row
    view of the stacked array (rows are independent snapshots, so source
    buffers stay reusable exactly like :func:`payload_to_bytes`).
    Same-sized arrays of *mixed* dtype stack through per-item flat byte
    views (still one burst-sized copy, byte-exact per payload); ragged
    or non-array bursts fall back to per-payload copies."""
    if len(bufs) <= 1:
        return [payload_to_bytes(b) for b in bufs]
    first = bufs[0]
    if isinstance(first, np.ndarray):
        dt, shape, nbytes = first.dtype, first.shape, first.nbytes
        if all(isinstance(b, np.ndarray) and b.dtype == dt
               and b.shape == shape for b in bufs):
            stacked = np.stack(bufs)                  # the ONE copy
            return list(stacked.reshape(len(bufs), -1).view(np.uint8))
        if all(isinstance(b, np.ndarray) and b.nbytes == nbytes
               for b in bufs):
            # mixed dtype/shape but same byte size: np.stack reads
            # per-item flat byte views and performs the single copy
            stacked = np.stack([
                b if b.dtype == np.uint8 and b.ndim == 1
                else b.reshape(-1).view(np.uint8)
                for b in bufs])
            return list(stacked)                      # row views, no copy
    return [payload_to_bytes(b) for b in bufs]


def pack_payloads(bufs: Sequence[Any], wire_bf16: bool = False
                  ) -> Tuple[np.ndarray, np.ndarray, Optional[str]]:
    """Stage a fused doorbell: ONE dtype-normalized copy builds the
    packed wire image (DESIGN.md §13).  Returns ``(data, sizes,
    wire_dtype)`` for a :class:`PackedBurst`: ``data`` is ``(K,
    row_bytes)`` uint8, ``sizes[i]`` the delivered byte size of row
    ``i``.

    Fast paths, in order:

    * every element is the SAME array object (a repeated payload — the
      message-rate hot loop): one row snapshot, broadcast K ways with no
      further copying;
    * uniform dtype+shape ndarrays: one ``np.stack``;
    * anything else: per-row byte staging into a zero-padded matrix.

    ``wire_bf16`` compresses float32 bursts to bf16 on the wire at zero
    marginal cost (the cast IS the staging copy); it applies only on the
    uniform-f32 fast paths — mixed bursts ship uncompressed — and
    ``sizes`` always reports the *delivered* (f32) byte size."""
    k = len(bufs)
    first = bufs[0]
    if isinstance(first, np.ndarray):
        # identity probe runs at C speed: 64-element bursts are common
        # and a Python-level ``all(b is first ...)`` genexpr shows up in
        # the message-rate profile
        if len(set(map(id, bufs))) == 1:
            flat = first.reshape(-1)
            if wire_bf16 and first.dtype == np.float32:
                row = flat.astype(ml_dtypes.bfloat16).view(np.uint8)
                wire_dtype = "bf16"
            else:
                row = flat.view(np.uint8).copy()      # the one snapshot
                wire_dtype = None
            data = np.broadcast_to(row, (k, row.size))
            return data, np.full(k, first.nbytes, np.int64), wire_dtype
        dt, shape = first.dtype, first.shape
        if all(isinstance(b, np.ndarray) and b.dtype == dt
               and b.shape == shape for b in bufs):
            flat = np.stack(bufs).reshape(k, -1)      # the ONE copy
            if wire_bf16 and dt == np.float32:
                return (flat.astype(ml_dtypes.bfloat16).view(np.uint8),
                        np.full(k, first.nbytes, np.int64), "bf16")
            return (flat.view(np.uint8),
                    np.full(k, first.nbytes, np.int64), None)
    rows = [payload_to_bytes(b) for b in bufs]
    sizes = np.fromiter((r.nbytes for r in rows), np.int64, k)
    data = np.zeros((k, int(sizes.max(initial=0))), np.uint8)
    for i, r in enumerate(rows):
        data[i, :r.nbytes] = r
    return data, sizes, None
