"""The simulated interconnect: wire messages, queues, registered memory.

The :class:`Fabric` stands in for the NIC/ICI: per ``(dst-rank,
device-stream)`` bounded FIFO queues.  A full queue surfaces ``retry`` —
the same back-pressure path a full ibv send queue triggers in the paper
(§4.4) — and the progress engine moves such requests through the backlog
queue.  Messages are keyed by the *sender's* device index, so each device
stream is an independent, ordered channel: replicating devices replicates
streams, which is exactly the paper's resource-replication story (§3.2.3).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import attrs as _attrs
from ..completion import CompletionObject
from ..concurrency.atomics import AtomicCounter
from ..matching import MatchingPolicy
from ..post import CommKind
from ..status import FatalError

#: attrs the fabric resolves at alloc time
FABRIC_ATTRS = ("fabric_depth", "link_latency")


class WireKind:
    EAGER_SEND = "eager_send"      # send-recv eager payload
    EAGER_AM = "eager_am"          # active-message eager payload
    RTS = "rts"                    # rendezvous request-to-send
    CTS = "cts"                    # rendezvous clear-to-send
    RDMA_PAYLOAD = "rdma_payload"  # rendezvous data movement (zero-copy)
    PUT = "put"                    # RMA put (optionally with signal)
    GET_REQ = "get_req"            # RMA get request
    GET_RESP = "get_resp"          # RMA get response


@dataclasses.dataclass
class WireMsg:
    kind: str
    src: int
    dst: int
    tag: int = 0
    payload: Any = None
    size: int = 0
    rcomp: Optional[int] = None
    matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG
    # rendezvous bookkeeping
    op_id: int = -1                # source-side pending-op id
    remote_buf: Any = None         # (region_id, offset) for RMA
    device_index: int = 0          # which device stream this rides
    ready_at: float = 0.0          # wire-latency model: drainable after this


@dataclasses.dataclass
class PendingOp:
    """Source-side state for a posted (not yet complete) operation."""
    kind: CommKind
    buf: Any
    size: int
    tag: int
    peer: int
    local_comp: Optional[CompletionObject]
    packet: int = -1               # bufcopy: packet id to return to the pool
    lane: int = 0
    user_context: Any = None


_op_ids = itertools.count()


def next_op_id() -> int:
    return next(_op_ids)


class Fabric(_attrs.AttrResource):
    """Bounded per-(dst, device) FIFO queues; the NIC send-queue stand-in.

    ``depth`` bounds each queue — a full queue is the paper's "underlying
    network send queue is full" event and surfaces ``retry``.

    ``latency`` (seconds) models the wire: a pushed message only becomes
    drainable ``latency`` after its push.  The default (0) keeps the
    historical instantly-visible behaviour; the multithreaded message-rate
    benchmark uses a nonzero latency so that completion-window waits are
    real and threads can overlap them — the paper's core asynchrony
    argument.  Thread-safety note (DESIGN.md §10): streams are
    single-consumer (the consumer device's progress try-lock serializes
    ``drain``); concurrent producers ride the GIL-atomic deque append, so
    the depth bound is approximate by at most the number of racing
    posters — back-pressure, not an invariant.
    """

    def __init__(self, n_ranks: int, depth: int = 4096,
                 latency: float = 0.0,
                 resolved: Optional[_attrs.ResolvedAttrs] = None):
        self.n_ranks = n_ranks
        self.depth = depth
        self.latency = latency
        self._queues: Dict[Tuple[int, int], collections.deque] = {}
        # atomic: producers on any thread bump these concurrently
        self._pushes = AtomicCounter()
        self._full_events = AtomicCounter()
        self._init_attrs(resolved or _attrs.resolved_from_values(
            {"fabric_depth": depth, "link_latency": latency}))
        self._export_attr("in_flight", self.in_flight)
        self._export_attr("pushes", lambda: self.pushes)
        self._export_attr("full_events", lambda: self.full_events)

    @property
    def pushes(self) -> int:
        return self._pushes.load()

    @property
    def full_events(self) -> int:
        return self._full_events.load()

    def _q(self, dst: int, device_index: int) -> collections.deque:
        return self._queues.setdefault((dst, device_index),
                                       collections.deque())

    def try_push(self, msg: WireMsg) -> bool:
        q = self._q(msg.dst, msg.device_index)
        if len(q) >= self.depth:
            self._full_events.fetch_add(1)
            return False
        if self.latency:
            msg.ready_at = time.perf_counter() + self.latency
        q.append(msg)
        self._pushes.fetch_add(1)
        return True

    def push_burst(self, msgs: Sequence[WireMsg]) -> int:
        """One doorbell: push a burst of messages bound for the SAME
        ``(dst, device_index)`` stream.  Accepts the longest prefix that
        fits under the depth bound (never a subsequence — accepting
        message k+1 after rejecting k would break stream FIFO) and
        returns how many were accepted.  Per-burst costs are paid once:
        one queue lookup, one latency stamp, one deque extend, one
        telemetry FAA — the paper's §4.3 amortization at the device
        boundary."""
        if not msgs:
            return 0
        dst, didx = msgs[0].dst, msgs[0].device_index
        for m in msgs[1:]:
            if m.dst != dst or m.device_index != didx:
                raise FatalError("push_burst: a doorbell rides one "
                                 "(dst, device) stream; got mixed streams")
        q = self._q(dst, didx)
        n = min(len(msgs), max(0, self.depth - len(q)))
        if n < len(msgs):
            self._full_events.fetch_add(1)
        if n == 0:
            return 0
        accepted = msgs[:n]
        if self.latency:
            ready = time.perf_counter() + self.latency
            for m in accepted:
                m.ready_at = ready
        q.extend(accepted)
        self._pushes.fetch_add(n)
        return n

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        """Pop ready messages from one stream.  ``limit`` bounds the
        burst: ``limit == 0`` means "drain all" (every currently-ready
        message), ``limit > 0`` caps the batch at that many messages per
        call; ``limit < 0`` is an error."""
        if limit < 0:
            raise ValueError(f"drain: limit must be >= 0 (0 = drain all), "
                             f"got {limit}")
        q = self._q(dst, device_index)
        n = len(q) if limit == 0 else min(limit, len(q))
        if not self.latency:
            return [q.popleft() for _ in range(n)]
        # latency model: streams are FIFO, so stop at the first message
        # still "on the wire"
        now = time.perf_counter()
        out: List[WireMsg] = []
        while len(out) < n and q and q[0].ready_at <= now:
            out.append(q.popleft())
        return out

    def stream_depth(self, dst: int, device_index: int) -> int:
        """Queued messages on one stream (including not-yet-drainable
        ones) — the lock-free idle probe progress drivers use to skip a
        quiet device without paying for a full locked pass."""
        q = self._queues.get((dst, device_index))
        return len(q) if q is not None else 0

    def in_flight(self) -> int:
        """Total queued messages (including not-yet-drainable ones)."""
        return sum(len(q) for q in self._queues.values())

    def pending_to(self, dst: int) -> int:
        return sum(len(q) for (d, _), q in self._queues.items() if d == dst)

    def pending_streams(self, dst: int) -> List[int]:
        """Device-stream indices with traffic queued toward ``dst``."""
        return sorted(i for (d, i), q in self._queues.items()
                      if d == dst and q)


# ---------------------------------------------------------------------------
# memory registration (paper §3.3.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemoryRegion:
    """Registered memory: mandatory for remote buffers (RMA targets)."""
    rid: int
    buf: np.ndarray                # 1-D uint8 view of the registered range


def as_bytes_view(buf: Any) -> np.ndarray:
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8)
    if isinstance(buf, (bytearray, memoryview)):
        return np.frombuffer(buf, dtype=np.uint8)
    raise FatalError(f"cannot register memory of type {type(buf)}")


def payload_to_bytes(buf: Any) -> np.ndarray:
    """Materialize a payload (or buffer list, §3.3.1) as bytes."""
    if isinstance(buf, (list, tuple)):
        parts = [payload_to_bytes(b) for b in buf]
        return (np.concatenate(parts) if parts
                else np.zeros(0, np.uint8))
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8).copy()
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(buf), dtype=np.uint8)
    raise FatalError(f"unsupported payload type {type(buf)}")


def payloads_to_bytes(bufs: Sequence[Any]) -> List[np.ndarray]:
    """Stage a burst's payloads — ONE stacked copy instead of K.

    When every payload is a same-sized ``np.ndarray`` (the windowed-
    benchmark common case), the whole burst is materialized with a single
    ``np.stack`` — one vectorized memcpy — and each message gets a row
    view of the stacked array (rows are independent snapshots, so source
    buffers stay reusable exactly like :func:`payload_to_bytes`).  Ragged
    or non-array bursts fall back to per-payload copies."""
    if len(bufs) <= 1:
        return [payload_to_bytes(b) for b in bufs]
    first = bufs[0]
    if isinstance(first, np.ndarray):
        nbytes = first.nbytes
        if all(isinstance(b, np.ndarray) and b.nbytes == nbytes
               for b in bufs):
            # flat uint8 payloads (the hot case) stack as-is; anything
            # else gets a per-item flat byte view first — np.stack reads
            # the views and performs the single burst-sized copy
            stacked = np.stack([
                b if b.dtype == np.uint8 and b.ndim == 1
                else b.reshape(-1).view(np.uint8)
                for b in bufs])
            return list(stacked)                      # row views, no copy
    return [payload_to_bytes(b) for b in bufs]
