"""Rendezvous (RTS/CTS/RDMA) and RMA handling — the zero-copy protocol.

The paper's §4.3 zero-copy path: a large send posts an **RTS** carrying
only metadata; the receiver matches it, pins a landing zone, and answers
**CTS**; the sender then moves the payload with a single RDMA write into
the landing zone.  RMA put/get ride the same machinery minus matching:
the remote buffer is a registered :class:`~.fabric.MemoryRegion`.

All per-handshake state (the CTS landing zones and the shared pending-op
table) lives on the owning :class:`~repro.core.runtime.Runtime`, so any
number of :class:`~.engine.ProgressEngine` instances — one shared engine
or one per device — can drive the reactions without coordination.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from ..post import CommKind
from ..protocol import Protocol
from ..status import FatalError, Status, done, posted
from .fabric import (MemoryRegion, PendingOp, WireKind, WireMsg,
                     as_bytes_view, next_op_id, payload_to_bytes)


class RendezvousManager:
    """Owns the CTS landing zones and reacts to handshake/RMA messages."""

    def __init__(self, runtime):
        self.rt = runtime
        self.landing: list = []    # rendezvous landing zones (CTS state)

    # -- source side ---------------------------------------------------------
    def post_rts(self, engine, kind: CommKind, rank: int, buf: Any,
                 tag: int, size: int, local_comp, remote_comp,
                 matching_policy, dev, allow_retry: bool,
                 user_context: Any) -> Status:
        """Start a zero-copy transfer: register the pending op, wire an RTS."""
        rt = self.rt
        op_id = next_op_id()
        rt.pending_ops[op_id] = PendingOp(kind, buf, size, tag, rank,
                                          local_comp, lane=dev.lane,
                                          user_context=user_context)
        msg = WireMsg(WireKind.RTS, rt.rank, rank, tag=tag, size=size,
                      rcomp=remote_comp, matching_policy=matching_policy,
                      op_id=op_id, device_index=dev.index)
        rt.stats.handshakes += 1
        st = engine.submit(msg, dev, allow_retry)
        if st.is_retry():
            del rt.pending_ops[op_id]
        else:
            rt.stats.record(Protocol.ZEROCOPY, size)
        return st

    def post_put(self, engine, kind: CommKind, rank: int, buf: Any,
                 tag: int, size: int, local_comp, remote_buf, remote_comp,
                 dev, allow_retry: bool) -> Status:
        rt = self.rt
        op_id = next_op_id()
        rt.pending_ops[op_id] = PendingOp(kind, buf, size, tag, rank,
                                          local_comp, lane=dev.lane)
        msg = WireMsg(WireKind.PUT, rt.rank, rank, tag=tag,
                      payload=payload_to_bytes(buf), size=size,
                      rcomp=remote_comp, remote_buf=remote_buf,
                      op_id=op_id, device_index=dev.index)
        st = engine.submit(msg, dev, allow_retry)
        if st.is_retry():
            del rt.pending_ops[op_id]
            return st
        rt.stats.record(Protocol.ZEROCOPY, size)
        return posted(ctx=op_id)

    def post_get(self, engine, rank: int, buf: Any, tag: int, size: int,
                 local_comp, remote_buf, dev, allow_retry: bool) -> Status:
        rt = self.rt
        op_id = next_op_id()
        rt.pending_ops[op_id] = PendingOp(CommKind.GET, buf, size, tag, rank,
                                          local_comp, lane=dev.lane)
        msg = WireMsg(WireKind.GET_REQ, rt.rank, rank, tag=tag, size=size,
                      remote_buf=remote_buf, op_id=op_id,
                      device_index=dev.index)
        st = engine.submit(msg, dev, allow_retry)
        if st.is_retry():
            del rt.pending_ops[op_id]
            return st
        rt.stats.record(Protocol.ZEROCOPY, size)
        return posted(ctx=op_id)

    # -- target side ---------------------------------------------------------
    def reply_cts(self, rts: WireMsg, recv_buf: Any, recv_comp, dev) -> None:
        cts = WireMsg(WireKind.CTS, self.rt.rank, rts.src, tag=rts.tag,
                      op_id=rts.op_id, device_index=rts.device_index)
        cts.payload = (len(self.landing),)
        self.landing.append((recv_buf, recv_comp, dev))
        self.rt.stats.handshakes += 1
        if not self.rt.fabric.try_push(cts):
            dev.backlog.push(("wire", cts))
        else:
            dev.count_push()

    # -- reactions (called from ProgressEngine._react) -----------------------
    def on_rts(self, engine, msg: WireMsg, dev) -> None:
        from ..matching import MatchKind, make_key
        if msg.rcomp is not None:           # zero-copy active message
            # allocate a landing buffer and CTS straight away
            landing = np.zeros(msg.size, np.uint8)
            comp = self.rt.rcomp_registry[msg.rcomp]
            self.reply_cts(msg, landing, comp, dev)
            return
        key = make_key(msg.src, msg.tag, msg.matching_policy)
        match = self.rt.matching.insert(key, MatchKind.SEND, ("rts", msg))
        if match is not None:
            _, buf, comp, rdev = match
            self.reply_cts(msg, buf, comp, dev)

    def on_cts(self, engine, msg: WireMsg, dev) -> None:
        op = self.rt.pending_ops.pop(msg.op_id, None)
        if op is None:
            raise FatalError("CTS for unknown op")
        landing_idx = msg.payload[0]
        data = payload_to_bytes(op.buf)
        rdma = WireMsg(WireKind.RDMA_PAYLOAD, self.rt.rank, msg.src,
                       tag=op.tag, payload=data, size=op.size,
                       op_id=landing_idx, device_index=msg.device_index)
        if not self.rt.fabric.try_push(rdma):
            dev.backlog.push(("wire", rdma))
        else:
            dev.count_push()
        engine.signal(op.local_comp, done(rank=op.peer, tag=op.tag), dev)

    def on_rdma_payload(self, engine, msg: WireMsg, dev) -> None:
        buf, comp, rdev = self.landing[msg.op_id]
        engine.deliver_recv(buf, msg.payload, comp, msg.src, msg.tag, dev)

    def on_put(self, engine, msg: WireMsg, dev) -> None:
        region_id, offset = msg.remote_buf
        region: MemoryRegion = self.rt.memory_regions[region_id]
        region.buf[offset:offset + msg.size] = msg.payload[:msg.size]
        if msg.rcomp is not None:           # put with signal
            comp = self.rt.rcomp_registry[msg.rcomp]
            engine.signal(comp, done(msg.payload, rank=msg.src, tag=msg.tag),
                          dev)

    def on_get_req(self, engine, msg: WireMsg, dev) -> None:
        region_id, offset = msg.remote_buf
        region = self.rt.memory_regions[region_id]
        data = region.buf[offset:offset + msg.size].copy()
        resp = WireMsg(WireKind.GET_RESP, self.rt.rank, msg.src,
                       tag=msg.tag, payload=data, size=msg.size,
                       op_id=msg.op_id, device_index=msg.device_index)
        if not self.rt.fabric.try_push(resp):
            dev.backlog.push(("wire", resp))
        else:
            dev.count_push()

    def on_get_resp(self, engine, msg: WireMsg, dev) -> None:
        op = self.rt.pending_ops.pop(msg.op_id, None)
        if op is None:
            raise FatalError("GET_RESP for unknown op")
        view = as_bytes_view(op.buf)
        view[:msg.size] = msg.payload[:msg.size]
        engine.signal(op.local_comp, done(msg.payload, rank=op.peer,
                                          tag=op.tag), dev)
