"""The progress-engine subsystem: fabric, reactions, rendezvous, endpoints.

Layout (each file one concern; the paper's Figure-1 chain in engine.py):

* :mod:`.fabric` — registered memory, pending-op records, payload staging
  (the wire types and the :class:`Fabric` implementation itself live in
  :mod:`repro.core.transport`; re-exported here for compatibility).
* :mod:`.engine` — :class:`ProgressEngine`: posting + the reaction chain
  (drain backlog -> source completions -> poll incoming -> react).
* :mod:`.rendezvous` — :class:`RendezvousManager`: RTS/CTS/RDMA handshake
  and RMA put/get handling.
* :mod:`.endpoint` — :class:`Endpoint`/:class:`EndpointSpec`: named
  multi-device bundles with striping + progress policies.
"""
from .endpoint import (ENDPOINT_ATTRS, PROGRESS_POLICIES,
                       STRIPE_POLICIES, Endpoint, EndpointSpec)
from .engine import ProgressEngine
from .fabric import (Fabric, MemoryRegion, PackedBurst, PendingBurst,
                     PendingOp, WireKind, WireMsg, as_bytes_view,
                     next_op_id, pack_payloads, payload_to_bytes,
                     payloads_to_bytes)
from .reliability import RELIABILITY_ATTRS, ReliabilityManager
from .rendezvous import RendezvousManager

__all__ = [
    "ENDPOINT_ATTRS", "Endpoint", "EndpointSpec", "Fabric", "MemoryRegion", "PendingOp",
    "PackedBurst", "PendingBurst", "pack_payloads",
    "ProgressEngine", "RELIABILITY_ATTRS", "ReliabilityManager",
    "RendezvousManager", "WireKind", "WireMsg",
    "PROGRESS_POLICIES", "STRIPE_POLICIES", "as_bytes_view", "next_op_id",
    "payload_to_bytes", "payloads_to_bytes",
]
