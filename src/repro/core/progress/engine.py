"""The progress engine — posting plus the paper's Figure-1 reaction chain.

Progress (§3.2.6) is explicit: nothing moves unless someone drives a
:class:`ProgressEngine` over a device.  One progress pass implements the
reaction chain:

    drain backlog -> poll source completions -> poll incoming -> react
    (match, signal, rendezvous, replenish)

Engines are *drivers*, not state: the pending-op table, matching engine,
packet pool and landing zones all live on the owning ``Runtime``, so a
single shared engine and a fleet of dedicated per-device engines (the
paper's shared/dedicated resource split, :class:`~repro.core.modes.CommMode`)
are interchangeable — an :class:`~.endpoint.Endpoint`'s progress policy
picks between them per workload.
"""
from __future__ import annotations

from typing import List, Optional

from ..completion import CompletionObject
from ..concurrency.atomics import AtomicCounter
from ..matching import MatchKind, MatchingPolicy, make_key
from ..post import CommKind
from ..protocol import Protocol, select_protocol
from ..status import ErrorCode, FatalError, Status, done, posted, retry
from .fabric import (PendingOp, WireKind, WireMsg, as_bytes_view,
                     next_op_id, payload_to_bytes)


class ProgressEngine:
    """Drives posting and progress for a runtime's devices.

    ``devices=None`` means "whatever the runtime currently owns" (the
    shared-engine mode); a dedicated engine is constructed with the
    single device it is responsible for.
    """

    def __init__(self, runtime, devices: Optional[List] = None,
                 name: str = "engine"):
        self.rt = runtime
        self._devices = devices
        self.name = name
        # telemetry (paper's do_background_work counters) — atomic: a
        # shared engine is driven from many threads at once
        self._passes = AtomicCounter()
        self._reactions = AtomicCounter()

    @property
    def passes(self) -> int:
        return self._passes.load()

    @property
    def reactions(self) -> int:
        return self._reactions.load()

    @property
    def devices(self) -> List:
        return self.rt.devices if self._devices is None else self._devices

    def __repr__(self) -> str:
        scope = "shared" if self._devices is None else \
            f"dedicated[{','.join(str(d.index) for d in self._devices)}]"
        return f"ProgressEngine({self.name!r}, {scope})"

    # -- posting (called via Runtime._post / post.post_comm) -----------------
    def post(self, *, kind: CommKind, rank: int, buf, tag: int,
             size: int, local_comp, remote_buf, remote_comp, device,
             matching_policy: MatchingPolicy, allow_retry: bool,
             user_context) -> Status:
        rt = self.rt
        dev = device or rt.default_device
        dev.count_post()
        if rank < 0 or rank >= rt.n_ranks:
            raise FatalError(f"bad target rank {rank}")

        if kind == CommKind.RECV:
            return self._post_recv(rank, buf, tag, size, local_comp, dev,
                                   matching_policy)
        if kind == CommKind.GET:
            return rt.rdv.post_get(self, rank, buf, tag, size, local_comp,
                                   remote_buf, dev, allow_retry)
        if kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
            return rt.rdv.post_put(self, kind, rank, buf, tag, size,
                                   local_comp, remote_buf, remote_comp,
                                   dev, allow_retry)

        # SEND / AM with inject | bufcopy | zerocopy
        proto = select_protocol(size, rt.config)
        if proto == Protocol.ZEROCOPY:
            return rt.rdv.post_rts(self, kind, rank, buf, tag, size,
                                   local_comp, remote_comp, matching_policy,
                                   dev, allow_retry, user_context)

        packet = -1
        if proto == Protocol.BUFCOPY:
            packet, pst = rt.packet_pool.get(dev.lane)
            if pst.is_retry():
                rt.stats.retries += 1
                if allow_retry:
                    return pst
                # user disallowed retry: park in the backlog (paper §4.4)
                dev.backlog.push(("post", kind, rank, buf, tag, size,
                                  local_comp, remote_comp, matching_policy,
                                  user_context))
                return posted(code=ErrorCode.POSTED_BACKLOG)
            # stage payload into the packet (buffer-copy)
            data = payload_to_bytes(buf)
            if data.nbytes > rt.packet_pool.packet_bytes:
                rt.packet_pool.put(dev.lane, packet)
                raise FatalError("bufcopy payload exceeds packet size")

        wire_kind = (WireKind.EAGER_AM if kind == CommKind.AM
                     else WireKind.EAGER_SEND)
        op_id = -1
        if proto == Protocol.BUFCOPY:
            op_id = next_op_id()
            rt.pending_ops[op_id] = PendingOp(kind, buf, size, tag, rank,
                                              local_comp, packet=packet,
                                              lane=dev.lane,
                                              user_context=user_context)
        msg = WireMsg(wire_kind, rt.rank, rank, tag=tag,
                      payload=payload_to_bytes(buf), size=size,
                      rcomp=remote_comp, matching_policy=matching_policy,
                      op_id=op_id, device_index=dev.index)
        st = self.submit(msg, dev, allow_retry)
        if st.is_retry():
            if packet >= 0:
                rt.packet_pool.put(dev.lane, packet)
                del rt.pending_ops[op_id]
            return st
        rt.stats.record(proto, size)
        if proto == Protocol.INJECT:
            if st.code == ErrorCode.POSTED_BACKLOG:
                # the wire push was deferred; the payload is already copied
                # so the source buffer is reusable, but the op has not hit
                # the network — report the backlog, not done.  Inject ops
                # never signal completion objects (paper §3.2.5).
                return st
            # inject completes immediately; comps are NOT signaled (paper)
            return done(code=ErrorCode.DONE_INLINE, rank=rank, tag=tag)
        return posted(ctx=op_id)

    def submit(self, msg: WireMsg, dev, allow_retry: bool) -> Status:
        """Push to the fabric; full queue -> retry or backlog."""
        rt = self.rt
        if rt.fabric.try_push(msg):
            dev.count_push()
            # source completion for bufcopy/zerocopy is deferred to progress
            if msg.op_id >= 0:
                dev.pending_tx.append(msg.op_id)
            return posted()
        rt.stats.retries += 1
        if allow_retry:
            return retry(ErrorCode.RETRY_LOCKED)
        st = dev.backlog.push(("wire", msg))
        if st.is_retry():
            return st
        if msg.op_id >= 0:
            dev.pending_tx.append(msg.op_id)
        return posted(code=ErrorCode.POSTED_BACKLOG)

    def _post_recv(self, rank: int, buf, tag: int, size: int,
                   local_comp, dev, policy: MatchingPolicy) -> Status:
        key = make_key(rank, tag, policy)
        match = self.rt.matching.insert(key, MatchKind.RECV,
                                        ("recv", buf, local_comp, dev))
        if match is None:
            return posted(code=ErrorCode.POSTED_UNMATCHED)
        mkind, *rest = match
        if mkind == "eager":
            payload, src, mtag = rest
            if buf is not None:               # fill the posted buffer too
                view = as_bytes_view(buf)
                n = min(view.nbytes, payload.nbytes)
                view[:n] = payload[:n]
            # done => completion objects will NOT be signaled (paper §3.2.5)
            return done(payload, rank=src, tag=mtag)
        if mkind == "rts":
            msg = rest[0]
            self.rt.rdv.reply_cts(msg, buf, local_comp, dev)
            return posted()
        raise FatalError(f"unexpected match kind {mkind}")

    # -- progress (§3.2.6, Figure 1) -----------------------------------------
    def progress(self, device=None, max_msgs: int = 0) -> bool:
        """Drive one progress pass on ``device``; returns True if any work
        was done (paper: do_background_work).

        The pass runs under the device's progress try-lock (blocking spin
        here — single-threaded callers never contend), so the reaction
        chain is single-writer per device even when worker threads drive
        the same engine; use :meth:`try_progress` for the paper's
        fail-and-move-on discipline."""
        dev = device or (self._devices[0] if self._devices
                         else self.rt.default_device)
        with dev.progress_lock:
            return self._progress_locked(dev, max_msgs)

    def try_progress(self, device=None, max_msgs: int = 0):
        """Non-blocking progress (paper §4.2.3: "multiple threads call
        progress; a thread that fails the try-lock moves on").  Returns
        ``None`` when the device is being progressed by another thread,
        else the pass's did-work bool."""
        dev = device or (self._devices[0] if self._devices
                         else self.rt.default_device)
        if not dev.progress_lock.try_acquire():
            return None
        try:
            return self._progress_locked(dev, max_msgs)
        finally:
            dev.progress_lock.release()

    def _progress_locked(self, dev, max_msgs: int = 0) -> bool:
        rt = self.rt
        dev.count_progress()
        self._passes.fetch_add(1)
        did = False

        # (3) retry backlogged requests first
        while not dev.backlog.empty_flag:
            item, st = dev.backlog.pop()
            if st.is_retry():
                break
            tag0 = item[0]
            if tag0 == "wire":
                msg = item[1]
                if not rt.fabric.try_push(msg):
                    # requeue at the HEAD: a tail push would let a later
                    # same-stream message overtake this one once the
                    # fabric frees up (push_front never fails)
                    dev.backlog.push_front(item)
                    break
                dev.count_push()
                if msg.op_id >= 0:
                    dev.pending_tx.append(msg.op_id)
                did = True
            elif tag0 == "post":
                (_, kind, rank, buf, tag, size, local_comp, remote_comp,
                 policy, uctx) = item
                st2 = self.post(kind=kind, rank=rank, buf=buf, tag=tag,
                                size=size, local_comp=local_comp,
                                remote_buf=None, remote_comp=remote_comp,
                                device=dev, matching_policy=policy,
                                allow_retry=True, user_context=uctx)
                if st2.is_retry():
                    dev.backlog.push_front(item)   # keep FIFO redelivery
                    break
                did = True
            elif tag0 == "signal":
                # a completion object rejected this signal earlier
                # (retry(RETRY_QUEUE_FULL)); redeliver until accepted.
                # Requeue at the HEAD on rejection: pushing to the tail
                # would rotate parked signals and deliver later
                # completions to the same queue out of order.
                _, comp, st2 = item
                if comp.signal(st2).is_retry():
                    dev.backlog.push_front(item)
                    break
                did = True

        # source-side completions (bufcopy send done on the wire)
        while dev.pending_tx:
            op_id = dev.pending_tx.popleft()
            op = rt.pending_ops.get(op_id)
            if op is None:
                continue
            if op.kind in (CommKind.SEND, CommKind.AM):
                if op.packet >= 0:              # return packet to the pool
                    rt.packet_pool.put(op.lane, op.packet)
                    self.signal(op.local_comp,
                                done(rank=op.peer, tag=op.tag), dev)
                    del rt.pending_ops[op_id]
                # zerocopy sends complete on CTS+RDMA, not here
            elif op.kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
                self.signal(op.local_comp, done(rank=op.peer, tag=op.tag),
                            dev)
                del rt.pending_ops[op_id]
            did = True

        # (4) poll incoming for this device stream and react
        for msg in rt.fabric.drain(rt.rank, dev.index, max_msgs):
            self._react(msg, dev)
            did = True
        return did

    def progress_all(self, rounds: int = 1, max_msgs: int = 0) -> int:
        """Drive every device this engine is responsible for."""
        n = 0
        for _ in range(rounds):
            for dev in self.devices:
                n += bool(self.progress(dev, max_msgs))
        return n

    def _react(self, msg: WireMsg, dev) -> None:
        rt = self.rt
        self._reactions.fetch_add(1)
        k = msg.kind
        if k == WireKind.EAGER_AM:
            comp = rt.rcomp_registry[msg.rcomp]
            self.signal(comp, done(msg.payload, rank=msg.src, tag=msg.tag),
                        dev)
        elif k == WireKind.EAGER_SEND:
            key = make_key(msg.src, msg.tag, msg.matching_policy)
            match = rt.matching.insert(
                key, MatchKind.SEND, ("eager", msg.payload, msg.src, msg.tag))
            if match is not None:
                _, buf, comp, rdev = match
                self.deliver_recv(buf, msg.payload, comp, msg.src, msg.tag,
                                  dev)
        elif k == WireKind.RTS:
            rt.rdv.on_rts(self, msg, dev)
        elif k == WireKind.CTS:
            rt.rdv.on_cts(self, msg, dev)
        elif k == WireKind.RDMA_PAYLOAD:
            rt.rdv.on_rdma_payload(self, msg, dev)
        elif k == WireKind.PUT:
            rt.rdv.on_put(self, msg, dev)
        elif k == WireKind.GET_REQ:
            rt.rdv.on_get_req(self, msg, dev)
        elif k == WireKind.GET_RESP:
            rt.rdv.on_get_resp(self, msg, dev)
        else:
            raise FatalError(f"unknown wire kind {k}")

    def deliver_recv(self, buf, payload, comp, src: int, tag: int,
                     dev=None) -> None:
        if buf is not None:
            view = as_bytes_view(buf)
            n = min(view.nbytes, payload.nbytes)
            view[:n] = payload[:n]
        self.signal(comp, done(payload, rank=src, tag=tag), dev)

    def signal(self, comp: Optional[CompletionObject], st: Status,
               dev=None) -> None:
        """Deliver a completion through the unified comp protocol: every
        completion object returns a Status from ``signal``; a ``retry``
        (e.g. RETRY_QUEUE_FULL) parks the delivery in the device backlog,
        and the next progress pass redelivers (paper §4.4)."""
        if comp is None:
            return
        result = comp.signal(st)
        if isinstance(result, Status) and result.is_retry():
            dev = dev or self.rt.default_device
            dev.backlog.push(("signal", comp, st))
