"""The progress engine — posting plus the paper's Figure-1 reaction chain.

Progress (§3.2.6) is explicit: nothing moves unless someone drives a
:class:`ProgressEngine` over a device.  One progress pass implements the
reaction chain:

    drain backlog -> poll source completions -> poll incoming -> react
    (match, signal, rendezvous, replenish)

Engines are *drivers*, not state: the pending-op table, matching engine,
packet pool and landing zones all live on the owning ``Runtime``, so a
single shared engine and a fleet of dedicated per-device engines (the
paper's shared/dedicated resource split, :class:`~repro.core.modes.CommMode`)
are interchangeable — an :class:`~.endpoint.Endpoint`'s progress policy
picks between them per workload.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from ..completion import CompletionObject
from ..concurrency.atomics import AtomicCounter
from ..matching import MatchKind, MatchingPolicy, make_key
from ..post import CommKind
from ..protocol import Protocol, select_protocol
from ..status import ErrorCode, FatalError, Status, done, err, posted, retry
from ..telemetry import NULL_TELEMETRY, record_burst_mix
from .fabric import (PackedBurst, PendingBurst, PendingOp, WireKind, WireMsg,
                     as_bytes_view, next_op_id, pack_payloads,
                     payload_to_bytes, payloads_to_bytes)

#: wire kinds whose reactions batch their completion signals
_EAGER_KINDS = frozenset((WireKind.EAGER_AM, WireKind.EAGER_SEND,
                          WireKind.EAGER_PACKED_AM,
                          WireKind.EAGER_PACKED_SEND))


class _SignalBatch:
    """Per-pass accumulator: completions grouped by target comp object so
    one ``signal_many`` amortizes the admission cost (paper §4.3's
    batched-CQ-poll analogue).  Per-comp order equals accumulation order,
    so FIFO delivery per completion object is preserved."""

    __slots__ = ("_groups",)

    def __init__(self):
        self._groups: Dict[int, Tuple[CompletionObject, List[Status]]] = {}

    def add(self, comp: Optional[CompletionObject], st: Status) -> None:
        if comp is None:
            return
        group = self._groups.get(id(comp))
        if group is None:
            self._groups[id(comp)] = (comp, [st])
        else:
            group[1].append(st)

    def add_many(self, comp: Optional[CompletionObject],
                 sts: List[Status]) -> None:
        """A fused doorbell's worth of completions for one comp object —
        one dict probe and one extend instead of K ``add`` calls."""
        if comp is None or not sts:
            return
        group = self._groups.get(id(comp))
        if group is None:
            self._groups[id(comp)] = (comp, list(sts))
        else:
            group[1].extend(sts)

    def flush(self, engine: "ProgressEngine", dev) -> None:
        for comp, sts in self._groups.values():
            engine.signal_many(comp, sts, dev)
        self._groups.clear()


class ProgressEngine:
    """Drives posting and progress for a runtime's devices.

    ``devices=None`` means "whatever the runtime currently owns" (the
    shared-engine mode); a dedicated engine is constructed with the
    single device it is responsible for.
    """

    def __init__(self, runtime, devices: Optional[List] = None,
                 name: str = "engine"):
        self.rt = runtime
        self._devices = devices
        self.name = name
        # the owning runtime's telemetry hub (stage spans + registry);
        # directly-constructed runtest doubles fall back to the null hub
        self.tele = getattr(runtime, "tele", None) or NULL_TELEMETRY
        # telemetry (paper's do_background_work counters) — atomic: a
        # shared engine is driven from many threads at once
        self._passes = AtomicCounter()
        self._reactions = AtomicCounter()
        self._burst_posts = AtomicCounter()

    @property
    def passes(self) -> int:
        return self._passes.load()

    @property
    def reactions(self) -> int:
        return self._reactions.load()

    @property
    def burst_posts(self) -> int:
        """Doorbells rung through :meth:`post_burst`."""
        return self._burst_posts.load()

    @property
    def devices(self) -> List:
        return self.rt.devices if self._devices is None else self._devices

    def __repr__(self) -> str:
        scope = "shared" if self._devices is None else \
            f"dedicated[{','.join(str(d.index) for d in self._devices)}]"
        return f"ProgressEngine({self.name!r}, {scope})"

    # -- posting (called via Runtime._post / post.post_comm) -----------------
    def post(self, *, kind: CommKind, rank: int, buf, tag: int,
             size: int, local_comp, remote_buf, remote_comp, device,
             matching_policy: MatchingPolicy, allow_retry: bool,
             user_context) -> Status:
        tele = self.tele
        if tele.timers_on:
            with tele.span("post"):
                return self._post_scalar(
                    kind, rank, buf, tag, size, local_comp, remote_buf,
                    remote_comp, device, matching_policy, allow_retry,
                    user_context)
        return self._post_scalar(
            kind, rank, buf, tag, size, local_comp, remote_buf,
            remote_comp, device, matching_policy, allow_retry, user_context)

    def _post_scalar(self, kind: CommKind, rank: int, buf, tag: int,
                     size: int, local_comp, remote_buf, remote_comp, device,
                     matching_policy: MatchingPolicy, allow_retry: bool,
                     user_context) -> Status:
        rt = self.rt
        dev = device or rt.default_device
        dev.count_post()
        if rank < 0 or rank >= rt.n_ranks:
            raise FatalError(f"bad target rank {rank}")
        if rt.dead_peers and rank in rt.dead_peers \
                and kind != CommKind.RECV:
            # the peer is declared dead (DESIGN.md §16): the op can never
            # complete, so it fails at post time — comps are NOT signaled
            # (the err status is returned directly, like done)
            return err(ErrorCode.ERR_PEER_DEAD, rank=rank, tag=tag,
                       ctx=user_context)

        if kind == CommKind.RECV:
            return self._post_recv(rank, buf, tag, size, local_comp, dev,
                                   matching_policy)
        if kind == CommKind.GET:
            return rt.rdv.post_get(self, rank, buf, tag, size, local_comp,
                                   remote_buf, dev, allow_retry)
        if kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
            return rt.rdv.post_put(self, kind, rank, buf, tag, size,
                                   local_comp, remote_buf, remote_comp,
                                   dev, allow_retry)

        # SEND / AM with inject | bufcopy | zerocopy
        proto = select_protocol(size, rt.config)
        if proto == Protocol.ZEROCOPY:
            return rt.rdv.post_rts(self, kind, rank, buf, tag, size,
                                   local_comp, remote_comp, matching_policy,
                                   dev, allow_retry, user_context)

        packet = -1
        if proto == Protocol.BUFCOPY:
            packet, pst = rt.packet_pool.get(dev.lane)
            if pst.is_retry():
                rt.stats.retries += 1
                if allow_retry:
                    return pst
                # user disallowed retry: park in the backlog (paper §4.4)
                dev.backlog.push(("post", kind, rank, buf, tag, size,
                                  local_comp, remote_comp, matching_policy,
                                  user_context))
                return posted(code=ErrorCode.POSTED_BACKLOG)
            # stage payload into the packet (buffer-copy)
            data = payload_to_bytes(buf)
            if data.nbytes > rt.packet_pool.packet_bytes:
                rt.packet_pool.put(dev.lane, packet)
                raise FatalError("bufcopy payload exceeds packet size")

        wire_kind = (WireKind.EAGER_AM if kind == CommKind.AM
                     else WireKind.EAGER_SEND)
        op_id = -1
        if proto == Protocol.BUFCOPY:
            op_id = next_op_id()
            rt.pending_ops[op_id] = PendingOp(kind, buf, size, tag, rank,
                                              local_comp, packet=packet,
                                              lane=dev.lane,
                                              user_context=user_context)
        msg = WireMsg(wire_kind, rt.rank, rank, tag=tag,
                      payload=payload_to_bytes(buf), size=size,
                      rcomp=remote_comp, matching_policy=matching_policy,
                      op_id=op_id, device_index=dev.index)
        st = self.submit(msg, dev, allow_retry)
        if st.is_retry():
            if packet >= 0:
                rt.packet_pool.put(dev.lane, packet)
                del rt.pending_ops[op_id]
            return st
        rt.stats.record(proto, size)
        if proto == Protocol.INJECT:
            if st.code == ErrorCode.POSTED_BACKLOG:
                # the wire push was deferred; the payload is already copied
                # so the source buffer is reusable, but the op has not hit
                # the network — report the backlog, not done.  Inject ops
                # never signal completion objects (paper §3.2.5).
                return st
            # inject completes immediately; comps are NOT signaled (paper)
            return done(code=ErrorCode.DONE_INLINE, rank=rank, tag=tag)
        return posted(ctx=op_id)

    def _push_one(self, msg: WireMsg) -> bool:
        """Push one message, routing eager kinds through the reliability
        layer when armed — rel stamps a stream seq on acceptance (the
        ack then completes the op instead of the tx sweep)."""
        rt = self.rt
        rel = rt.rel
        if rel is not None and msg.kind in _EAGER_KINDS:
            return rel.send(rt.fabric, msg)
        return rt.fabric.try_push(msg)

    def submit(self, msg: WireMsg, dev, allow_retry: bool) -> Status:
        """Push to the fabric; full queue -> retry or backlog."""
        rt = self.rt
        tele = self.tele
        if tele.timers_on:
            with tele.span("transport.push"):
                ok = self._push_one(msg)
        else:
            ok = self._push_one(msg)
        if ok:
            dev.count_push()
            # source completion for bufcopy/zerocopy is deferred to
            # progress; a rel-stamped message (seq >= 0) completes on its
            # ack instead of the tx sweep
            if msg.op_id >= 0 and msg.seq < 0:
                dev.pending_tx.append(msg.op_id)
            return posted()
        rt.stats.retries += 1
        if allow_retry:
            return retry(ErrorCode.RETRY_LOCKED)
        st = dev.backlog.push(("wire", msg))
        if st.is_retry():
            return st
        if msg.op_id >= 0:
            dev.pending_tx.append(msg.op_id)
        return posted(code=ErrorCode.POSTED_BACKLOG)

    # -- burst posting (paper §4.3: amortize per-message software costs) ----
    def post_burst(self, ops: Sequence, dev) -> List[Status]:
        """Post a burst of operations on ONE device as coalesced doorbells.

        ``ops`` are :class:`~repro.core.post.CommDesc` descriptors with
        ``size`` already resolved.  Consecutive eager ops (SEND/AM small
        enough for inject/bufcopy, with ``allow_retry``) form a doorbell:
        one ``pool.get_n`` covers the run's packet demand, one stacked
        payload copy stages the run, one ``fabric.push_burst`` per
        (peer, device) stream rings it, one telemetry bump counts it.
        Anything else — recvs, RMA, rendezvous-sized sends, no-retry ops —
        cuts the run and rides the scalar :meth:`post` path in order.

        Failure semantics are *prefix-accept*: the first op that cannot
        proceed (pool exhausted, fabric full) fails, and every later op in
        the burst fails with the same retry — posting op k+1 after op k
        failed would let it overtake on the stream and break FIFO.  The
        caller re-posts the failed suffix after driving progress (that is
        the doorbell split the burst-ordering tests exercise)."""
        tele = self.tele
        if tele.timers_on:
            with tele.span("post_burst"):
                return self._post_burst_runs(ops, dev)
        return self._post_burst_runs(ops, dev)

    def _post_burst_runs(self, ops: Sequence, dev) -> List[Status]:
        rt = self.rt
        n = len(ops)
        statuses: List[Optional[Status]] = [None] * n
        self._burst_posts.fetch_add(1)
        i = 0
        last_size = last_proto = None    # memoized: bursts are usually
        while i < n:                     # uniform-size, one lookup serves
            run_start = i                # the whole run
            protos: List[Protocol] = []
            while i < n:
                op = ops[i]
                if op.kind not in (CommKind.SEND, CommKind.AM) \
                        or not op.allow_retry:
                    break
                if op.size != last_size:
                    last_proto = select_protocol(op.size, rt.config)
                    last_size = op.size
                if last_proto == Protocol.ZEROCOPY:
                    break
                protos.append(last_proto)
                i += 1
            if protos:
                sts = self._post_eager_run(ops[run_start:i], protos, dev)
                statuses[run_start:i] = sts
                if sts[-1].is_retry():
                    code = sts[-1].code
                    for j in range(i, n):
                        statuses[j] = retry(code)
                    return statuses
            if i < n:                        # one non-burstable op, scalar
                op = ops[i]
                st = self.post(kind=op.kind, rank=op.rank, buf=op.buf,
                               tag=op.tag, size=op.size,
                               local_comp=op.local_comp,
                               remote_buf=op.remote_buf,
                               remote_comp=op.remote_comp, device=dev,
                               matching_policy=op.matching_policy,
                               allow_retry=op.allow_retry,
                               user_context=op.user_context)
                statuses[i] = st
                if st.is_retry():
                    for j in range(i + 1, n):
                        statuses[j] = retry(st.code)
                    return statuses
                i += 1
        return statuses

    def _post_eager_run(self, ops: Sequence, protos: List[Protocol],
                        dev) -> List[Status]:
        """Route one eager run: fused packed doorbell when the run is
        long enough and uniform (one peer, one kind, one remote comp,
        one matching policy — the shape a single PackedBurst descriptor
        can carry), else the scalar per-message burst."""
        rt = self.rt
        if rt.doorbell_fused and len(ops) >= rt.fused_min_burst:
            first = ops[0]
            kind, rank = first.kind, first.rank
            rcomp, policy = first.remote_comp, first.matching_policy
            # ONE pass both proves uniformity and extracts the columns
            # the packed descriptor needs (kind/policy are enum
            # singletons, so identity compares)
            bufs: List = []
            tags: List[int] = []
            sizes: List[int] = []
            lcomps: List = []
            for op in ops:
                if (op.kind is not kind or op.rank != rank
                        or op.remote_comp != rcomp
                        or op.matching_policy is not policy
                        or op.user_context is not None):
                    break
                bufs.append(op.buf)
                tags.append(op.tag)
                sizes.append(op.size)
                lcomps.append(op.local_comp)
            else:
                return self._post_fused_run(kind, rank, bufs, tags, sizes,
                                            protos, lcomps, rcomp, policy,
                                            dev)
        return self._post_eager_burst(ops, protos, dev)

    def _post_fused_run(self, kind: CommKind, rank: int, bufs: List,
                        tags: List[int], sizes, protos: Sequence[Protocol],
                        local_comps, remote_comp,
                        policy: MatchingPolicy, dev) -> List[Status]:
        """One FUSED doorbell (DESIGN.md §13): K uniform eager ops to one
        peer collapse into a single stage-copy-push — one pool ``get_n``,
        one packed staging copy (:func:`pack_payloads`, where the
        ``wire_bf16`` compression rides for free), ONE wire descriptor
        (:class:`PackedBurst`) rung with one ``fabric.push_packed``, and
        one :class:`PendingBurst` covering every bufcopy row's deferred
        completion.  Status semantics, prefix-accept split points and
        telemetry match :meth:`_post_eager_burst` row for row.

        ``sizes`` is an int (uniform) or per-row list; ``local_comps`` a
        single comp object (or None) shared by all rows, or a per-row
        list."""
        rt = self.rt
        n = len(bufs)
        dev.count_post(n)
        if rank < 0 or rank >= rt.n_ranks:
            raise FatalError(f"bad target rank {rank}")
        if rt.dead_peers and rank in rt.dead_peers:
            return [err(ErrorCode.ERR_PEER_DEAD, rank=rank, tag=t)
                    for t in tags]

        # ONE pool round-trip covers the whole run's packet demand
        n_buf = protos.count(Protocol.BUFCOPY) if hasattr(protos, "count") \
            else sum(1 for p in protos if p == Protocol.BUFCOPY)
        uniform_proto = (Protocol.BUFCOPY if n_buf == n
                         else Protocol.INJECT if n_buf == 0 else None)
        packets: List[int] = []
        if n_buf:
            packets, _pst = rt.packet_pool.get_n(dev.lane, n_buf)
        cut = n                              # first op we can't cover
        if len(packets) < n_buf:
            short = len(packets)
            seen = 0
            for idx, proto in enumerate(protos):
                if proto == Protocol.BUFCOPY:
                    if seen == short:
                        cut = idx
                        break
                    seen += 1
            rt.stats.retries += n - cut

        pushed = 0
        op_id = -1
        if cut:
            # ONE packed staging copy builds the whole wire image
            data, dsizes, wire_dtype = pack_payloads(
                bufs if cut == n else bufs[:cut], rt.wire_bf16)
            if n_buf and int(dsizes.max(initial=0)) \
                    > rt.packet_pool.packet_bytes:
                # only bufcopy rows must fit a packet (as in the scalar
                # path); the max() gate keeps the per-row check off the
                # hot path
                for idx, (proto, ds) in enumerate(zip(protos, dsizes)):
                    if proto == Protocol.BUFCOPY \
                            and ds > rt.packet_pool.packet_bytes:
                        rt.packet_pool.put_n(dev.lane, packets)
                        raise FatalError(
                            "bufcopy payload exceeds packet size")
            burst = PackedBurst(data, dsizes,
                                tags if cut == n else tags[:cut],
                                cut, wire_dtype)
            msg = WireMsg(WireKind.EAGER_PACKED_AM if kind == CommKind.AM
                          else WireKind.EAGER_PACKED_SEND,
                          rt.rank, rank, tag=tags[0], payload=burst,
                          size=int(data.nbytes), rcomp=remote_comp,
                          matching_policy=policy, op_id=-1,
                          device_index=dev.index)
            rel = rt.rel
            tele = self.tele
            if tele.timers_on:
                with tele.span("transport.push"):
                    pushed = (rel.send_packed(rt.fabric, msg)
                              if rel is not None
                              else rt.fabric.push_packed(msg))
            else:
                pushed = (rel.send_packed(rt.fabric, msg)
                          if rel is not None
                          else rt.fabric.push_packed(msg))
            dev.count_push(pushed)
            if pushed < cut:
                rt.stats.retries += cut - pushed

        # bufcopy bookkeeping: one pending op for the whole doorbell;
        # packets of unpushed rows go straight back
        if n_buf:
            if uniform_proto is not None:        # all-bufcopy run
                used = pushed
                bidx = range(pushed)
            else:
                bidx = [i for i in range(pushed)
                        if protos[i] == Protocol.BUFCOPY]
                used = len(bidx)
            if used < len(packets):
                rt.packet_pool.put_n(dev.lane, packets[used:])
            if used:
                op_id = next_op_id()
                if isinstance(local_comps, list):
                    comps = [local_comps[i] for i in bidx]
                    if len(set(map(id, comps))) == 1:
                        # uniform run (commonly all None): collapse to a
                        # scalar so the completion sweep takes its bulk
                        # branch — or skips the rows entirely
                        comps = comps[0]
                else:
                    comps = local_comps
                rt.pending_ops[op_id] = PendingBurst(
                    kind, rank, dev.lane, packets[:used],
                    tags[:pushed] if used == pushed
                    else [tags[i] for i in bidx], comps)
                # a rel-stamped doorbell (msg.seq >= 0) binds its op to
                # the recorded entry and completes on the cumulative ack
                # instead of the tx sweep
                if not (msg.seq >= 0 and rt.rel is not None
                        and rt.rel.bind_op(rank, dev.index, msg.seq,
                                           op_id)):
                    dev.pending_tx.append(op_id)

        # burst telemetry: ONE shared helper does the per-protocol-class
        # accounting for the accepted prefix (identical arithmetic to the
        # scalar-burst path, so the two can never drift)
        if pushed:
            record_burst_mix(rt.stats, protos, sizes, pushed,
                             registry=(self.tele.registry
                                       if self.tele.counters_on else None))

        # statuses: identical codes to the scalar burst; identical rows
        # share ONE immutable status object instead of K constructions
        out: List[Optional[Status]] = [None] * n
        if pushed:
            if n_buf == 0:
                t0 = tags[0]
                if all(t == t0 for t in tags[:pushed]):
                    st = done(code=ErrorCode.DONE_INLINE, rank=rank, tag=t0)
                    out[:pushed] = [st] * pushed
                else:
                    out[:pushed] = [done(code=ErrorCode.DONE_INLINE,
                                         rank=rank, tag=t)
                                    for t in tags[:pushed]]
            elif uniform_proto is not None:
                out[:pushed] = [posted(ctx=op_id)] * pushed
            else:
                pst = posted(ctx=op_id)
                for i in range(pushed):
                    out[i] = pst if protos[i] == Protocol.BUFCOPY else \
                        done(code=ErrorCode.DONE_INLINE, rank=rank,
                             tag=tags[i])
        if pushed < cut:
            out[pushed:cut] = [retry(ErrorCode.RETRY_LOCKED)] * (cut - pushed)
        if cut < n:
            out[cut:] = [retry(ErrorCode.RETRY_NOPACKET)] * (n - cut)
        return out

    def _post_eager_burst(self, ops: Sequence, protos: List[Protocol],
                          dev) -> List[Status]:
        """One doorbell: eager SEND/AM ops on one device, all allow_retry."""
        rt = self.rt
        n = len(ops)
        dev.count_post(n)
        for op in ops:
            if op.rank < 0 or op.rank >= rt.n_ranks:
                raise FatalError(f"bad target rank {op.rank}")
        if rt.dead_peers and any(op.rank in rt.dead_peers for op in ops):
            # rare path: a burst touching a dead peer degrades to scalar
            # posts so each op gets its own err/posted verdict in order
            dev.count_post(-n)     # the scalar path re-counts each post
            out: List[Status] = []
            for i, op in enumerate(ops):
                st = self.post(kind=op.kind, rank=op.rank, buf=op.buf,
                               tag=op.tag, size=op.size,
                               local_comp=op.local_comp, remote_buf=None,
                               remote_comp=op.remote_comp, device=dev,
                               matching_policy=op.matching_policy,
                               allow_retry=True,
                               user_context=op.user_context)
                out.append(st)
                if st.is_retry():
                    out.extend(retry(st.code) for _ in ops[i + 1:])
                    break
            return out

        # ONE pool round-trip covers the whole run's packet demand
        n_buf = sum(1 for p in protos if p == Protocol.BUFCOPY)
        packets: List[int] = []
        if n_buf:
            packets, pst = rt.packet_pool.get_n(dev.lane, n_buf)
        cut = n                              # first op we can't cover
        if len(packets) < n_buf:
            short = len(packets)
            seen = 0
            for idx, proto in enumerate(protos):
                if proto == Protocol.BUFCOPY:
                    if seen == short:
                        cut = idx
                        break
                    seen += 1
            rt.stats.retries += n - cut

        # ONE stacked copy stages the whole run's payloads
        payloads = payloads_to_bytes([op.buf for op in ops[:cut]])
        for proto, data in zip(protos[:cut], payloads):
            if proto == Protocol.BUFCOPY \
                    and data.nbytes > rt.packet_pool.packet_bytes:
                rt.packet_pool.put_n(dev.lane, packets)
                raise FatalError("bufcopy payload exceeds packet size")
        msgs: List[WireMsg] = []
        pi = 0
        for op, proto, data in zip(ops[:cut], protos[:cut], payloads):
            packet, op_id = -1, -1
            if proto == Protocol.BUFCOPY:
                packet = packets[pi]
                pi += 1
                op_id = next_op_id()
                rt.pending_ops[op_id] = PendingOp(
                    op.kind, op.buf, op.size, op.tag, op.rank,
                    op.local_comp, packet=packet, lane=dev.lane,
                    user_context=op.user_context)
            wire_kind = (WireKind.EAGER_AM if op.kind == CommKind.AM
                         else WireKind.EAGER_SEND)
            msgs.append(WireMsg(wire_kind, rt.rank, op.rank, tag=op.tag,
                                payload=data, size=op.size,
                                rcomp=op.remote_comp,
                                matching_policy=op.matching_policy,
                                op_id=op_id, device_index=dev.index))

        # ring one doorbell per consecutive (peer, device) stream
        tele = self.tele
        rel = rt.rel
        pushed = cut
        j = 0
        while j < len(msgs):
            k = j
            while k < len(msgs) and msgs[k].dst == msgs[j].dst:
                k += 1
            if tele.timers_on:
                with tele.span("transport.push"):
                    acc = (rel.send_burst(rt.fabric, msgs[j:k])
                           if rel is not None
                           else rt.fabric.push_burst(msgs[j:k]))
            else:
                acc = (rel.send_burst(rt.fabric, msgs[j:k])
                       if rel is not None
                       else rt.fabric.push_burst(msgs[j:k]))
            for m in msgs[j:j + acc]:
                if m.op_id >= 0 and m.seq < 0:
                    dev.pending_tx.append(m.op_id)
            if acc < k - j:                  # fabric full: cut here
                pushed = j + acc
                break
            j = k
        dev.count_push(pushed)

        # unwind the fabric-rejected tail (all ops here allow retry)
        if pushed < cut:
            unwound = [m.op_id for m in msgs[pushed:] if m.op_id >= 0]
            rt.packet_pool.put_n(
                dev.lane, [rt.pending_ops[oid].packet for oid in unwound])
            for oid in unwound:
                del rt.pending_ops[oid]
            rt.stats.retries += cut - pushed

        # burst telemetry: the same shared helper as the fused path does
        # the per-protocol-class accounting for the accepted prefix
        if pushed:
            record_burst_mix(rt.stats, protos, [op.size for op in ops],
                             pushed,
                             registry=(tele.registry if tele.counters_on
                                       else None))

        out: List[Status] = []
        for idx, (op, proto) in enumerate(zip(ops, protos)):
            if idx >= pushed:
                out.append(retry(ErrorCode.RETRY_NOPACKET if idx >= cut
                                 else ErrorCode.RETRY_LOCKED))
            elif proto == Protocol.INJECT:
                out.append(done(code=ErrorCode.DONE_INLINE, rank=op.rank,
                                tag=op.tag))
            else:
                out.append(posted(ctx=msgs[idx].op_id))
        return out

    def _post_recv(self, rank: int, buf, tag: int, size: int,
                   local_comp, dev, policy: MatchingPolicy) -> Status:
        rt = self.rt
        if rt.dead_peers and rank in rt.dead_peers \
                and policy is not MatchingPolicy.TAG_ONLY:
            # a recv naming a dead source can never match (wildcard-rank
            # recvs stay postable: a living sender may still satisfy them)
            return err(ErrorCode.ERR_PEER_DEAD, rank=rank, tag=tag)
        key = make_key(rank, tag, policy)
        value = ("recv", buf, local_comp, dev)
        match = self.rt.matching.insert(key, MatchKind.RECV, value)
        if match is None:
            if rt.rel is not None:
                rt.rel.track_recv(key, value, local_comp, rank, tag, dev)
            return posted(code=ErrorCode.POSTED_UNMATCHED)
        mkind, *rest = match
        if mkind == "eager":
            payload, src, mtag = rest
            if buf is not None:               # fill the posted buffer too
                view = as_bytes_view(buf)
                n = min(view.nbytes, payload.nbytes)
                view[:n] = payload[:n]
            # done => completion objects will NOT be signaled (paper §3.2.5)
            return done(payload, rank=src, tag=mtag)
        if mkind == "rts":
            msg = rest[0]
            self.rt.rdv.reply_cts(msg, buf, local_comp, dev)
            return posted()
        raise FatalError(f"unexpected match kind {mkind}")

    # -- progress (§3.2.6, Figure 1) -----------------------------------------
    def progress(self, device=None, max_msgs: int = 0) -> bool:
        """Drive one progress pass on ``device``; returns True if any work
        was done (paper: do_background_work).

        The pass runs under the device's progress try-lock (blocking spin
        here — single-threaded callers never contend), so the reaction
        chain is single-writer per device even when worker threads drive
        the same engine; use :meth:`try_progress` for the paper's
        fail-and-move-on discipline."""
        dev = device or (self._devices[0] if self._devices
                         else self.rt.default_device)
        with dev.progress_lock:
            return self._progress_locked(dev, max_msgs)

    def try_progress(self, device=None, max_msgs: int = 0):
        """Non-blocking progress (paper §4.2.3: "multiple threads call
        progress; a thread that fails the try-lock moves on").  Returns
        ``None`` when the device is being progressed by another thread,
        else the pass's did-work bool."""
        dev = device or (self._devices[0] if self._devices
                         else self.rt.default_device)
        rt = self.rt
        # idle fast path: nothing backlogged, no pending source-side
        # completions, nothing due on the wire — skip the lock and the
        # pass bookkeeping entirely.  Polling threads spend most of
        # their passes discovering exactly this, and under the GIL an
        # expensive "nothing to do" serializes every OTHER thread too.
        # Unlocked reads are safe: a stale miss is just an earlier poll,
        # and new work re-arms all three signals.
        if dev.backlog.empty_flag and not dev.pending_tx \
                and not rt.fabric.ready(rt.rank, dev.index) \
                and (rt.rel is None or not rt.rel.armed()):
            return False
        if not dev.progress_lock.try_acquire():
            return None
        try:
            return self._progress_locked(dev, max_msgs)
        finally:
            dev.progress_lock.release()

    def _progress_locked(self, dev, max_msgs: int = 0) -> bool:
        """One pass of the Figure-1 reaction chain, split into its three
        stages (backlog redelivery, source-completion sweep, drain+react)
        so the timers level can attribute the pass's time per stage.  At
        lower levels the stages are called directly — no span machinery
        touches the off-level hot path."""
        tele = self.tele
        if tele.timers_on:
            with tele.span("progress"):
                return self._progress_stages(dev, max_msgs, tele)
        return self._progress_stages(dev, max_msgs, None)

    def _progress_stages(self, dev, max_msgs: int, tele) -> bool:
        dev.count_progress()
        self._passes.fetch_add(1)
        did = False
        if not dev.backlog.empty_flag:
            if tele is not None:
                with tele.span("progress.backlog"):
                    did = self._stage_backlog(dev)
            else:
                did = self._stage_backlog(dev)
        if dev.pending_tx:
            if tele is not None:
                with tele.span("progress.tx_sweep"):
                    did |= self._stage_tx_sweep(dev)
            else:
                did |= self._stage_tx_sweep(dev)
        if tele is not None:
            with tele.span("progress.drain"):
                did |= self._stage_drain(dev, max_msgs)
        else:
            did |= self._stage_drain(dev, max_msgs)
        rel = self.rt.rel
        if rel is not None and rel.armed():
            # reliability timers (DESIGN.md §16): retransmit overdue
            # entries, expire post deadlines, flush stuck acks
            if tele is not None:
                with tele.span("progress.rel"):
                    did |= rel.sweep(self, dev)
            else:
                did |= rel.sweep(self, dev)
        return did

    def _stage_backlog(self, dev) -> bool:
        """Stage (3): retry backlogged requests first."""
        rt = self.rt
        did = False
        while not dev.backlog.empty_flag:
            item, st = dev.backlog.pop()
            if st.is_retry():
                break
            tag0 = item[0]
            if tag0 == "wire":
                msg = item[1]
                if not self._push_one(msg):
                    # requeue at the HEAD: a tail push would let a later
                    # same-stream message overtake this one once the
                    # fabric frees up (push_front never fails)
                    dev.backlog.push_front(item)
                    break
                dev.count_push()
                if msg.op_id >= 0 and msg.seq < 0:
                    dev.pending_tx.append(msg.op_id)
                did = True
            elif tag0 == "post":
                (_, kind, rank, buf, tag, size, local_comp, remote_comp,
                 policy, uctx) = item
                st2 = self.post(kind=kind, rank=rank, buf=buf, tag=tag,
                                size=size, local_comp=local_comp,
                                remote_buf=None, remote_comp=remote_comp,
                                device=dev, matching_policy=policy,
                                allow_retry=True, user_context=uctx)
                if st2.is_retry():
                    dev.backlog.push_front(item)   # keep FIFO redelivery
                    break
                did = True
            elif tag0 == "signal":
                # a completion object rejected this signal earlier
                # (retry(RETRY_QUEUE_FULL)); redeliver until accepted.
                # Requeue at the HEAD on rejection: pushing to the tail
                # would rotate parked signals and deliver later
                # completions to the same queue out of order.
                _, comp, st2 = item
                if comp.signal(st2).is_retry():
                    dev.backlog.push_front(item)
                    break
                did = True
        return did

    def _stage_tx_sweep(self, dev) -> bool:
        """Source-side completions (bufcopy send done on the wire) — the
        whole sweep batches its pool returns (one put_n per lane) and
        its completion signals (one signal_many per comp object)."""
        rt = self.rt
        did = False
        if dev.pending_tx:
            batch = _SignalBatch()
            puts: Dict[int, List[int]] = {}
            while dev.pending_tx:
                op_id = dev.pending_tx.popleft()
                op = rt.pending_ops.get(op_id)
                if op is None:
                    continue
                if type(op) is PendingBurst:
                    # one fused doorbell: all packets back in one batch,
                    # completions in row (FIFO) order
                    puts.setdefault(op.lane, []).extend(op.packets)
                    if isinstance(op.comps, list):
                        for c, t in zip(op.comps, op.tags):
                            if c is not None:
                                batch.add(c, done(rank=op.peer, tag=t))
                    elif op.comps is not None:
                        t0 = op.tags[0] if op.tags else None
                        if all(t == t0 for t in op.tags):
                            # uniform tags: ONE immutable status serves
                            # the whole doorbell's local completions
                            batch.add_many(op.comps,
                                           [done(rank=op.peer, tag=t0)]
                                           * len(op.tags))
                        else:
                            batch.add_many(op.comps,
                                           [done(rank=op.peer, tag=t)
                                            for t in op.tags])
                    del rt.pending_ops[op_id]
                    did = True
                    continue
                if op.kind in (CommKind.SEND, CommKind.AM):
                    if op.packet >= 0:          # return packet to the pool
                        puts.setdefault(op.lane, []).append(op.packet)
                        batch.add(op.local_comp,
                                  done(rank=op.peer, tag=op.tag))
                        del rt.pending_ops[op_id]
                    # zerocopy sends complete on CTS+RDMA, not here
                elif op.kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
                    batch.add(op.local_comp, done(rank=op.peer, tag=op.tag))
                    del rt.pending_ops[op_id]
                did = True
            for lane, pkts in puts.items():
                rt.packet_pool.put_n(lane, pkts)
            batch.flush(self, dev)
        return did

    # -- reliability completions (DESIGN.md §16) -----------------------------
    def complete_tx_op(self, op_id: int, dev) -> None:
        """Retire one rel-tracked pending op whose cumulative ack
        arrived — packets back to the pool, comps signaled done, exactly
        the per-op semantics of :meth:`_stage_tx_sweep`.  Idempotent: a
        second call (or a call after a deadline failure already popped
        the op) is a no-op, keeping comp signals exactly-once."""
        self._finish_tx_op(op_id, dev, None)

    def fail_tx_op(self, op_id: int, dev, code: ErrorCode) -> None:
        """Terminally fail one rel-tracked pending op: packets still
        return to the pool, but comps are signaled ``err(code)`` so
        waiters never hang (ERR_TIMEOUT / ERR_PEER_DEAD)."""
        self._finish_tx_op(op_id, dev, code)

    def _finish_tx_op(self, op_id: int, dev,
                      code: Optional[ErrorCode]) -> None:
        rt = self.rt
        op = rt.pending_ops.pop(op_id, None)
        if op is None:
            return
        if code is None:
            mk = lambda t: done(rank=op.peer, tag=t)   # noqa: E731
        else:
            mk = lambda t: err(code, rank=op.peer, tag=t)  # noqa: E731
        if type(op) is PendingBurst:
            rt.packet_pool.put_n(op.lane, op.packets)
            if isinstance(op.comps, list):
                for c, t in zip(op.comps, op.tags):
                    self.signal(c, mk(t), dev)
            elif op.comps is not None:
                self.signal_many(op.comps, [mk(t) for t in op.tags], dev)
            return
        if op.kind in (CommKind.SEND, CommKind.AM):
            if op.packet >= 0:
                rt.packet_pool.put(op.lane, op.packet)
                self.signal(op.local_comp, mk(op.tag), dev)
        elif op.kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
            self.signal(op.local_comp, mk(op.tag), dev)

    def _stage_drain(self, dev, max_msgs: int) -> bool:
        """Stage (4): poll incoming for this device stream and react:
        drain is one bounded burst per lock acquisition; eager
        completions accumulate into one signal batch flushed per
        contiguous eager run — a rendezvous/RMA reaction signals comps
        immediately inside _react, so the batch must flush BEFORE it runs
        or a deferred eager completion would overtake it on the same
        comp."""
        rt = self.rt
        tele = self.tele
        did = False
        if tele.timers_on:
            with tele.span("transport.drain"):
                msgs = rt.fabric.drain(rt.rank, dev.index, max_msgs)
        else:
            msgs = rt.fabric.drain(rt.rank, dev.index, max_msgs)
        if msgs and rt.rel is not None:
            # reliability filter: consume acks, drop dups/stale epochs,
            # resequence held-back runs into exact per-stream seq order
            msgs = rt.rel.on_incoming(msgs, self, dev)
        if msgs:
            batch = _SignalBatch()
            for msg in msgs:
                if msg.kind in _EAGER_KINDS:
                    self._react(msg, dev, batch)
                else:
                    batch.flush(self, dev)     # keep per-comp wire order
                    self._react(msg, dev)
            batch.flush(self, dev)
            did = True
        return did

    def progress_all(self, rounds: int = 1, max_msgs: int = 0) -> int:
        """Drive every device this engine is responsible for."""
        n = 0
        for _ in range(rounds):
            for dev in self.devices:
                n += bool(self.progress(dev, max_msgs))
        return n

    def _react(self, msg: WireMsg, dev, batch: Optional[_SignalBatch] = None
               ) -> None:
        rt = self.rt
        self._reactions.fetch_add(1)
        k = msg.kind
        if k == WireKind.EAGER_AM:
            comp = rt.rcomp_registry[msg.rcomp]
            st = done(msg.payload, rank=msg.src, tag=msg.tag)
            if batch is not None:
                batch.add(comp, st)
            else:
                self.signal(comp, st, dev)
        elif k == WireKind.EAGER_PACKED_AM:
            # one fused doorbell: one rcomp lookup, one vectorized
            # payload unpack (bf16 rows decompress here), one batched
            # signal extend for the whole burst
            burst: PackedBurst = msg.payload
            self._reactions.fetch_add(burst.count - 1)
            comp = rt.rcomp_registry[msg.rcomp]
            src = msg.src
            tags = burst.tags
            if (burst.data.strides[0] == 0 and burst.wire_dtype is None
                    and len(set(tags)) == 1):
                # broadcast burst (same payload object repeated): every
                # delivered row is byte-identical, so ONE immutable
                # Status serves the whole doorbell
                sts = [done(burst.data[0], rank=src, tag=tags[0])
                       ] * burst.count
            else:
                sts = [done(p, rank=src, tag=t)
                       for p, t in zip(burst.delivered_payloads(), tags)]
            if batch is not None:
                batch.add_many(comp, sts)
            else:
                for st in sts:
                    self.signal(comp, st, dev)
        elif k == WireKind.EAGER_PACKED_SEND:
            burst = msg.payload
            self._reactions.fetch_add(burst.count - 1)
            src, pol = msg.src, msg.matching_policy
            payloads = burst.delivered_payloads()
            tags = burst.tags
            t0 = tags[0]
            if all(t == t0 for t in tags):
                # uniform match key: ONE bucket probe pops the whole
                # burst's worth of pre-posted recvs
                vals = rt.matching.match_now_n(
                    make_key(src, t0, pol), MatchKind.SEND, burst.count)
                matches = vals + [None] * (burst.count - len(vals))
            else:
                matches = rt.matching.match_now_burst(
                    [make_key(src, t, pol) for t in tags], MatchKind.SEND)
            for i, match in enumerate(matches):
                payload = payloads[i]
                if match is None:           # per-bucket locked fallback
                    match = rt.matching.insert(
                        make_key(src, tags[i], pol), MatchKind.SEND,
                        ("eager", payload, src, tags[i]))
                if match is not None:
                    _, buf, comp, rdev = match
                    self.deliver_recv(buf, payload, comp, src, tags[i],
                                      dev, batch=batch)
        elif k == WireKind.EAGER_SEND:
            key = make_key(msg.src, msg.tag, msg.matching_policy)
            # eager fast path: a lock-free probe of the pre-posted-recv
            # stripe — when the recv is already posted (the windowed-
            # benchmark common case) the delivery skips the bucket lock
            # and the unexpected-queue insertion entirely
            match = rt.matching.match_now(key, MatchKind.SEND)
            if match is None:
                match = rt.matching.insert(
                    key, MatchKind.SEND,
                    ("eager", msg.payload, msg.src, msg.tag))
            if match is not None:
                _, buf, comp, rdev = match
                self.deliver_recv(buf, msg.payload, comp, msg.src, msg.tag,
                                  dev, batch=batch)
        elif k == WireKind.RTS:
            rt.rdv.on_rts(self, msg, dev)
        elif k == WireKind.CTS:
            rt.rdv.on_cts(self, msg, dev)
        elif k == WireKind.RDMA_PAYLOAD:
            rt.rdv.on_rdma_payload(self, msg, dev)
        elif k == WireKind.PUT:
            rt.rdv.on_put(self, msg, dev)
        elif k == WireKind.GET_REQ:
            rt.rdv.on_get_req(self, msg, dev)
        elif k == WireKind.GET_RESP:
            rt.rdv.on_get_resp(self, msg, dev)
        elif k == WireKind.ACK:
            # normally consumed by rel.on_incoming before reaction; a
            # straggler ack with reliability disabled is just dropped
            if rt.rel is not None:
                rt.rel._on_ack(msg, self, dev)
        else:
            raise FatalError(f"unknown wire kind {k}")

    def deliver_recv(self, buf, payload, comp, src: int, tag: int,
                     dev=None, batch: Optional[_SignalBatch] = None) -> None:
        if buf is not None:
            view = as_bytes_view(buf)
            n = min(view.nbytes, payload.nbytes)
            view[:n] = payload[:n]
        st = done(payload, rank=src, tag=tag)
        if batch is not None:
            batch.add(comp, st)
        else:
            self.signal(comp, st, dev)

    def signal(self, comp: Optional[CompletionObject], st: Status,
               dev=None) -> None:
        """Deliver a completion through the unified comp protocol: every
        completion object returns a Status from ``signal``; a ``retry``
        (e.g. RETRY_QUEUE_FULL) parks the delivery in the device backlog,
        and the next progress pass redelivers (paper §4.4)."""
        if comp is None:
            return
        result = comp.signal(st)
        if isinstance(result, Status) and result.is_retry():
            dev = dev or self.rt.default_device
            dev.backlog.push(("signal", comp, st))

    def signal_many(self, comp: Optional[CompletionObject],
                    statuses: List[Status], dev=None) -> None:
        """Burst delivery: one ``signal_many`` on the comp object; any
        rejected suffix (the comp protocol guarantees rejects are a
        prefix-accept's tail, in order) parks in the device backlog for
        in-order redelivery, exactly like scalar :meth:`signal`."""
        if comp is None or not statuses:
            return
        tele = self.tele
        if tele.timers_on:
            with tele.span("signal"):
                results = comp.signal_many(statuses)
        else:
            results = comp.signal_many(statuses)
        last = results[-1] if results else None
        if not (isinstance(last, Status) and last.is_retry()):
            return          # rejects are a suffix: clean last = clean burst
        dev = dev or self.rt.default_device
        for st, r in zip(statuses, results):
            if isinstance(r, Status) and r.is_retry():
                dev.backlog.push(("signal", comp, st))
