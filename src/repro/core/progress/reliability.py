"""Reliability plane: post deadlines, retransmit, and dedup (DESIGN.md §16).

The chaos transport (:mod:`repro.core.transport.chaos`) drops, duplicates
and reorders reliability-stamped wire traffic; this module is the layer
that makes eager messaging survive it — the software analogue of a verbs
RC connection's ack/retransmit machinery, driven entirely from the
progress engine's reaction chain:

* **Sender**: every eager message (scalar, burst, or fused packed
  doorbell) is stamped with a per-``(dst, device)`` stream sequence
  number at the moment it is accepted by the fabric, and recorded in an
  unacked window.  A packed doorbell allocates ``count`` *consecutive*
  seqs — one per row — so a partial prefix-accept or a partially
  duplicated delivery stays addressable at row granularity.  The sweep
  stage retransmits entries whose ack is overdue (exponential backoff,
  ``retry_backoff`` doubling per attempt, capped), fails them with
  ``ERR_TIMEOUT`` once ``retry_limit`` attempts are spent, and with
  ``ERR_PEER_DEAD`` when the peer has been declared dead.

* **Receiver**: per-``(src, device)`` cumulative counter plus a hold
  buffer resequences the stream — duplicates (seq ≤ cum) are swallowed,
  gaps are held until the retransmit arrives, and delivery order is
  exactly seq order, which restores the per-stream FIFO the matching
  tests pin.  Every accepted-or-duplicate batch triggers a cumulative
  :data:`~repro.core.transport.wire.WireKind.ACK` back to the sender
  (payload ``(cum, epoch)``); a lost ack is healed by the retransmit
  it fails to suppress — the dup re-triggers an ack.

* **Deadlines**: ``post_deadline_us`` is a *completion* deadline.  An
  expired send signals ``err(ERR_TIMEOUT)`` to its comps exactly once
  (the pending op is popped, so the eventual ack completes nothing) but
  keeps retransmitting — abandoning the payload would leave a permanent
  gap in the stream and stall every later message behind it.  Expired
  recvs are withdrawn from the matching engine (:meth:`remove` — a
  no-op if they already matched) and err-signaled.

Sequence numbers are allocated and recorded under a per-stream
:class:`~repro.core.concurrency.locks.TryLock`, so concurrent posters
cannot interleave stamp and push; the sweep uses ``try_acquire`` and
moves on, the paper's progress discipline.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Set, Tuple

from ..concurrency.atomics import AtomicCounter
from ..concurrency.locks import TryLock
from ..matching import MatchKind
from ..status import ErrorCode, err
from .fabric import PACKED_KINDS, PackedBurst, WireKind, WireMsg

#: attrs the reliability plane resolves at runtime construction
RELIABILITY_ATTRS = ("reliability", "post_deadline_us", "retry_limit",
                     "retry_backoff")

#: exponential backoff is capped at ``retry_backoff * _BACKOFF_CAP``
_BACKOFF_CAP = 16


def _rows(msg: WireMsg) -> int:
    """How many stream seqs ``msg`` occupies (packed: one per row)."""
    if msg.kind in PACKED_KINDS:
        return msg.payload.count
    return 1


def _suffix(burst: PackedBurst, start: int) -> PackedBurst:
    """Rows ``[start:]`` of a packed burst (complement of ``prefix``)."""
    return PackedBurst(burst.data[start:], burst.sizes[start:],
                       burst.tags[start:], burst.count - start,
                       burst.wire_dtype)


@dataclasses.dataclass(slots=True)
class _TxEntry:
    """One unacked wire message: ``count`` consecutive seqs starting at
    ``first_seq``.  ``op_id`` is the pending-op completed on ack (or -1
    for inject rows, which retransmit but never signal comps)."""

    first_seq: int
    count: int
    msg: WireMsg
    op_id: int
    last_tx: float
    deadline: float = 0.0          # 0 = no completion deadline
    retries: int = 0
    failed: bool = False           # deadline already err-signaled


@dataclasses.dataclass(slots=True)
class _RecvTrack:
    """A deadline-tracked posted recv (only built when
    ``post_deadline_us > 0``)."""

    key: Any
    value: Any                     # the matching-engine entry (identity)
    comp: Any
    deadline: float
    rank: int
    tag: int
    dev: Any


class ReliabilityManager:
    """Per-runtime ack/retransmit state (sender windows + receiver
    resequencers).  Constructed by :class:`~repro.core.runtime.Runtime`
    when the ``reliability`` attr is ``"on"``, or ``"auto"`` with an
    active message-faulting chaos transport."""

    def __init__(self, rt, resolved):
        self.rt = rt
        self.deadline_us: float = resolved["post_deadline_us"]
        self.retry_limit: int = resolved["retry_limit"]
        self.retry_backoff: float = resolved["retry_backoff"]
        self.epoch = 0
        # sender state, per (dst, device_index) stream
        self._locks: Dict[Tuple[int, int], TryLock] = {}
        self._next_seq: Dict[Tuple[int, int], int] = {}
        self._unacked: Dict[Tuple[int, int], Deque[_TxEntry]] = {}
        # receiver state, per (src, device_index) stream
        self._cum: Dict[Tuple[int, int], int] = {}
        self._hold: Dict[Tuple[int, int], Dict[int, WireMsg]] = {}
        self._ack_pending: Set[Tuple[int, int]] = set()
        self._tracked_recvs: Deque[_RecvTrack] = collections.deque()
        self._dead: Set[int] = set()
        # counters (atomic: posting threads and sweepers race)
        self.retransmits = AtomicCounter()
        self.acks_sent = AtomicCounter()
        self.acks_received = AtomicCounter()
        self.dups_dropped = AtomicCounter()
        self.resequenced = AtomicCounter()
        self.held = AtomicCounter()
        self.expired_timeout = AtomicCounter()
        self.expired_peer_dead = AtomicCounter()
        self.abandoned = AtomicCounter()
        self.stale_epoch = AtomicCounter()

    # -- sender: stamp-on-accept ---------------------------------------------
    def _lock_of(self, key: Tuple[int, int]) -> TryLock:
        lk = self._locks.get(key)
        if lk is None:
            lk = self._locks.setdefault(
                key, TryLock(name=f"rel/{key[0]}.{key[1]}"))
        return lk

    def _record(self, key: Tuple[int, int], msg: WireMsg, op_id: int,
                count: int, now: float) -> _TxEntry:
        deadline = (now + self.deadline_us * 1e-6
                    if self.deadline_us > 0 else 0.0)
        entry = _TxEntry(msg.seq, count, msg, op_id, now, deadline)
        self._unacked.setdefault(key, collections.deque()).append(entry)
        return entry

    def send(self, fabric, msg: WireMsg) -> bool:
        """Stamp one eager message and push it; returns the push result.
        A failed push unstamps (the seq is not consumed), so wire
        acceptance order IS seq order — the FIFO the receiver restores."""
        key = (msg.dst, msg.device_index)
        now = time.monotonic()
        with self._lock_of(key):
            seq = self._next_seq.get(key, 0)
            msg.seq = seq
            msg.epoch = self.epoch
            if msg.dst in self._dead:
                # record-but-never-push: the sweep fails it PEER_DEAD so
                # the op's comps are signaled instead of silently lost
                self._next_seq[key] = seq + 1
                self._record(key, msg, msg.op_id, 1, now)
                return True
            if not fabric.try_push(msg):
                msg.seq = -1
                return False
            self._next_seq[key] = seq + 1
            self._record(key, msg, msg.op_id, 1, now)
        return True

    def send_burst(self, fabric, msgs: List[WireMsg]) -> int:
        """Stamp-and-push one same-stream burst; prefix-accept.  The
        rejected tail is unstamped (seqs rolled back under the lock), so
        the engine's unwind-and-retry re-posts it with fresh seqs."""
        if not msgs:
            return 0
        key = (msgs[0].dst, msgs[0].device_index)
        now = time.monotonic()
        with self._lock_of(key):
            seq = self._next_seq.get(key, 0)
            for i, m in enumerate(msgs):
                m.seq = seq + i
                m.epoch = self.epoch
            if msgs[0].dst in self._dead:
                acc = len(msgs)
            else:
                acc = fabric.push_burst(msgs)
            for m in msgs[acc:]:
                m.seq = -1
            self._next_seq[key] = seq + acc
            for m in msgs[:acc]:
                self._record(key, m, m.op_id, 1, now)
        return acc

    def send_packed(self, fabric, msg: WireMsg) -> int:
        """Stamp one fused doorbell with ``count`` consecutive per-row
        seqs and push it; prefix-accept at row granularity.  The recorded
        entry covers exactly the accepted prefix — ``msg.seq`` stays the
        stamped first seq so the engine can bind the pending-burst op to
        it afterwards (:meth:`bind_op`)."""
        burst: PackedBurst = msg.payload
        key = (msg.dst, msg.device_index)
        now = time.monotonic()
        with self._lock_of(key):
            seq = self._next_seq.get(key, 0)
            msg.seq = seq
            msg.epoch = self.epoch
            if msg.dst in self._dead:
                self._next_seq[key] = seq + burst.count
                self._record(key, msg, -1, burst.count, now)
                return burst.count
            pushed = fabric.push_packed(msg)
            if pushed <= 0:
                msg.seq = -1
                return 0
            self._next_seq[key] = seq + pushed
            rec = msg if pushed == burst.count else dataclasses.replace(
                msg, payload=burst.prefix(pushed),
                size=int(burst.data[:pushed].nbytes))
            self._record(key, rec, -1, pushed, now)
        return pushed

    def bind_op(self, dst: int, device_index: int, first_seq: int,
                op_id: int) -> bool:
        """Attach a pending-op id to the packed entry recorded with
        ``first_seq`` (the engine creates the PendingBurst only after the
        push).  Returns True when bound — the engine must then NOT queue
        the op on ``pending_tx`` (the ack completes it instead)."""
        key = (dst, device_index)
        with self._lock_of(key):
            dq = self._unacked.get(key)
            if dq:
                for entry in reversed(dq):
                    if entry.first_seq == first_seq:
                        entry.op_id = op_id
                        return True
        return False

    # -- receiver: resequence + dedup + ack ----------------------------------
    def _slice_from(self, msg: WireMsg, start: int) -> WireMsg:
        """Rows ``[start:]`` of a partially duplicated delivery (a
        retransmit overlapping the cum counter)."""
        if start <= 0:
            return msg
        nb = _suffix(msg.payload, start)
        return dataclasses.replace(msg, payload=nb, seq=msg.seq + start,
                                   size=int(nb.data.nbytes))

    def on_incoming(self, msgs: List[WireMsg], engine, dev
                    ) -> List[WireMsg]:
        """Filter one drained batch: consume ACKs, drop duplicates and
        stale epochs, hold out-of-order messages, release resequenced
        runs.  Returns the messages the engine should react to, with
        tracked traffic in exact seq order per stream."""
        out: List[WireMsg] = []
        touched: Set[Tuple[int, int]] = set()
        for msg in msgs:
            if msg.kind == WireKind.ACK:
                self._on_ack(msg, engine, dev)
                continue
            if msg.seq < 0:
                out.append(msg)            # untracked control traffic
                continue
            if msg.epoch != self.epoch:
                self.stale_epoch.fetch_add(1)
                continue
            key = (msg.src, msg.device_index)
            if msg.src in self._dead:
                continue                   # a corpse's straggler
            cum = self._cum.get(key, -1)
            count = _rows(msg)
            last = msg.seq + count - 1
            if last <= cum:                # full duplicate
                self.dups_dropped.fetch_add(count)
                touched.add(key)           # re-ack: heals a lost ack
                continue
            if msg.seq > cum + 1:          # gap: hold for the retransmit
                hold = self._hold.setdefault(key, {})
                if msg.seq in hold:
                    self.dups_dropped.fetch_add(count)
                else:
                    hold[msg.seq] = msg
                    self.held.fetch_add(1)
                touched.add(key)
                continue
            # in-order (possibly overlapping a retransmit): deliver the
            # rows beyond cum, then release any consecutive held run
            out.append(self._slice_from(msg, cum + 1 - msg.seq))
            cum = last
            hold = self._hold.get(key)
            while hold:
                ready = [s for s in hold if s <= cum + 1]
                if not ready:
                    break
                s = min(ready)
                m2 = hold.pop(s)
                c2 = _rows(m2)
                l2 = s + c2 - 1
                if l2 <= cum:
                    self.dups_dropped.fetch_add(c2)
                    continue
                out.append(self._slice_from(m2, cum + 1 - s))
                self.resequenced.fetch_add(1)
                cum = l2
            self._cum[key] = cum
            touched.add(key)
        if touched:
            self._ack_pending.update(touched)
            self._flush_acks()
        return out

    def _flush_acks(self) -> bool:
        """Push pending cumulative acks best-effort; a full fabric keeps
        the stream marked and the sweep retries."""
        did = False
        fabric = self.rt.fabric
        for key in list(self._ack_pending):
            cum = self._cum.get(key, -1)
            ack = WireMsg(WireKind.ACK, self.rt.rank, key[0],
                          payload=(cum, self.epoch), device_index=key[1])
            if fabric.try_push(ack):
                self._ack_pending.discard(key)
                self.acks_sent.fetch_add(1)
                did = True
        return did

    def _on_ack(self, msg: WireMsg, engine, dev) -> None:
        """Sender side of an incoming cumulative ack: retire every entry
        fully covered by ``cum`` and complete its pending op."""
        cum, epoch = msg.payload
        if epoch != self.epoch:
            self.stale_epoch.fetch_add(1)
            return
        key = (msg.src, msg.device_index)
        done_entries: List[_TxEntry] = []
        with self._lock_of(key):
            dq = self._unacked.get(key)
            while dq and dq[0].first_seq + dq[0].count - 1 <= cum:
                done_entries.append(dq.popleft())
        self.acks_received.fetch_add(1)
        for e in done_entries:
            if e.op_id >= 0:
                # a deadline-failed op was already popped+err-signaled;
                # complete_tx_op on a popped id is a no-op, so the comps
                # stay exactly-once either way
                engine.complete_tx_op(e.op_id, dev)

    # -- recv deadlines -------------------------------------------------------
    def track_recv(self, key, value, comp, rank: int, tag: int,
                   dev) -> None:
        """Arm a completion deadline for one unmatched posted recv (no-op
        without ``post_deadline_us``, so the default costs nothing)."""
        if self.deadline_us <= 0:
            return
        self._tracked_recvs.append(_RecvTrack(
            key, value, comp, time.monotonic() + self.deadline_us * 1e-6,
            rank if rank is not None else -1,
            tag if tag is not None else -1, dev))

    # -- rank death -----------------------------------------------------------
    def kill_peer(self, rank: int) -> None:
        """Declare ``rank`` dead: the next sweep fails its unacked window
        with ``ERR_PEER_DEAD``; its receiver state is discarded."""
        self._dead.add(rank)
        for key in list(self._hold):
            if key[0] == rank:
                self._hold.pop(key, None)
        self._ack_pending.difference_update(
            k for k in list(self._ack_pending) if k[0] == rank)

    def peer_dead(self, rank: int) -> bool:
        return rank in self._dead

    def bump_epoch(self) -> int:
        """Reset every stream (elastic shrink / recovery): in-flight
        traffic from the old epoch is dropped on arrival."""
        self.epoch += 1
        self._next_seq.clear()
        self._unacked.clear()
        self._cum.clear()
        self._hold.clear()
        self._ack_pending.clear()
        return self.epoch

    # -- the sweep stage ------------------------------------------------------
    def sweep(self, engine, dev) -> bool:
        """One timer pass: retransmit overdue entries, expire deadlines,
        fail dead-peer windows, flush stuck acks, expire tracked recvs.
        Called from the progress reaction chain when :meth:`armed`."""
        did = False
        now = time.monotonic()
        fabric = self.rt.fabric
        for key in list(self._unacked.keys()):
            lock = self._lock_of(key)
            if not lock.try_acquire():
                continue                   # another thread owns the stream
            try:
                dq = self._unacked.get(key)
                if not dq:
                    continue
                if key[0] in self._dead:
                    while dq:
                        e = dq.popleft()
                        if e.op_id >= 0:
                            engine.fail_tx_op(e.op_id, dev,
                                              ErrorCode.ERR_PEER_DEAD)
                        self.expired_peer_dead.fetch_add(1)
                    did = True
                    continue
                drop: List[_TxEntry] = []
                for e in dq:
                    if e.deadline and not e.failed and now >= e.deadline:
                        # completion deadline: err the op exactly once
                        # but KEEP retransmitting — abandoning the seq
                        # would stall the receiver's stream on the gap
                        e.failed = True
                        if e.op_id >= 0:
                            engine.fail_tx_op(e.op_id, dev,
                                              ErrorCode.ERR_TIMEOUT)
                        self.expired_timeout.fetch_add(1)
                        did = True
                    wait = self.retry_backoff * min(1 << e.retries,
                                                    _BACKOFF_CAP)
                    if now - e.last_tx < wait:
                        continue
                    if e.retries >= self.retry_limit:
                        if e.op_id >= 0 and not e.failed:
                            engine.fail_tx_op(e.op_id, dev,
                                              ErrorCode.ERR_TIMEOUT)
                        self.abandoned.fetch_add(1)
                        drop.append(e)
                        did = True
                        continue
                    if e.msg.kind in PACKED_KINDS:
                        ok = fabric.push_packed(e.msg) > 0
                    else:
                        ok = fabric.try_push(e.msg)
                    if ok:
                        # a partial packed re-push still counts: the
                        # receiver dedups rows, the suffix rides the
                        # next attempt
                        e.retries += 1
                        e.last_tx = now
                        self.retransmits.fetch_add(1)
                        did = True
                for e in drop:
                    dq.remove(e)
            finally:
                lock.release()
        if self._ack_pending:
            did |= self._flush_acks()
        dq = self._tracked_recvs
        while dq:
            try:
                head = dq[0]
            except IndexError:
                break
            if head.deadline > now:
                break
            try:
                dq.remove(head)
            except ValueError:
                continue                   # another sweeper got it
            if head.rank >= 0 and head.rank in self._dead:
                code = ErrorCode.ERR_PEER_DEAD
            else:
                code = ErrorCode.ERR_TIMEOUT
            if self.rt.matching.remove(head.key, MatchKind.RECV,
                                       head.value):
                engine.signal(head.comp,
                              err(code,
                                  rank=None if head.rank < 0 else head.rank,
                                  tag=None if head.tag < 0 else head.tag),
                              head.dev or dev)
                self.expired_timeout.fetch_add(1)
                did = True
        return did

    # -- probes ---------------------------------------------------------------
    def armed(self) -> bool:
        """Timer work pending (the progress idle fast path must not skip
        the pass): unacked entries, stuck acks, or tracked recvs."""
        if self._ack_pending or self._tracked_recvs:
            return True
        for dq in self._unacked.values():
            if dq:
                return True
        return False

    def busy(self) -> bool:
        """Quiesce probe: also counts receiver hold buffers (a gap that
        is still waiting on the peer's retransmit)."""
        return self.armed() or any(self._hold.values())

    def counters(self) -> dict:
        return {"retransmits": self.retransmits.load(),
                "acks_sent": self.acks_sent.load(),
                "acks_received": self.acks_received.load(),
                "dups_dropped": self.dups_dropped.load(),
                "resequenced": self.resequenced.load(),
                "held": self.held.load(),
                "expired_timeout": self.expired_timeout.load(),
                "expired_peer_dead": self.expired_peer_dead.load(),
                "abandoned": self.abandoned.load(),
                "stale_epoch": self.stale_epoch.load(),
                "epoch": self.epoch}
