"""Endpoints — named, striped bundles of devices with a progress policy.

The paper's central design point (§3.2.3) is that communication resources
are *replicable and incrementally tunable*: a workload that is bottlenecked
on one NIC queue pair allocates more devices and stripes traffic across
them.  An :class:`Endpoint` makes that a first-class API object (per the
AMT-interface argument that the resource group should not be an implicit
global): it owns ``n_devices`` devices on one runtime, a **striping
policy** deciding which device each posted operation rides, and a
**progress policy** deciding who drives them:

* stripe ``"round_robin"`` — ops rotate across devices (max throughput for
  homogeneous traffic);
* stripe ``"by_peer"`` — device = f(target rank): all traffic to one peer
  stays ordered on one stream;
* stripe ``"by_size"`` — size classes get their own devices so small
  latency-sensitive messages (decode tokens) never queue behind bulk
  transfers (prefill prompts) — the paper's "new possibilities" scenario;

* progress ``"shared"`` — the runtime's single engine drives all devices
  (the paper's shared-resource thread mode);
* progress ``"dedicated"`` — one :class:`~.engine.ProgressEngine` per
  device (the dedicated mode that scales with threads);
* progress ``"workers"`` — ``n_workers`` real threads drive the
  endpoint's engines concurrently through per-device try-locks (the
  paper's §4.2.3 multithreaded progress discipline: a thread that fails
  a device's try-lock moves on to the next device).  Start them with
  ``ep.start_workers()`` (or use the endpoint as a context manager) and
  stop with ``ep.stop_workers()``.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Optional, Sequence

import numpy as np

from .. import attrs as _attrs
from ..attrs import AttrError
from ..concurrency.locks import aggregate_lock_stats
from ..concurrency.workers import ProgressWorkerPool
from ..matching import MatchingPolicy
from ..modes import CommMode
from ..post import (CommDesc, CommKind, post_am_x, post_get_x, post_put_x,
                    post_recv_x, post_send_x)
from ..post import post_comm as _post_comm
from ..post import post_many as _post_many
from ..protocol import Protocol, select_protocol
from ..status import FatalError, Status
from .engine import ProgressEngine

STRIPE_POLICIES = _attrs.get_spec("stripe").choices
PROGRESS_POLICIES = _attrs.get_spec("progress").choices

#: the attrs an endpoint resolves at alloc time
ENDPOINT_ATTRS = ("n_devices", "stripe", "progress", "n_workers",
                  "worker_burst")


@dataclasses.dataclass(frozen=True)
class EndpointSpec(_attrs.AttrResource):
    """Declarative endpoint description — what a layer *asks for*.

    A thin view over resolved attributes (DESIGN.md §12): every shape
    field defaults to ``None`` = "resolve through the attribute chain"
    (library default, then ``REPRO_ATTR_*``), and explicit fields are
    validated at construction with errors naming the attribute.  Carried
    by config objects (e.g. ``distributed.Comm``) that cannot hold live
    devices; ``Runtime.alloc_endpoint(spec=...)`` materializes it.
    """

    name: str = "endpoint"
    n_devices: Optional[int] = None
    stripe: Optional[str] = None
    progress: Optional[str] = None
    # workers mode: thread count driving the endpoint's devices
    # (0 = auto: one worker per device)
    n_workers: Optional[int] = None
    # by_size boundaries (bytes): size class i = first boundary >= size;
    # None derives geometric classes from the runtime's protocol thresholds.
    size_boundaries: Optional[Sequence[int]] = None
    # wire messages drained per progress-lock grab in workers mode
    worker_burst: Optional[int] = None

    def __post_init__(self):
        explicit = {a: getattr(self, a) for a in ENDPOINT_ATTRS
                    if getattr(self, a) is not None}
        resolved = _attrs.resolve(ENDPOINT_ATTRS, overrides=explicit)
        self._init_attrs(resolved)
        for attr in ENDPOINT_ATTRS:
            object.__setattr__(self, attr, resolved[attr])
        if self.n_workers and self.progress != "workers":
            if resolved.source("n_workers") == "resource":
                raise AttrError("attribute 'n_workers' only applies to "
                                "progress='workers', got progress="
                                f"{self.progress!r}")
            # an env/runtime-layer worker count is ambient tuning, not a
            # request for workers mode: inert on non-worker endpoints.
            # The stored resolution must agree with what the endpoint
            # actually runs with, so zero it there too.
            object.__setattr__(self, "n_workers", 0)
            self._init_attrs(resolved.merged(_attrs.ResolvedAttrs(
                {"n_workers": 0},
                {"n_workers": resolved.source("n_workers")})))
        if self.size_boundaries is not None:
            bounds = tuple(self.size_boundaries)
            if any(b < 0 for b in bounds):
                raise AttrError("attribute 'size_boundaries' must be "
                                f"non-negative byte sizes, got {bounds}")
            object.__setattr__(self, "size_boundaries", bounds)
        self._export_attr("size_boundaries", lambda: self.size_boundaries)

    @classmethod
    def for_mode(cls, mode: CommMode, n_devices: int = 1,
                 name: str = "endpoint", stripe: str = "round_robin"
                 ) -> "EndpointSpec":
        """Map the paper's shared/dedicated mode split onto a spec."""
        if mode == CommMode.LCI_DEDICATED and n_devices > 1:
            return cls(name=name, n_devices=n_devices, stripe=stripe,
                       progress="dedicated")
        return cls(name=name, n_devices=max(1, n_devices), stripe=stripe,
                   progress="shared")


class Endpoint(_attrs.AttrResource):
    """A live bundle of devices on one runtime, posting through a stripe."""

    def __init__(self, runtime, spec: EndpointSpec,
                 resolved: Optional[_attrs.ResolvedAttrs] = None):
        self.runtime = runtime
        self.spec = spec
        self.devices = [runtime.alloc_device()
                        for _ in range(spec.n_devices)]
        self.workers: Optional[ProgressWorkerPool] = None
        if spec.progress in ("dedicated", "workers"):
            self.engines = [ProgressEngine(runtime, [d],
                                           name=f"{spec.name}/dev{i}")
                            for i, d in enumerate(self.devices)]
        else:
            self.engines = [runtime.engine]
        if spec.progress == "workers":
            self.workers = ProgressWorkerPool(
                list(zip(self.engines, self.devices)),
                n_workers=spec.n_workers or spec.n_devices,
                name=f"{spec.name}/workers", burst=spec.worker_burst,
                tele=getattr(runtime, "tele", None))
        self._rr = 0
        if spec.size_boundaries is not None:
            self._boundaries = list(spec.size_boundaries)
        else:
            # geometric classes seeded by the protocol thresholds: class 0
            # holds inject-able messages, each further class 8x larger
            self._boundaries = [runtime.config.inject_max_bytes * (8 ** i)
                                for i in range(spec.n_devices - 1)]
        # introspection: the alloc-time resolution (full provenance when
        # allocated through Runtime.alloc_endpoint) plus discovered state
        self._init_attrs(resolved or spec._resolved_attrs)
        self._export_attr("width", lambda: len(self.devices))
        self._export_attr("size_boundaries", lambda: list(self._boundaries))
        self._export_attr("device_indices",
                          lambda: [d.index for d in self.devices])
        self._export_attr("contention", self._contention)
        self._export_attr("telemetry", self._telemetry_block)

    def _telemetry_block(self) -> dict:
        """This endpoint's contribution to the unified snapshot: its
        devices' counters plus the bundle's progress-lock contention."""
        tele = getattr(self.runtime, "tele", None)
        counters = {"endpoint.posts": sum(d.posts for d in self.devices),
                    "endpoint.pushes": sum(d.pushes for d in self.devices),
                    "endpoint.progresses": sum(d.progresses
                                               for d in self.devices)}
        counters.update({f"endpoint.lock_{k}": v
                         for k, v in self._contention().items()})
        return {"level": tele.level if tele is not None else "off",
                "counters": counters}

    def _contention(self) -> dict:
        """Aggregate progress-lock telemetry across the bundle (the
        runtime-discovered contention attribute)."""
        return aggregate_lock_stats(d.progress_lock for d in self.devices)

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    def __repr__(self) -> str:
        return (f"Endpoint({self.name!r}, n_devices={self.n_devices}, "
                f"stripe={self.spec.stripe!r}, "
                f"progress={self.spec.progress!r})")

    # -- striping ------------------------------------------------------------
    def select_device(self, *, rank: int = 0, size: int = 0):
        """Pick the device an op rides, per the endpoint's stripe policy."""
        stripe = self.spec.stripe
        if stripe == "by_peer":
            return self.devices[rank % len(self.devices)]
        if stripe == "by_size":
            cls = bisect.bisect_left(self._boundaries, size)
            return self.devices[min(cls, len(self.devices) - 1)]
        dev = self.devices[self._rr % len(self.devices)]
        self._rr += 1
        return dev

    def select_burst_device(self, *, rank: int = 0, size: int = 0):
        """Stripe decision for a whole doorbell, or ``None`` for per-op
        selection.  Round-robin advances once per *burst*, not per op: a
        doorbell rides ONE device stream — per-peer FIFO holds within
        the burst and the per-doorbell costs (pool ``get_n``, payload
        staging, ``push_burst``, the receiver's progress pass) amortize
        over the full burst instead of splintering across the bundle;
        successive bursts still rotate over every device.  ``by_peer`` /
        ``by_size`` keep per-op selection (their placement is a function
        of the op, not of arrival order)."""
        if self.spec.stripe == "round_robin":
            return self.select_device(rank=rank, size=size)
        return None

    # -- posting sugar: every op routes through the single endpoint= path
    #    of repro.core.post (the stripe policy picks the device inside
    #    _route_endpoint, which also validates endpoint ownership) --------
    def post_comm(self, direction, rank: int, buf, local_comp=None, *,
                  tag: int = 0, size=None, remote_buf=None, remote_comp=None,
                  matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG,
                  allow_retry: bool = True, user_context=None) -> Status:
        """The generic Table-1 posting operation, endpoint-routed."""
        return _post_comm(self.runtime, direction, rank, buf, local_comp,
                          tag=tag, size=size, remote_buf=remote_buf,
                          remote_comp=remote_comp, endpoint=self,
                          matching_policy=matching_policy,
                          allow_retry=allow_retry, user_context=user_context)

    def post_send(self, rank: int, buf, size=None, tag: int = 0,
                  local_comp=None, *, allow_retry: bool = True) -> Status:
        return post_send_x(self.runtime, rank, buf, size, tag, local_comp) \
            .endpoint(self).allow_retry(allow_retry)()

    def post_recv(self, rank: int, buf, size=None, tag: int = 0,
                  local_comp=None, *, allow_retry: bool = True) -> Status:
        return post_recv_x(self.runtime, rank, buf, size, tag, local_comp) \
            .endpoint(self).allow_retry(allow_retry)()

    def post_am(self, rank: int, buf, size=None, local_comp=None,
                remote_comp=None, *, tag: int = 0,
                allow_retry: bool = True) -> Status:
        return post_am_x(self.runtime, rank, buf, size, local_comp,
                         remote_comp).tag(tag).endpoint(self) \
            .allow_retry(allow_retry)()

    def post_put(self, rank: int, buf, remote_buf, size=None,
                 local_comp=None, remote_comp=None, *, tag: int = 0,
                 allow_retry: bool = True) -> Status:
        return post_put_x(self.runtime, rank, buf, remote_buf, size,
                          local_comp, remote_comp).tag(tag).endpoint(self) \
            .allow_retry(allow_retry)()

    def post_get(self, rank: int, buf, remote_buf, size=None,
                 local_comp=None, *, tag: int = 0,
                 allow_retry: bool = True) -> Status:
        return post_get_x(self.runtime, rank, buf, remote_buf, size,
                          local_comp).tag(tag).endpoint(self) \
            .allow_retry(allow_retry)()

    # -- burst posting (paper §4.3): K posts, one doorbell per stripe ------
    def post_many(self, ops) -> list[Status]:
        """Post a burst (:class:`~repro.core.post.CommDesc` descriptors or
        unfired ``post_*_x`` builders) through the endpoint's stripe: ops
        are grouped by the device each resolves to, and each group rides
        ONE doorbell — one packet-pool ``get_n``, one stacked payload
        staging copy, one ``fabric.push_burst``, one telemetry bump.
        Per-group order is preserved and failure is prefix-accept, so a
        mid-burst ``retry`` splits — never reorders — the doorbell."""
        return _post_many(self.runtime, ops, endpoint=self)

    def _try_post_fused(self, kind: CommKind, rank: int, bufs, tags,
                        tag: int, local_comp, remote_comp) -> \
            Optional[list[Status]]:
        """Direct fused lowering for a uniform ``post_*_many`` burst
        (DESIGN.md §13): skip per-op :class:`CommDesc` construction and
        size resolution entirely and hand the raw payload list to the
        engine's packed doorbell.  Returns ``None`` when the burst is
        not uniform-eager — the caller falls back to descriptors."""
        rt = self.runtime
        k = len(bufs)
        if not (rt.doorbell_fused and k >= rt.fused_min_burst):
            return None
        first = bufs[0]
        if not isinstance(first, np.ndarray):
            return None
        nb = first.nbytes
        if not (len(set(map(id, bufs))) == 1
                or all(isinstance(b, np.ndarray) and b.nbytes == nb
                       for b in bufs)):
            return None
        proto = select_protocol(nb, rt.config)
        if proto == Protocol.ZEROCOPY:
            return None
        if tags is None:
            tags = [tag] * k
        elif len(tags) != len(bufs):
            raise FatalError(f"post_{kind.value}_many: {len(bufs)} bufs "
                             f"but {len(tags)} tags")
        else:
            tags = list(tags)
        dev = self.select_burst_device(rank=rank, size=nb) \
            or self.select_device(rank=rank, size=nb)
        eng = rt.engine
        eng._burst_posts.fetch_add(1)
        tele = eng.tele
        if tele.timers_on:
            with tele.span("post_burst"):
                return eng._post_fused_run(kind, rank, bufs, tags, nb,
                                           (proto,) * k, local_comp,
                                           remote_comp,
                                           MatchingPolicy.RANK_TAG, dev)
        return eng._post_fused_run(kind, rank, bufs, tags, nb, (proto,) * k,
                                   local_comp, remote_comp,
                                   MatchingPolicy.RANK_TAG, dev)

    def post_send_many(self, rank: int, bufs, *, tags=None, tag: int = 0,
                       local_comp=None, allow_retry: bool = True
                       ) -> list[Status]:
        """Burst of sends to one peer; ``tags`` (else constant ``tag``)
        aligns with ``bufs``."""
        if allow_retry and bufs:
            sts = self._try_post_fused(CommKind.SEND, rank, bufs, tags,
                                       tag, local_comp, None)
            if sts is not None:
                return sts
        if tags is None:
            tags = [tag] * len(bufs)
        elif len(tags) != len(bufs):
            raise FatalError(f"post_send_many: {len(bufs)} bufs but "
                             f"{len(tags)} tags")
        return _post_many(self.runtime, [
            CommDesc(CommKind.SEND, rank, b, tag=t, local_comp=local_comp,
                     allow_retry=allow_retry)
            for b, t in zip(bufs, tags)], endpoint=self)

    def post_am_many(self, rank: int, bufs, remote_comp, *, tags=None,
                     tag: int = 0, local_comp=None,
                     allow_retry: bool = True) -> list[Status]:
        """Burst of active messages to one peer (the message-rate hot
        loop): K payloads, one remote completion handle."""
        if remote_comp is None:
            raise FatalError("post_am_many requires a remote completion "
                             "handle")
        if allow_retry and bufs:
            sts = self._try_post_fused(CommKind.AM, rank, bufs, tags,
                                       tag, local_comp, remote_comp)
            if sts is not None:
                return sts
        if tags is None:
            tags = [tag] * len(bufs)
        elif len(tags) != len(bufs):
            raise FatalError(f"post_am_many: {len(bufs)} bufs but "
                             f"{len(tags)} tags")
        return _post_many(self.runtime, [
            CommDesc(CommKind.AM, rank, b, tag=t, local_comp=local_comp,
                     remote_comp=remote_comp, allow_retry=allow_retry)
            for b, t in zip(bufs, tags)], endpoint=self)

    # -- progress ------------------------------------------------------------
    def _idle(self, dev) -> bool:
        """Lock-free probe: nothing for a pass on ``dev`` to do — no
        incoming traffic, no backlog, no pending source completions, and
        no armed reliability timers (a dropped message's retransmit is
        work even when every queue is empty).  A burst that landed on one
        stripe leaves the other devices idle; skipping their locked
        passes keeps a wide endpoint's progress cost proportional to
        traffic, not to width."""
        rel = self.runtime.rel
        return (not dev.pending_tx and dev.backlog.empty_flag
                and not self.runtime.fabric.stream_depth(
                    self.runtime.rank, dev.index)
                and (rel is None or not rel.armed()))

    def progress(self, rounds: int = 1, max_msgs: int = 0) -> int:
        """Drive this endpoint's devices with its engine(s).

        Safe to call while the worker pool runs: the inline pass uses the
        same per-device try-locks, skipping any device a worker holds."""
        n = 0
        for _ in range(rounds):
            if self.spec.progress == "workers":
                for eng, dev in zip(self.engines, self.devices):
                    if self._idle(dev):
                        continue
                    n += bool(eng.try_progress(dev, max_msgs))
            elif self.spec.progress == "dedicated":
                for eng, dev in zip(self.engines, self.devices):
                    if self._idle(dev):
                        continue
                    n += bool(eng.progress(dev, max_msgs))
            else:
                for dev in self.devices:
                    if self._idle(dev):
                        continue
                    n += bool(self.engines[0].progress(dev, max_msgs))
        return n

    # -- worker lifecycle (progress == "workers") ----------------------------
    def start_workers(self) -> "Endpoint":
        """Spawn the endpoint's progress worker threads."""
        if self.workers is None:
            raise FatalError(f"endpoint {self.name!r} has progress="
                             f"{self.spec.progress!r}; workers need "
                             "EndpointSpec(progress='workers')")
        self.workers.start()
        return self

    def stop_workers(self, timeout: float = 10.0) -> None:
        if self.workers is not None and self.workers.running:
            self.workers.stop(timeout)

    def __enter__(self) -> "Endpoint":
        if self.workers is not None:
            self.start_workers()
        return self

    def __exit__(self, *exc) -> None:
        self.stop_workers()

    # -- telemetry -----------------------------------------------------------
    def counters(self) -> dict:
        """Per-device posts/pushes/progress counts (Fig-8-style evidence
        that traffic really striped across the bundle)."""
        out = {
            "name": self.name,
            "stripe": self.spec.stripe,
            "progress": self.spec.progress,
            "devices": [
                {"index": d.index, "lane": d.lane, "posts": d.posts,
                 "pushes": d.pushes, "progresses": d.progresses}
                for d in self.devices
            ],
        }
        if self.workers is not None:
            out["workers"] = self.workers.counters()
        return out
