"""LCI-X core — the paper's contribution as a composable JAX module.

Public surface mirrors the paper's C++ API (Listing 2) where it makes
sense in Python, plus the in-graph collective layer that is the TPU
adaptation of the zero-copy protocol.
"""
from .attrs import (REGISTRY, AttrError, AttrResource, AttrSpec,
                    ResolvedAttrs, get_spec, parse_attr_args, register_attr,
                    registry_table, resolve, resolve_one,
                    resolved_from_values)
from .backlog import BacklogQueue, Ring, init_ring, ring_pop, ring_push, ring_size
from .channels import Channel, Device, make_channels
from .concurrency import (LCQ, AtomicCounter, AtomicCredit, AtomicFlag,
                          ProgressWorkerPool, ThreadSafeCompletionQueue,
                          TryLock, aggregate_lock_stats)
from .completion import (CompletionHandler, CompletionObject, CompletionQueue,
                         MPMCArray, Synchronizer, SyncState, init_sync,
                         sync_ready, sync_signal)
from .graph import CompletionGraph
from .matching import (HostMatchingEngine, MatchKind, MatchTable,
                       MatchingPolicy, encode_key, init_table, insert,
                       insert_batch, make_key, pending_count, probe,
                       probe_batch)
from .modes import CommConfig, CommMode, parse_mode
from .off import OffBuilder, off
from .packet_pool import (HostPacketPool, SlotPool, free_count,
                          init_buffers, init_pool, pool_get,
                          pool_get_copy_n, pool_get_n, pool_put)
from .post import (CommDesc, CommKind, Direction, PostBatch, classify,
                   post_am, post_am_x, post_comm, post_comm_x, post_get,
                   post_get_x, post_many, post_put, post_put_x, post_recv,
                   post_recv_x, post_send, post_send_x)
from .protocol import Protocol, ProtocolStats, select_protocol
from .progress import (Endpoint, EndpointSpec, Fabric, MemoryRegion,
                       PackedBurst, ProgressEngine, RendezvousManager,
                       WireKind, WireMsg, pack_payloads)
from .runtime import (LocalCluster, ProcessCluster, Runtime, g_runtime,
                      g_runtime_fina, g_runtime_init, progress, progress_x)
from .telemetry import (NULL_TELEMETRY, MetricRegistry, Telemetry,
                        TraceBuffer, merge_snapshots, record_burst_mix,
                        render_block, summarize_spans)
from .transport import (Transport, backend_class, decode_msg, encode_msg,
                        make_transport, msg_weight, register_backend)
from .status import (ErrorCode, ErrorKind, FatalError, Status, done, posted,
                     retry)
from . import collectives

__all__ = [
    # status
    "ErrorCode", "ErrorKind", "FatalError", "Status", "done", "posted",
    "retry",
    # unified attribute system (DESIGN.md §12)
    "REGISTRY", "AttrError", "AttrResource", "AttrSpec", "ResolvedAttrs",
    "get_spec", "parse_attr_args", "register_attr", "registry_table",
    "resolve", "resolve_one", "resolved_from_values",
    # resources
    "BacklogQueue", "Channel", "Device", "CompletionGraph",
    "CompletionHandler", "CompletionObject", "CompletionQueue", "MPMCArray",
    "Synchronizer", "HostMatchingEngine", "HostPacketPool",
    "MatchingPolicy", "MatchKind", "make_channels", "make_key",
    # functional resources
    "Ring", "init_ring", "ring_push", "ring_pop", "ring_size",
    "SlotPool", "init_pool", "pool_get", "pool_put", "free_count",
    "MatchTable", "init_table", "insert", "insert_batch", "encode_key",
    "pending_count", "SyncState", "init_sync", "sync_signal", "sync_ready",
    # posting
    "CommKind", "Direction", "classify", "post_comm", "post_comm_x",
    "post_send", "post_send_x", "post_recv", "post_recv_x", "post_am",
    "post_am_x", "post_put", "post_put_x", "post_get", "post_get_x",
    # burst posting (paper §4.3 batched data plane)
    "CommDesc", "PostBatch", "post_many", "pool_get_n",
    # fused doorbells (DESIGN.md §13)
    "PackedBurst", "pack_payloads", "pool_get_copy_n", "init_buffers",
    "probe", "probe_batch",
    # runtime + progress subsystem
    "Fabric", "LocalCluster", "MemoryRegion", "Runtime", "WireKind",
    "WireMsg", "g_runtime", "g_runtime_fina", "g_runtime_init", "progress",
    "progress_x", "Endpoint", "EndpointSpec", "ProgressEngine",
    "RendezvousManager",
    # pluggable transport backends (DESIGN.md §14)
    "Transport", "ProcessCluster", "backend_class", "decode_msg",
    "encode_msg", "make_transport", "msg_weight", "register_backend",
    # modes & protocol
    "CommConfig", "CommMode", "parse_mode", "Protocol", "ProtocolStats",
    "select_protocol", "off", "OffBuilder",
    # concurrency subsystem (paper §4.1)
    "AtomicCounter", "AtomicCredit", "AtomicFlag", "LCQ",
    "ProgressWorkerPool", "ThreadSafeCompletionQueue", "TryLock",
    "aggregate_lock_stats",
    # telemetry plane (DESIGN.md §15)
    "NULL_TELEMETRY", "MetricRegistry", "Telemetry", "TraceBuffer",
    "merge_snapshots", "record_burst_mix", "render_block",
    "summarize_spans",
    # in-graph collectives
    "collectives",
]
