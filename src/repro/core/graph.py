"""Completion graph (paper §3.2.5) — DAGs of comm/compute with partial order.

Paper: "Graph is a more advanced completion object type similar to CUDA
Graph that allows users to specify a set of communication operations or
user-provided functions with a partial execution order. If operation u
precedes operation v in that order, then v will be started only after u
completes. ... Every node in the completion graph uses an atomic counter to
track the number of received signals. Every ready node will be immediately
fired, and a completed node will signal all its descendants."

The graph is a true completion object (:class:`~.completion.CompletionObject`):

* **function nodes** run a host callable inline when ready;
* **communication nodes** hold a *deferred* operation — an unfired OFF
  builder (``post_send_x(...)`` etc., see :mod:`repro.core.off`).  When the
  node becomes ready the graph *posts* the op; the progress engine signals
  the node on completion, and descendants fire as signals arrive.  This is
  the paper's headline graph feature: comm ops as nodes, completed
  asynchronously, never fired host-side.
* **signal nodes** complete when ``graph.signal(status)`` is delivered from
  outside — this is how the graph itself serves as the completion object of
  an external operation.

Lifecycle: ``alloc_graph`` → build (``add_node``/``add_comm``/``add_edge``)
→ ``start()`` (posts ready comm nodes, runs ready fn nodes) → drive
progress → ``test()``/``wait()``.  The old synchronous ``execute()`` is
kept as a thin shim over start+drain and behaves identically for pure
host-function graphs.

On TPU the same DAG discipline is *the* scheduling primitive of LCI-X:
executing it under ``jax.jit`` traces the nodes in dependency order and
leaves independent chains unordered, which is exactly the freedom XLA's
latency-hiding scheduler needs to overlap collective chains with compute
chains.  The host-side executor drives async checkpoint commit pipelines
(:mod:`repro.checkpoint.store`) and the 1F1B pipeline-parallel schedule
(:mod:`repro.distributed.pipeline`).

Execution keeps the paper's *counter* semantics observable: each node holds
a signal counter; nodes fire from a ready set (counter == indegree), never
by naive list order, and ``fire_order`` records the *completion* sequence
for tests to assert the partial order.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from .completion import CompletionObject, _as_progress_fn
from .off import OffBuilder
from .status import ErrorCode, FatalError, Status, done

_FN, _COMM, _SIGNAL = "fn", "comm", "signal"


@dataclasses.dataclass
class _Node:
    nid: int
    fn: Any                  # callable (fn), OffBuilder (comm), None (signal)
    deps: tuple
    name: str
    kind: str = _FN
    # paper: "every node ... uses an atomic counter to track the number of
    # received signals"
    signals: int = 0
    fired: bool = False      # started (posted, for comm nodes)
    completed: bool = False
    value: Any = None


class _GraphNodeComp(CompletionObject):
    """Per-node completion proxy handed to a comm node's posting op."""

    def __init__(self, graph: "CompletionGraph", nid: int):
        self.graph = graph
        self.nid = nid

    def signal(self, status: Status) -> Status:
        self.graph._on_comm_complete(self.nid, status)
        return done()

    def test(self):
        node = self.graph._nodes[self.nid]
        return node.completed, node.value


class CompletionGraph(CompletionObject):
    """A DAG of host callables and deferred comm ops; a completion object."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self._nodes: List[_Node] = []
        self._succs: Dict[int, List[int]] = {}
        self.fire_order: List[int] = []
        self._started = False
        self._n_done = 0
        self._inflight = 0                    # comm ops posted, not signaled
        self._ready: collections.deque = collections.deque()
        self._parked: collections.deque = collections.deque()  # comm retries
        self._ext_signals: collections.deque = collections.deque()
        self._progress_sources: list = []
        # read-only discovered attrs (the unified get_attr surface)
        self._export_attr("n_nodes", lambda: len(self._nodes))
        self._export_attr("n_comm_nodes", lambda: sum(
            1 for n in self._nodes if n.kind != _FN))
        self._export_attr("started", lambda: self._started)
        self._export_attr("n_done", lambda: self._n_done)

    # -- construction -------------------------------------------------------
    def _insert(self, fn, deps: Sequence[int], name: Optional[str],
                kind: str) -> int:
        nid = len(self._nodes)
        for d in deps:
            if d >= nid or d < 0:
                raise FatalError(f"graph node {nid}: bad dep {d}")
            self._succs.setdefault(d, []).append(nid)
        self._nodes.append(_Node(nid, fn, tuple(deps),
                                 name or f"{kind}{nid}", kind=kind))
        return nid

    def add_node(self, fn, deps: Sequence[int] = (),
                 name: Optional[str] = None) -> int:
        """Add a node. A callable receives the *values* of its deps, in
        order; an unfired OFF builder becomes a communication node."""
        if isinstance(fn, OffBuilder):
            return self.add_comm(fn, deps, name)
        return self._insert(fn, deps, name or f"n{len(self._nodes)}", _FN)

    def add_comm(self, op: OffBuilder, deps: Sequence[int] = (),
                 name: Optional[str] = None) -> int:
        """Add a *communication* node: an unfired OFF builder (e.g.
        ``post_send_x(rt, 1, buf, 8, tag).endpoint(ep)``).  The graph posts
        it when the node becomes ready and completes the node when the
        progress engine signals the operation's local completion."""
        if not isinstance(op, OffBuilder):
            raise FatalError(f"add_comm needs an unfired OFF builder, got "
                             f"{type(op).__name__} (use add_node for "
                             f"host callables)")
        if op.get("local_comp") is not None:
            raise FatalError("comm node op must leave local_comp unset — "
                             "the graph owns the node's completion")
        nid = self._insert(op, deps, name, _COMM)
        op.set("local_comp", _GraphNodeComp(self, nid))
        # the graph is the retry mechanism for its nodes: retries come back
        # as values and the node is re-posted from _parked.  allow_retry
        # False would instead park the op in the engine backlog, where a
        # backlogged *inject* completes without ever signaling local_comp
        # (paper §3.2.5) — the node would never finish.
        try:
            op.set("allow_retry", True)
        except TypeError:             # op without the option: nothing to fix
            pass
        self._note_progress_source(op)
        return nid

    def add_signal_node(self, deps: Sequence[int] = (),
                        name: Optional[str] = None) -> int:
        """Add a node completed by an external ``graph.signal(status)`` —
        how the graph serves as the completion object of ops outside it."""
        return self._insert(None, deps, name, _SIGNAL)

    def add_edge(self, u: int, v: int) -> None:
        """Impose ordering u -> v without value flow.

        Validated at insertion (paper: fatal errors raise): self-edges,
        duplicate edges, and backward edges (``u >= v`` — node ids are
        topologically ordered, so such an edge can only create a cycle)
        are all rejected here instead of surfacing as a cycle error deep
        inside execution.
        """
        n = len(self._nodes)
        if not (0 <= u < n and 0 <= v < n):
            raise FatalError(f"add_edge({u}, {v}): unknown node "
                             f"(graph has {n} nodes)")
        if u == v:
            raise FatalError(f"add_edge({u}, {u}): self-edge would deadlock "
                             "the node on its own completion")
        if u > v:
            raise FatalError(f"add_edge({u}, {v}): backward edge — node ids "
                             "are topologically ordered, so u must precede "
                             "v (this edge would create a cycle)")
        node = self._nodes[v]
        if u in node.deps:
            raise FatalError(f"add_edge({u}, {v}): duplicate edge (already "
                             "a dependency)")
        if node.fired:
            raise FatalError(f"add_edge({u}, {v}): node {v} already fired "
                             "in a running graph")
        node.deps = node.deps + (u,)
        self._succs.setdefault(u, []).append(v)

    def add_progress(self, source) -> None:
        """Register an extra progress driver for ``wait()``/``execute()``."""
        if source not in self._progress_sources:
            self._progress_sources.append(source)

    def _note_progress_source(self, op: OffBuilder) -> None:
        # post_* builders carry the runtime first; drive its whole cluster
        # so peer ranks react too (thread-mode: one address space).
        args = getattr(op, "_args", ())
        if args:
            rt = args[0]
            src = getattr(rt, "cluster", None) or \
                (rt if hasattr(rt, "progress") else None)
            if src is not None and src not in self._progress_sources:
                self._progress_sources.append(src)

    # -- the async lifecycle: start -> progress -> test/wait -----------------
    def start(self, *root_args) -> "CompletionGraph":
        """Reset state, then fire every ready node: host-fn nodes run
        inline, comm nodes are *posted* (their completion arrives through
        the progress engine).  Returns self for chaining."""
        if self._inflight:
            raise FatalError(f"graph {self.name!r} restarted with "
                             f"{self._inflight} comm nodes still in flight")
        for n in self._nodes:
            n.signals = 0
            n.fired = False
            n.completed = False
            n.value = None
        self.fire_order = []
        self._started = True
        self._n_done = 0
        self._ready.clear()
        self._parked.clear()
        # _ext_signals deliberately survives the reset: signal() may be
        # delivered (and buffered) before start() — dropping it here would
        # lose a completion that signal() already accepted with done()
        self._root_args = root_args
        for n in self._nodes:
            if not n.deps:
                self._ready.append(n.nid)
        self._pump()
        return self

    def _pump(self) -> None:
        """Fire every currently-ready node (FIFO: deterministic order)."""
        while self._ready:
            self._fire(self._ready.popleft())

    def _fire(self, nid: int) -> None:
        node = self._nodes[nid]
        if node.fired:
            raise FatalError(f"node {node.name} fired twice")
        node.fired = True
        if node.kind == _FN:
            args = (list(self._root_args) if not node.deps
                    else [self._nodes[d].value for d in node.deps])
            self._complete(nid, node.fn(*args))
        elif node.kind == _COMM:
            self._post_comm_node(nid)
        else:                                  # _SIGNAL
            if self._ext_signals:
                self._complete(nid, self._ext_signals.popleft())
            # else: stays fired-but-incomplete until graph.signal() arrives

    def _post_comm_node(self, nid: int) -> None:
        node = self._nodes[nid]
        st = node.fn()                         # fire the OFF builder
        if not isinstance(st, Status):
            raise FatalError(f"comm node {node.name} did not return a "
                             f"Status (got {type(st).__name__})")
        if st.is_done():
            # completed inline (inject / pre-matched recv): comps are NOT
            # signaled for done (paper §3.2.5) — complete the node now
            self._complete(nid, st)
        elif st.is_posted():
            if st.code == ErrorCode.POSTED_BACKLOG:
                # should be unreachable (add_comm forces allow_retry=True):
                # a backlogged inject never signals its comp
                raise FatalError(f"comm node {node.name} was parked in the "
                                 "engine backlog; post it with "
                                 "allow_retry=True so the graph can retry")
            self._inflight += 1               # progress engine will signal
        else:                                  # retry: repost on next pump
            node.fired = False
            self._parked.append(nid)

    def _complete(self, nid: int, value: Any) -> None:
        node = self._nodes[nid]
        if node.completed:
            raise FatalError(f"node {node.name} completed twice")
        node.fired = True
        node.completed = True
        node.value = value
        self._n_done += 1
        self.fire_order.append(nid)
        # completed node signals all its descendants
        for s in self._succs.get(nid, ()):
            snode = self._nodes[s]
            snode.signals += 1
            if snode.signals == len(snode.deps):
                self._ready.append(s)

    def _on_comm_complete(self, nid: int, status: Status) -> None:
        node = self._nodes[nid]
        if not self._started or not node.fired or node.completed:
            raise FatalError(f"stray completion signal for node "
                             f"{node.name} (started={self._started})")
        self._inflight -= 1
        self._complete(nid, status)
        self._pump()                           # descendants fire as signals arrive

    # -- the unified comp protocol ------------------------------------------
    def signal(self, status: Status) -> Status:
        """External delivery (graph used as another op's completion object):
        completes the oldest ready signal node, or buffers the status until
        one becomes ready."""
        if not any(n.kind == _SIGNAL for n in self._nodes):
            raise FatalError(f"graph {self.name!r} signaled but has no "
                             "signal nodes (add_signal_node)")
        for n in self._nodes:
            if n.kind == _SIGNAL and n.fired and not n.completed:
                self._complete(n.nid, status)
                self._pump()
                return done()
        self._ext_signals.append(status)
        return done()

    def test(self) -> tuple[bool, Optional[Dict[int, Any]]]:
        """Non-blocking: repost parked comm nodes, then report completion.
        Payload is the ``{nid: value}`` map once every node completed."""
        if not self._started:
            return False, None
        for _ in range(len(self._parked)):     # retry parked comm posts
            self._ready.append(self._parked.popleft())
        self._pump()
        if self._n_done == len(self._nodes):
            return True, {n.nid: n.value for n in self._nodes}
        if (self._inflight == 0 and not self._parked and not self._ready
                and not any(n.kind == _SIGNAL and n.fired and not n.completed
                            for n in self._nodes)):
            pending = [n.name for n in self._nodes if not n.completed]
            raise FatalError(f"completion graph stalled (cycle or orphan "
                             f"dependency); unfired: {pending}")
        return False, None

    def wait(self, progress=None, max_rounds: int = 100_000
             ) -> Dict[int, Any]:
        """Drive progress until every node completed; returns the values.
        With ``progress=None`` the graph drives the clusters/runtimes its
        comm nodes post on (collected at ``add_comm`` time)."""
        if progress is None and self._progress_sources:
            drivers = [_as_progress_fn(s) for s in self._progress_sources]

            def progress():                    # noqa: F811 - deliberate
                for drive in drivers:
                    drive()
        return super().wait(progress, max_rounds)

    # -- compatibility shim: the old synchronous execute ---------------------
    def execute(self, *root_args) -> Dict[int, Any]:
        """start + drain.  For pure host-function graphs this is exactly the
        old synchronous semantics; with comm nodes it drives the involved
        clusters' progress until the graph completes."""
        self.start(*root_args)
        return self.wait()

    def value(self, nid: int) -> Any:
        return self._nodes[nid].value

    def __len__(self) -> int:
        return len(self._nodes)

    # -- introspection for tests/benchmarks ----------------------------------
    def assert_partial_order(self) -> None:
        """Validate the last execution respected every edge."""
        pos = {nid: i for i, nid in enumerate(self.fire_order)}
        for n in self._nodes:
            for d in n.deps:
                if pos[d] >= pos[n.nid]:
                    raise FatalError(
                        f"partial order violated: {d} fired after {n.nid}")

    def critical_path_len(self) -> int:
        """Longest chain length — the graph's serialization lower bound."""
        depth: Dict[int, int] = {}
        for n in self._nodes:               # nodes are topologically indexed
            depth[n.nid] = 1 + max((depth[d] for d in n.deps), default=0)
        return max(depth.values(), default=0)

    def counters(self) -> dict:
        """Node-state snapshot (telemetry, benchmark evidence)."""
        kinds = collections.Counter(n.kind for n in self._nodes)
        return {
            "name": self.name,
            "nodes": len(self._nodes),
            "fn_nodes": kinds.get(_FN, 0),
            "comm_nodes": kinds.get(_COMM, 0),
            "signal_nodes": kinds.get(_SIGNAL, 0),
            "completed": self._n_done,
            "inflight": self._inflight,
            "critical_path": self.critical_path_len(),
        }
