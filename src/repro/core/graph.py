"""Completion graph (paper §3.2.5) — DAGs of comm/compute with partial order.

Paper: "Graph is a more advanced completion object type similar to CUDA
Graph that allows users to specify a set of communication operations or
user-provided functions with a partial execution order. If operation u
precedes operation v in that order, then v will be started only after u
completes. ... Every node in the completion graph uses an atomic counter to
track the number of received signals. Every ready node will be immediately
fired, and a completed node will signal all its descendants."

On TPU the graph is *the* scheduling primitive of LCI-X: executing it under
``jax.jit`` traces the nodes in dependency order and leaves independent
chains unordered, which is exactly the freedom XLA's latency-hiding
scheduler needs to overlap collective chains with compute chains.  The same
executor drives host-side work (async checkpoint commit pipelines) and the
1F1B pipeline-parallel schedule (:mod:`repro.distributed.pipeline` builds a
CompletionGraph of per-microbatch stage nodes).

Execution keeps the paper's *counter* semantics observable: each node holds
a signal counter; ``execute`` fires nodes from a ready set (counter ==
indegree), never by naive list order, and records the firing sequence for
tests to assert the partial order.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

from .status import FatalError


@dataclasses.dataclass
class _Node:
    nid: int
    fn: Callable[..., Any]
    deps: tuple
    name: str
    # paper: "every node ... uses an atomic counter to track the number of
    # received signals"
    signals: int = 0
    fired: bool = False
    value: Any = None


class CompletionGraph:
    """A DAG of callables; ``execute`` fires ready nodes until drained."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self._nodes: List[_Node] = []
        self._succs: Dict[int, List[int]] = {}
        self.fire_order: List[int] = []

    # -- construction -------------------------------------------------------
    def add_node(self, fn: Callable[..., Any], deps: Sequence[int] = (),
                 name: Optional[str] = None) -> int:
        """Add a node. ``fn`` receives the *values* of its deps, in order."""
        nid = len(self._nodes)
        for d in deps:
            if d >= nid or d < 0:
                raise FatalError(f"graph node {nid}: bad dep {d}")
            self._succs.setdefault(d, []).append(nid)
        self._nodes.append(_Node(nid, fn, tuple(deps),
                                 name or f"n{nid}"))
        return nid

    def add_edge(self, u: int, v: int) -> None:
        """Impose ordering u -> v without value flow."""
        node = self._nodes[v]
        node.deps = node.deps + (u,)
        self._succs.setdefault(u, []).append(v)

    # -- execution -----------------------------------------------------------
    def execute(self, *root_args) -> Dict[int, Any]:
        """Fire all nodes respecting the partial order; returns values.

        Ready-set driven: a node fires when its signal counter reaches its
        indegree.  Roots (no deps) receive ``root_args``.
        """
        for n in self._nodes:
            n.signals = 0
            n.fired = False
            n.value = None
        self.fire_order = []

        indeg = {n.nid: len(n.deps) for n in self._nodes}
        ready = [n.nid for n in self._nodes if indeg[n.nid] == 0]
        fired = 0
        while ready:
            nid = ready.pop(0)           # FIFO: deterministic fire order
            node = self._nodes[nid]
            args = ([n for n in root_args] if not node.deps
                    else [self._nodes[d].value for d in node.deps])
            node.value = node.fn(*args)
            node.fired = True
            fired += 1
            self.fire_order.append(nid)
            # completed node signals all descendants
            for s in self._succs.get(nid, ()):
                snode = self._nodes[s]
                snode.signals += 1
                if snode.signals == len(snode.deps):
                    ready.append(s)
        if fired != len(self._nodes):
            pending = [n.name for n in self._nodes if not n.fired]
            raise FatalError(f"completion graph has a cycle or orphan "
                             f"dependency; unfired: {pending}")
        return {n.nid: n.value for n in self._nodes}

    def value(self, nid: int) -> Any:
        return self._nodes[nid].value

    def __len__(self) -> int:
        return len(self._nodes)

    # -- introspection for tests/benchmarks ----------------------------------
    def assert_partial_order(self) -> None:
        """Validate the last execution respected every edge."""
        pos = {nid: i for i, nid in enumerate(self.fire_order)}
        for n in self._nodes:
            for d in n.deps:
                if pos[d] >= pos[n.nid]:
                    raise FatalError(
                        f"partial order violated: {d} fired after {n.nid}")

    def critical_path_len(self) -> int:
        """Longest chain length — the graph's serialization lower bound."""
        depth: Dict[int, int] = {}
        for n in self._nodes:               # nodes are topologically indexed
            depth[n.nid] = 1 + max((depth[d] for d in n.deps), default=0)
        return max(depth.values(), default=0)
