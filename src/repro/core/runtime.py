"""LCI-X runtime — resource lifecycle: runtimes, devices, endpoints, clusters.

Mirrors the paper's runtime lifecycle (§3.2.2): no global init/fina;
instead runtime objects are allocated/freed, and multiple runtimes can
coexist (library composition).  :class:`LocalCluster` simulates the paper's
*thread mode* faithfully: all ranks live in one address space (exactly like
threads of one process), each with its own :class:`Runtime` holding
replicable resources (devices, matching engine, packet pool, CQs).

Everything that *moves data* lives in :mod:`repro.core.progress`:

* the fabric and wire format          -> ``progress/fabric.py``
* posting + the Figure-1 chain        -> ``progress/engine.py``
* rendezvous (RTS/CTS/RDMA) and RMA   -> ``progress/rendezvous.py``
* multi-device striped endpoints      -> ``progress/endpoint.py``

This module only allocates, wires together, and frees those resources —
plus the thin delegation (``Runtime._post`` / ``Runtime.progress``) that
keeps the paper's Listing-2 call surface on the runtime object.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from .channels import Device
from .completion import (CompletionHandler, CompletionObject, CompletionQueue,
                         MPMCArray, Synchronizer)
from .concurrency import ProgressWorkerPool, ThreadSafeCompletionQueue
from .graph import CompletionGraph
from .matching import HostMatchingEngine
from .modes import CommConfig
from .off import off
from .packet_pool import HostPacketPool
from .protocol import ProtocolStats
from .status import FatalError, Status
# Re-exported names that historically lived here (public API compatibility).
from .progress import (Endpoint, EndpointSpec, Fabric, MemoryRegion,
                       PendingOp, ProgressEngine, RendezvousManager,
                       WireKind, WireMsg, as_bytes_view, payload_to_bytes)

# back-compat aliases for the old private helpers
_as_bytes_view = as_bytes_view
_payload_to_bytes = payload_to_bytes


class Runtime:
    """One rank's LCI runtime: the replicable resource set.

    Posting and progress are delegated to the default
    :class:`~repro.core.progress.ProgressEngine`; dedicated engines (and
    multi-device striping) are allocated through :meth:`alloc_endpoint`.
    """

    def __init__(self, rank: int, cluster: "LocalCluster",
                 config: Optional[CommConfig] = None):
        self.rank = rank
        self.cluster = cluster
        self.config = config or cluster.config
        # resources (all replicable; these are the process-default set)
        self.matching = HostMatchingEngine(self.config.matching_buckets)
        self.packet_pool = HostPacketPool(
            n_lanes=max(1, self.config.n_channels),
            packets_per_lane=self.config.packets_per_lane,
            packet_bytes=self.config.packet_bytes)
        self.rcomp_registry = MPMCArray()      # paper §4.1.1 MPMC array
        self.memory_regions = MPMCArray()
        self.devices: List[Device] = []
        self._next_device_index = 0
        self.stats = ProtocolStats()
        # shared per-rank op state the engines operate on
        self.pending_ops: Dict[int, PendingOp] = {}
        self.rdv = RendezvousManager(self)
        self.engine = ProgressEngine(self, name=f"rank{rank}/shared")
        self.endpoints: List[Endpoint] = []
        self.default_device = self.alloc_device(lane=0)

    # -- rank / fabric queries ----------------------------------------------
    def get_rank_me(self) -> int:
        return self.rank

    def get_rank_n(self) -> int:
        return self.cluster.n_ranks

    @property
    def n_ranks(self) -> int:
        return self.cluster.n_ranks

    @property
    def fabric(self) -> Fabric:
        return self.cluster.fabric

    # -- resource allocation -------------------------------------------------
    def alloc_device(self, lane: Optional[int] = None) -> Device:
        dev = Device(self.config,
                     lane=(lane if lane is not None
                           else len(self.devices) % self.packet_pool.n_lanes))
        # indices are never reused: a fabric stream keyed by a freed
        # device's index must not silently alias a later allocation
        dev.index = self._next_device_index
        self._next_device_index += 1
        self.devices.append(dev)
        return dev

    def _check_device_freeable(self, device: Device) -> None:
        if device is self.default_device:
            raise FatalError("cannot free the default device")
        if not device.backlog.empty_flag or device.pending_tx:
            raise FatalError("cannot free a device with backlogged or "
                             "in-flight operations")
        if device.index in self.fabric.pending_streams(self.rank):
            raise FatalError("cannot free a device with undrained incoming "
                             "traffic (progress it first)")

    def free_device(self, device: Device) -> None:
        self._check_device_freeable(device)
        self.devices.remove(device)

    def alloc_endpoint(self, n_devices: int = 1,
                       stripe: str = "round_robin",
                       progress: str = "shared",
                       name: Optional[str] = None, *,
                       spec: Optional[EndpointSpec] = None) -> Endpoint:
        """Allocate a named multi-device endpoint (paper §3.2.3: devices
        are replicable and incrementally tunable).  Pass either the knobs
        or a prebuilt :class:`EndpointSpec`."""
        if spec is None:
            spec = EndpointSpec(
                name=name or f"rank{self.rank}/ep{len(self.endpoints)}",
                n_devices=n_devices, stripe=stripe, progress=progress)
        ep = Endpoint(self, spec)
        self.endpoints.append(ep)
        return ep

    def free_endpoint(self, ep: Endpoint) -> None:
        # a live worker pool must be quiesced before its devices go away
        ep.stop_workers()
        # validate every device BEFORE mutating: a busy device must not
        # leave the endpoint half-freed
        for dev in ep.devices:
            self._check_device_freeable(dev)
        for dev in ep.devices:
            self.devices.remove(dev)
        self.endpoints.remove(ep)

    def alloc_engine(self, devices: Optional[List[Device]] = None,
                     name: str = "engine") -> ProgressEngine:
        return ProgressEngine(self, devices, name=name)

    def alloc_workers(self, n_workers: int = 2) -> ProgressWorkerPool:
        """A worker pool over this runtime's current devices, driven by
        the shared engine (paper §4.2.3 multithreaded progress).  The
        caller owns the lifecycle: ``with rt.alloc_workers(4): ...``."""
        return ProgressWorkerPool.for_runtime(self, n_workers)

    # Completion-object allocation (paper §3.2.5): every alloc_* handle
    # satisfies the unified comp protocol — signal(Status) -> Status,
    # non-blocking test(), progress-driven wait().
    def alloc_cq(self, capacity: Optional[int] = None, *,
                 threadsafe: bool = False) -> CompletionObject:
        """``threadsafe=True`` returns the LCQ-backed queue (paper §4.1.4
        FAA array) — required when worker threads signal or drain it."""
        if threadsafe:
            return ThreadSafeCompletionQueue(capacity)
        return CompletionQueue(capacity)

    def alloc_handler(self, fn: Callable[[Status], None]) -> CompletionHandler:
        return CompletionHandler(fn)

    def alloc_sync(self, expected: int = 1) -> Synchronizer:
        return Synchronizer(expected)

    def alloc_graph(self, name: str = "graph") -> CompletionGraph:
        g = CompletionGraph(name)
        g.add_progress(self.cluster)   # default driver for wait()/execute()
        return g

    def free_comp(self, comp: CompletionObject) -> None:
        pass                                    # GC does the freeing

    def register_rcomp(self, comp: CompletionObject) -> int:
        """Register a completion object for *remote* signaling; returns the
        remote completion handle other ranks pass to post_am/put-signal."""
        return self.rcomp_registry.append(comp)

    def register_memory(self, buf: Any) -> MemoryRegion:
        view = as_bytes_view(buf)
        region = MemoryRegion(rid=len(self.memory_regions), buf=view)
        self.memory_regions.append(region)
        return region

    # -- posting / progress: thin delegation to the default engine -----------
    def _post(self, **kwargs) -> Status:
        return self.engine.post(**kwargs)

    def post_many(self, ops, *, endpoint: Optional[Endpoint] = None,
                  device: Optional[Device] = None) -> List[Status]:
        """Burst posting (paper §4.3): coalesce a sequence of ops
        (:class:`~repro.core.post.CommDesc` or unfired ``post_*_x``
        builders) into per-device doorbells — see
        :func:`repro.core.post.post_many`."""
        from .post import post_many as _post_many
        return _post_many(self, ops, endpoint=endpoint, device=device)

    def progress(self, device: Optional[Device] = None,
                 max_msgs: int = 0) -> bool:
        return self.engine.progress(device, max_msgs)

    # back-compat: rendezvous landing zones (CTS handshake state)
    @property
    def _rendezvous_landing(self) -> list:
        return self.rdv.landing

    @property
    def _pending(self) -> Dict[int, PendingOp]:
        return self.pending_ops


# -- module-level progress with the paper's OFF spelling --------------------
#    lci::progress_x().device(device)()

@off
def progress(runtime: Runtime, device: Optional[Device] = None,
             max_msgs: int = 0) -> bool:
    return runtime.progress(device=device, max_msgs=max_msgs)


progress_x = progress.x


# ---------------------------------------------------------------------------
# cluster
# ---------------------------------------------------------------------------

class LocalCluster:
    """All ranks in one address space — the paper's thread-mode testbed.

    ``link_latency`` (seconds) makes the simulated wire take time: pushed
    messages become drainable only after the latency elapses.  Zero (the
    default) keeps the instant fabric; the multithreaded benchmarks use a
    real latency so completion windows model flow control.
    """

    def __init__(self, n_ranks: int, config: Optional[CommConfig] = None,
                 fabric_depth: int = 4096, link_latency: float = 0.0):
        self.n_ranks = n_ranks
        self.config = config or CommConfig()
        self.fabric = Fabric(n_ranks, depth=fabric_depth,
                             latency=link_latency)
        self.runtimes = [Runtime(r, self) for r in range(n_ranks)]

    def __getitem__(self, rank: int) -> Runtime:
        return self.runtimes[rank]

    def alloc_endpoint(self, n_devices: int = 1,
                       stripe: str = "round_robin",
                       progress: str = "shared",
                       name: str = "endpoint") -> List[Endpoint]:
        """Allocate a symmetric endpoint on every rank (device streams are
        matched by index, so peers must replicate the same bundle shape);
        returns the per-rank endpoints, indexed by rank."""
        return [rt.alloc_endpoint(n_devices, stripe, progress,
                                  name=f"{name}@{rt.rank}")
                for rt in self.runtimes]

    def alloc_workers(self, n_workers: int = 2) -> "ProgressWorkerPool":
        """A worker pool spanning every rank's devices — the paper's
        thread-mode testbed with real threads driving all progress."""
        return ProgressWorkerPool.for_cluster(self, n_workers)

    def progress_all(self, rounds: int = 1) -> int:
        """Drive every device of every rank; returns #work events."""
        n = 0
        for _ in range(rounds):
            for rt in self.runtimes:
                for dev in rt.devices:
                    n += bool(rt.progress(dev))
        return n

    def quiesce(self, max_rounds: int = 10_000) -> None:
        """Progress until no work remains (test/benchmark helper)."""
        import time as _time
        for _ in range(max_rounds):
            if not self.progress_all():
                if self.fabric.in_flight() == 0:
                    return
                # messages still on the (latency-modeled) wire: wait for
                # them to become drainable rather than declaring quiet
                _time.sleep(self.fabric.latency / 4 or 1e-5)
        raise FatalError("cluster failed to quiesce")


# -- module-level convenience (paper's g_runtime) ---------------------------

_g_cluster: Optional[LocalCluster] = None


def g_runtime_init(n_ranks: int = 1,
                   config: Optional[CommConfig] = None) -> LocalCluster:
    global _g_cluster
    _g_cluster = LocalCluster(n_ranks, config)
    return _g_cluster


def g_runtime() -> LocalCluster:
    if _g_cluster is None:
        raise FatalError("g_runtime_init has not been called")
    return _g_cluster


def g_runtime_fina() -> None:
    global _g_cluster
    _g_cluster = None
