"""LCI-X runtime — runtime objects, devices, the fabric, and progress.

Mirrors the paper's runtime lifecycle (§3.2.2): no global init/fina;
instead runtime objects are allocated/freed, and multiple runtimes can
coexist (library composition).  :class:`LocalCluster` simulates the paper's
*thread mode* faithfully: all ranks live in one address space (exactly like
threads of one process), each with its own :class:`Runtime` holding
replicable resources (devices, matching engine, packet pool, CQs).

The :class:`Fabric` stands in for the NIC/ICI: per (src-device, dst-device)
bounded FIFO queues.  A full queue surfaces ``retry`` — the same
back-pressure path a full ibv send queue triggers in the paper — and the
progress engine moves such requests through the backlog queue (paper §4.4
steps (2)/(3)).

Progress (§3.2.6) is explicit: nothing moves unless someone calls
``runtime.progress(device)``; the call implements the paper's Figure-1
reaction chain: drain backlog -> poll completions (source side) -> poll
incoming (target side) -> react (match, signal, rendezvous, replenish).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .backlog import BacklogQueue
from .channels import Device
from .completion import (CompletionHandler, CompletionObject, CompletionQueue,
                         MPMCArray, Synchronizer)
from .graph import CompletionGraph
from .matching import HostMatchingEngine, MatchKind, MatchingPolicy, make_key
from .modes import CommConfig, CommMode
from .off import off
from .packet_pool import HostPacketPool
from .post import CommKind, Direction, payload_nbytes
from .protocol import Protocol, ProtocolStats, select_protocol
from .status import (ErrorCode, FatalError, Status, done, posted, retry)


# ---------------------------------------------------------------------------
# wire messages
# ---------------------------------------------------------------------------

class WireKind:
    EAGER_SEND = "eager_send"      # send-recv eager payload
    EAGER_AM = "eager_am"          # active-message eager payload
    RTS = "rts"                    # rendezvous request-to-send
    CTS = "cts"                    # rendezvous clear-to-send
    RDMA_PAYLOAD = "rdma_payload"  # rendezvous data movement (zero-copy)
    PUT = "put"                    # RMA put (optionally with signal)
    GET_REQ = "get_req"            # RMA get request
    GET_RESP = "get_resp"          # RMA get response


@dataclasses.dataclass
class WireMsg:
    kind: str
    src: int
    dst: int
    tag: int = 0
    payload: Any = None
    size: int = 0
    rcomp: Optional[int] = None
    matching_policy: MatchingPolicy = MatchingPolicy.RANK_TAG
    # rendezvous bookkeeping
    op_id: int = -1                # source-side pending-op id
    remote_buf: Any = None         # (region_id, offset) for RMA
    device_index: int = 0          # which device stream this rides


@dataclasses.dataclass
class PendingOp:
    """Source-side state for a posted (not yet complete) operation."""
    kind: CommKind
    buf: Any
    size: int
    tag: int
    peer: int
    local_comp: Optional[CompletionObject]
    packet: int = -1               # bufcopy: packet id to return to the pool
    lane: int = 0
    user_context: Any = None


# ---------------------------------------------------------------------------
# fabric — the simulated interconnect
# ---------------------------------------------------------------------------

class Fabric:
    """Bounded per-(dst, device) FIFO queues; the NIC send-queue stand-in.

    ``depth`` bounds each queue — a full queue is the paper's "underlying
    network send queue is full" event and surfaces ``retry``.
    """

    def __init__(self, n_ranks: int, depth: int = 4096):
        self.n_ranks = n_ranks
        self.depth = depth
        self._queues: Dict[Tuple[int, int], collections.deque] = {}
        self.pushes = 0
        self.full_events = 0

    def _q(self, dst: int, device_index: int) -> collections.deque:
        return self._queues.setdefault((dst, device_index),
                                       collections.deque())

    def try_push(self, msg: WireMsg) -> bool:
        q = self._q(msg.dst, msg.device_index)
        if len(q) >= self.depth:
            self.full_events += 1
            return False
        q.append(msg)
        self.pushes += 1
        return True

    def drain(self, dst: int, device_index: int, limit: int = 0
              ) -> List[WireMsg]:
        q = self._q(dst, device_index)
        n = len(q) if limit <= 0 else min(limit, len(q))
        return [q.popleft() for _ in range(n)]

    def pending_to(self, dst: int) -> int:
        return sum(len(q) for (d, _), q in self._queues.items() if d == dst)


# ---------------------------------------------------------------------------
# memory registration (paper §3.3.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemoryRegion:
    """Registered memory: mandatory for remote buffers (RMA targets)."""
    rid: int
    buf: np.ndarray                # 1-D uint8 view of the registered range


def _as_bytes_view(buf: Any) -> np.ndarray:
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8)
    if isinstance(buf, (bytearray, memoryview)):
        return np.frombuffer(buf, dtype=np.uint8)
    raise FatalError(f"cannot register memory of type {type(buf)}")


def _payload_to_bytes(buf: Any) -> np.ndarray:
    """Materialize a payload (or buffer list, §3.3.1) as bytes."""
    if isinstance(buf, (list, tuple)):
        parts = [_payload_to_bytes(b) for b in buf]
        return (np.concatenate(parts) if parts
                else np.zeros(0, np.uint8))
    if isinstance(buf, np.ndarray):
        return buf.reshape(-1).view(np.uint8).copy()
    if isinstance(buf, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(buf), dtype=np.uint8)
    raise FatalError(f"unsupported payload type {type(buf)}")


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

_op_ids = itertools.count()


class Runtime:
    """One rank's LCI runtime: resources + posting + progress."""

    def __init__(self, rank: int, cluster: "LocalCluster",
                 config: Optional[CommConfig] = None):
        self.rank = rank
        self.cluster = cluster
        self.config = config or cluster.config
        # resources (all replicable; these are the process-default set)
        self.matching = HostMatchingEngine(self.config.matching_buckets)
        self.packet_pool = HostPacketPool(
            n_lanes=max(1, self.config.n_channels),
            packets_per_lane=self.config.packets_per_lane,
            packet_bytes=self.config.packet_bytes)
        self.rcomp_registry = MPMCArray()      # paper §4.1.1 MPMC array
        self.memory_regions = MPMCArray()
        self.devices: List[Device] = []
        self.default_device = self.alloc_device(lane=0)
        self.stats = ProtocolStats()
        self._pending: Dict[int, PendingOp] = {}
        self._landing: list = []     # rendezvous landing zones (CTS state)

    # -- rank queries -------------------------------------------------------
    def get_rank_me(self) -> int:
        return self.rank

    def get_rank_n(self) -> int:
        return self.cluster.n_ranks

    # -- resource allocation -------------------------------------------------
    def alloc_device(self, lane: Optional[int] = None) -> Device:
        dev = Device(self.config,
                     lane=(lane if lane is not None
                           else len(self.devices) % self.packet_pool.n_lanes))
        dev.index = len(self.devices)
        self.devices.append(dev)
        return dev

    def free_device(self, device: Device) -> None:
        if device is self.default_device:
            raise FatalError("cannot free the default device")
        self.devices.remove(device)

    def alloc_cq(self, capacity: Optional[int] = None) -> CompletionQueue:
        return CompletionQueue(capacity)

    def alloc_handler(self, fn: Callable[[Status], None]) -> CompletionHandler:
        return CompletionHandler(fn)

    def alloc_sync(self, expected: int = 1) -> Synchronizer:
        return Synchronizer(expected)

    def alloc_graph(self, name: str = "graph") -> CompletionGraph:
        return CompletionGraph(name)

    def free_comp(self, comp: CompletionObject) -> None:
        pass                                    # GC does the freeing

    def register_rcomp(self, comp: CompletionObject) -> int:
        """Register a completion object for *remote* signaling; returns the
        remote completion handle other ranks pass to post_am/put-signal."""
        return self.rcomp_registry.append(comp)

    def register_memory(self, buf: Any) -> MemoryRegion:
        view = _as_bytes_view(buf)
        region = MemoryRegion(rid=len(self.memory_regions), buf=view)
        self.memory_regions.append(region)
        return region

    # -- posting (called via post.post_comm) ---------------------------------
    def _post(self, *, kind: CommKind, rank: int, buf: Any, tag: int,
              size: int, local_comp, remote_buf, remote_comp, device,
              matching_policy: MatchingPolicy, allow_retry: bool,
              user_context: Any) -> Status:
        dev: Device = device or self.default_device
        dev.posts += 1
        if rank < 0 or rank >= self.cluster.n_ranks:
            raise FatalError(f"bad target rank {rank}")

        if kind == CommKind.RECV:
            return self._post_recv(rank, buf, tag, size, local_comp, dev,
                                   matching_policy)
        if kind == CommKind.GET:
            return self._post_get(rank, buf, tag, size, local_comp,
                                  remote_buf, dev, allow_retry)

        proto = (Protocol.ZEROCOPY if kind in
                 (CommKind.PUT, CommKind.PUT_SIGNAL)
                 else select_protocol(size, self.config))
        if kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
            return self._post_put(kind, rank, buf, tag, size, local_comp,
                                  remote_buf, remote_comp, dev, allow_retry)

        # SEND / AM with inject | bufcopy | zerocopy
        if proto == Protocol.ZEROCOPY:
            op_id = next(_op_ids)
            self._pending[op_id] = PendingOp(kind, buf, size, tag, rank,
                                             local_comp, lane=dev.lane,
                                             user_context=user_context)
            msg = WireMsg(WireKind.RTS, self.rank, rank, tag=tag, size=size,
                          rcomp=remote_comp, matching_policy=matching_policy,
                          op_id=op_id, device_index=dev.index)
            self.stats.handshakes += 1
            st = self._submit(msg, dev, allow_retry)
            if st.is_retry():
                del self._pending[op_id]
            else:
                self.stats.record(proto, size)
            return st

        packet = -1
        if proto == Protocol.BUFCOPY:
            packet, pst = self.packet_pool.get(dev.lane)
            if pst.is_retry():
                self.stats.retries += 1
                if allow_retry:
                    return pst
                # user disallowed retry: park in the backlog (paper §4.4)
                dev.backlog.push(("post", kind, rank, buf, tag, size,
                                  local_comp, remote_comp, matching_policy,
                                  user_context))
                return posted(code=ErrorCode.POSTED_BACKLOG)
            # stage payload into the packet (buffer-copy)
            data = _payload_to_bytes(buf)
            if data.nbytes > self.packet_pool.packet_bytes:
                self.packet_pool.put(dev.lane, packet)
                raise FatalError("bufcopy payload exceeds packet size")

        wire_kind = (WireKind.EAGER_AM if kind == CommKind.AM
                     else WireKind.EAGER_SEND)
        op_id = -1
        if proto == Protocol.BUFCOPY:
            op_id = next(_op_ids)
            self._pending[op_id] = PendingOp(kind, buf, size, tag, rank,
                                             local_comp, packet=packet,
                                             lane=dev.lane,
                                             user_context=user_context)
        msg = WireMsg(wire_kind, self.rank, rank, tag=tag,
                      payload=_payload_to_bytes(buf), size=size,
                      rcomp=remote_comp, matching_policy=matching_policy,
                      op_id=op_id, device_index=dev.index)
        st = self._submit(msg, dev, allow_retry)
        if st.is_retry():
            if packet >= 0:
                self.packet_pool.put(dev.lane, packet)
                del self._pending[op_id]
            return st
        self.stats.record(proto, size)
        if proto == Protocol.INJECT:
            if st.code == ErrorCode.POSTED_BACKLOG:
                # the wire push was deferred; the payload is already copied
                # so the source buffer is reusable, but the op has not hit
                # the network — report the backlog, not done.  Inject ops
                # never signal completion objects (paper §3.2.5).
                return st
            # inject completes immediately; comps are NOT signaled (paper)
            return done(code=ErrorCode.DONE_INLINE, rank=rank, tag=tag)
        return posted(ctx=op_id)

    def _submit(self, msg: WireMsg, dev: Device, allow_retry: bool) -> Status:
        """Push to the fabric; full queue -> retry or backlog."""
        if self.cluster.fabric.try_push(msg):
            # source completion for bufcopy/zerocopy is deferred to progress
            if msg.op_id >= 0:
                dev.pending_tx.append(msg.op_id)
            return posted()
        self.stats.retries += 1
        if allow_retry:
            return retry(ErrorCode.RETRY_LOCKED)
        st = dev.backlog.push(("wire", msg))
        if st.is_retry():
            return st
        if msg.op_id >= 0:
            dev.pending_tx.append(msg.op_id)
        return posted(code=ErrorCode.POSTED_BACKLOG)

    def _post_recv(self, rank: int, buf: Any, tag: int, size: int,
                   local_comp, dev: Device,
                   policy: MatchingPolicy) -> Status:
        key = make_key(rank, tag, policy)
        match = self.matching.insert(key, MatchKind.RECV,
                                     ("recv", buf, local_comp, dev))
        if match is None:
            return posted(code=ErrorCode.POSTED_UNMATCHED)
        mkind, *rest = match
        if mkind == "eager":
            payload, src, mtag = rest
            if buf is not None:               # fill the posted buffer too
                view = _as_bytes_view(buf)
                n = min(view.nbytes, payload.nbytes)
                view[:n] = payload[:n]
            # done => completion objects will NOT be signaled (paper §3.2.5)
            return done(payload, rank=src, tag=mtag)
        if mkind == "rts":
            msg = rest[0]
            self._reply_cts(msg, buf, local_comp, dev)
            return posted()
        raise FatalError(f"unexpected match kind {mkind}")

    def _post_put(self, kind: CommKind, rank: int, buf: Any, tag: int,
                  size: int, local_comp, remote_buf, remote_comp,
                  dev: Device, allow_retry: bool) -> Status:
        op_id = next(_op_ids)
        self._pending[op_id] = PendingOp(kind, buf, size, tag, rank,
                                         local_comp, lane=dev.lane)
        msg = WireMsg(WireKind.PUT, self.rank, rank, tag=tag,
                      payload=_payload_to_bytes(buf), size=size,
                      rcomp=remote_comp, remote_buf=remote_buf,
                      op_id=op_id, device_index=dev.index)
        st = self._submit(msg, dev, allow_retry)
        if st.is_retry():
            del self._pending[op_id]
            return st
        self.stats.record(Protocol.ZEROCOPY, size)
        return posted(ctx=op_id)

    def _post_get(self, rank: int, buf: Any, tag: int, size: int,
                  local_comp, remote_buf, dev: Device,
                  allow_retry: bool) -> Status:
        op_id = next(_op_ids)
        self._pending[op_id] = PendingOp(CommKind.GET, buf, size, tag, rank,
                                         local_comp, lane=dev.lane)
        msg = WireMsg(WireKind.GET_REQ, self.rank, rank, tag=tag, size=size,
                      remote_buf=remote_buf, op_id=op_id,
                      device_index=dev.index)
        st = self._submit(msg, dev, allow_retry)
        if st.is_retry():
            del self._pending[op_id]
            return st
        self.stats.record(Protocol.ZEROCOPY, size)
        return posted(ctx=op_id)

    def _reply_cts(self, rts: WireMsg, recv_buf: Any, recv_comp, dev: Device
                   ) -> None:
        cts = WireMsg(WireKind.CTS, self.rank, rts.src, tag=rts.tag,
                      op_id=rts.op_id, device_index=rts.device_index)
        cts.payload = (len(self._rendezvous_landing),)
        self._rendezvous_landing.append((recv_buf, recv_comp, dev))
        self.stats.handshakes += 1
        if not self.cluster.fabric.try_push(cts):
            dev.backlog.push(("wire", cts))

    # -- progress (§3.2.6, Figure 1) -----------------------------------------
    def progress(self, device: Optional[Device] = None,
                 max_msgs: int = 0) -> bool:
        """Drive one progress pass on ``device``; returns True if any work
        was done (paper: do_background_work)."""
        dev: Device = device or self.default_device
        dev.progresses += 1
        did = False

        # (3) retry backlogged requests first
        while not dev.backlog.empty_flag:
            item, st = dev.backlog.pop()
            if st.is_retry():
                break
            tag0 = item[0]
            if tag0 == "wire":
                msg = item[1]
                if not self.cluster.fabric.try_push(msg):
                    dev.backlog.push(item)      # still full; stop retrying
                    break
                if msg.op_id >= 0:
                    dev.pending_tx.append(msg.op_id)
                did = True
            elif tag0 == "post":
                (_, kind, rank, buf, tag, size, local_comp, remote_comp,
                 policy, uctx) = item
                st2 = self._post(kind=kind, rank=rank, buf=buf, tag=tag,
                                 size=size, local_comp=local_comp,
                                 remote_buf=None, remote_comp=remote_comp,
                                 device=dev, matching_policy=policy,
                                 allow_retry=True, user_context=uctx)
                if st2.is_retry():
                    dev.backlog.push(item)
                    break
                did = True

        # source-side completions (bufcopy send done on the wire)
        while dev.pending_tx:
            op_id = dev.pending_tx.popleft()
            op = self._pending.get(op_id)
            if op is None:
                continue
            if op.kind in (CommKind.SEND, CommKind.AM):
                if op.packet >= 0:              # return packet to the pool
                    self.packet_pool.put(op.lane, op.packet)
                    self._signal(op.local_comp,
                                 done(rank=op.peer, tag=op.tag))
                    del self._pending[op_id]
                # zerocopy sends complete on CTS+RDMA, not here
            elif op.kind in (CommKind.PUT, CommKind.PUT_SIGNAL):
                self._signal(op.local_comp, done(rank=op.peer, tag=op.tag))
                del self._pending[op_id]
            did = True

        # (4) poll incoming for this device stream and react
        for msg in self.cluster.fabric.drain(self.rank, dev.index, max_msgs):
            self._react(msg, dev)
            did = True
        return did

    def _react(self, msg: WireMsg, dev: Device) -> None:
        k = msg.kind
        if k == WireKind.EAGER_AM:
            comp = self.rcomp_registry[msg.rcomp]
            st = done(msg.payload, rank=msg.src, tag=msg.tag)
            result = comp.signal(st)
            if isinstance(result, Status) and result.is_retry():
                dev.backlog.push(("wire", msg))  # CQ full: repost locally
        elif k == WireKind.EAGER_SEND:
            key = make_key(msg.src, msg.tag, msg.matching_policy)
            match = self.matching.insert(
                key, MatchKind.SEND, ("eager", msg.payload, msg.src, msg.tag))
            if match is not None:
                _, buf, comp, rdev = match
                self._deliver_recv(buf, msg.payload, comp, msg.src, msg.tag)
        elif k == WireKind.RTS:
            key = make_key(msg.src, msg.tag, msg.matching_policy)
            if msg.rcomp is not None:           # zero-copy active message
                # allocate a landing buffer and CTS straight away
                landing = np.zeros(msg.size, np.uint8)
                comp = self.rcomp_registry[msg.rcomp]
                self._reply_cts(msg, landing, comp, dev)
                return
            match = self.matching.insert(key, MatchKind.SEND, ("rts", msg))
            if match is not None:
                _, buf, comp, rdev = match
                self._reply_cts(msg, buf, comp, dev)
        elif k == WireKind.CTS:
            op = self._pending.pop(msg.op_id, None)
            if op is None:
                raise FatalError("CTS for unknown op")
            landing_idx = msg.payload[0]
            data = _payload_to_bytes(op.buf)
            rdma = WireMsg(WireKind.RDMA_PAYLOAD, self.rank, msg.src,
                           tag=op.tag, payload=data, size=op.size,
                           op_id=landing_idx, device_index=msg.device_index)
            if not self.cluster.fabric.try_push(rdma):
                dev.backlog.push(("wire", rdma))
            self._signal(op.local_comp, done(rank=op.peer, tag=op.tag))
        elif k == WireKind.RDMA_PAYLOAD:
            buf, comp, rdev = self._rendezvous_landing[msg.op_id]
            self._deliver_recv(buf, msg.payload, comp, msg.src, msg.tag)
        elif k == WireKind.PUT:
            region_id, offset = msg.remote_buf
            region: MemoryRegion = self.memory_regions[region_id]
            region.buf[offset:offset + msg.size] = msg.payload[:msg.size]
            if msg.rcomp is not None:           # put with signal
                comp = self.rcomp_registry[msg.rcomp]
                comp.signal(done(msg.payload, rank=msg.src, tag=msg.tag))
        elif k == WireKind.GET_REQ:
            region_id, offset = msg.remote_buf
            region = self.memory_regions[region_id]
            data = region.buf[offset:offset + msg.size].copy()
            resp = WireMsg(WireKind.GET_RESP, self.rank, msg.src,
                           tag=msg.tag, payload=data, size=msg.size,
                           op_id=msg.op_id, device_index=msg.device_index)
            if not self.cluster.fabric.try_push(resp):
                dev.backlog.push(("wire", resp))
        elif k == WireKind.GET_RESP:
            op = self._pending.pop(msg.op_id, None)
            if op is None:
                raise FatalError("GET_RESP for unknown op")
            view = _as_bytes_view(op.buf)
            view[:msg.size] = msg.payload[:msg.size]
            self._signal(op.local_comp, done(msg.payload, rank=op.peer,
                                             tag=op.tag))
        else:
            raise FatalError(f"unknown wire kind {k}")

    def _deliver_recv(self, buf: Any, payload: np.ndarray, comp,
                      src: int, tag: int) -> None:
        if buf is not None:
            view = _as_bytes_view(buf)
            n = min(view.nbytes, payload.nbytes)
            view[:n] = payload[:n]
        self._signal(comp, done(payload, rank=src, tag=tag))

    @staticmethod
    def _signal(comp: Optional[CompletionObject], st: Status) -> None:
        if comp is not None:
            comp.signal(st)

    # rendezvous landing zones (CTS handshake state)
    @property
    def _rendezvous_landing(self) -> list:
        return self._landing


# -- module-level progress with the paper's OFF spelling --------------------
#    lci::progress_x().device(device)()

@off
def progress(runtime: Runtime, device: Optional[Device] = None,
             max_msgs: int = 0) -> bool:
    return runtime.progress(device=device, max_msgs=max_msgs)


progress_x = progress.x


# ---------------------------------------------------------------------------
# cluster
# ---------------------------------------------------------------------------

class LocalCluster:
    """All ranks in one address space — the paper's thread-mode testbed."""

    def __init__(self, n_ranks: int, config: Optional[CommConfig] = None,
                 fabric_depth: int = 4096):
        self.n_ranks = n_ranks
        self.config = config or CommConfig()
        self.fabric = Fabric(n_ranks, depth=fabric_depth)
        self.runtimes = [Runtime(r, self) for r in range(n_ranks)]

    def __getitem__(self, rank: int) -> Runtime:
        return self.runtimes[rank]

    def progress_all(self, rounds: int = 1) -> int:
        """Drive every device of every rank; returns #work events."""
        n = 0
        for _ in range(rounds):
            for rt in self.runtimes:
                for dev in rt.devices:
                    n += bool(rt.progress(dev))
        return n

    def quiesce(self, max_rounds: int = 10_000) -> None:
        """Progress until no work remains (test/benchmark helper)."""
        for _ in range(max_rounds):
            if not self.progress_all():
                return
        raise FatalError("cluster failed to quiesce")


# -- module-level convenience (paper's g_runtime) ---------------------------

_g_cluster: Optional[LocalCluster] = None


def g_runtime_init(n_ranks: int = 1,
                   config: Optional[CommConfig] = None) -> LocalCluster:
    global _g_cluster
    _g_cluster = LocalCluster(n_ranks, config)
    return _g_cluster


def g_runtime() -> LocalCluster:
    if _g_cluster is None:
        raise FatalError("g_runtime_init has not been called")
    return _g_cluster


def g_runtime_fina() -> None:
    global _g_cluster
    _g_cluster = None
