"""LCI-X runtime — resource lifecycle: runtimes, devices, endpoints, clusters.

Mirrors the paper's runtime lifecycle (§3.2.2): no global init/fina;
instead runtime objects are allocated/freed, and multiple runtimes can
coexist (library composition).  :class:`LocalCluster` simulates the paper's
*thread mode* faithfully: all ranks live in one address space (exactly like
threads of one process), each with its own :class:`Runtime` holding
replicable resources (devices, matching engine, packet pool, CQs).

Everything that *moves data* lives in :mod:`repro.core.progress`:

* the fabric and wire format          -> ``progress/fabric.py``
* posting + the Figure-1 chain        -> ``progress/engine.py``
* rendezvous (RTS/CTS/RDMA) and RMA   -> ``progress/rendezvous.py``
* multi-device striped endpoints      -> ``progress/endpoint.py``

This module only allocates, wires together, and frees those resources —
plus the thin delegation (``Runtime._post`` / ``Runtime.progress``) that
keeps the paper's Listing-2 call surface on the runtime object.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Mapping, Optional

from . import attrs as _attrs
from .channels import DEVICE_ATTRS, Device
from .completion import (CompletionHandler, CompletionObject, CompletionQueue,
                         MPMCArray, Synchronizer)
from .concurrency import ProgressWorkerPool, ThreadSafeCompletionQueue
from .graph import CompletionGraph
from .matching import HostMatchingEngine
from .modes import _FIELD_TO_ATTR, CommConfig
from .off import off
from .packet_pool import POOL_ATTRS, HostPacketPool
from .protocol import ProtocolStats
from .status import ErrorCode, FatalError, Status, err
from .telemetry import Telemetry, merge_snapshots

#: runtime-level attrs one Runtime resolves at construction
RUNTIME_ATTRS = ("mode", "n_channels", "eager_max_bytes", "rdv_threshold",
                 "wire_bf16", "doorbell_fused", "fused_min_burst",
                 "matching_buckets", "matching_locks",
                 "packets_per_lane", "packet_bytes", "pool_lanes",
                 "telemetry_level", "trace_capacity")
# Re-exported names that historically lived here (public API compatibility).
from .progress import (ENDPOINT_ATTRS, RELIABILITY_ATTRS, Endpoint,
                       EndpointSpec, Fabric, MemoryRegion,
                       PendingOp, ProgressEngine, ReliabilityManager,
                       RendezvousManager,
                       WireKind, WireMsg, as_bytes_view, payload_to_bytes)
from .transport import (CHAOS_ATTRS, FABRIC_ATTRS, ChaosTransport, Transport,
                        make_transport, maybe_wrap_chaos)

# back-compat aliases for the old private helpers
_as_bytes_view = as_bytes_view
_payload_to_bytes = payload_to_bytes


def _resolve_worker_args(layer: Mapping, n_workers: Optional[int],
                         burst: Optional[int]) -> tuple:
    """Resolve alloc_workers knobs through the chain; attr ``n_workers``
    0 means "auto" = the historical pool default of 2."""
    explicit = {k: v for k, v in (("n_workers", n_workers),
                                  ("worker_burst", burst)) if v is not None}
    r = _attrs.resolve(("n_workers", "worker_burst"), runtime=layer,
                       overrides=explicit)
    return r["n_workers"] or 2, r["worker_burst"]


class Runtime(_attrs.AttrResource):
    """One rank's LCI runtime: the replicable resource set.

    Posting and progress are delegated to the default
    :class:`~repro.core.progress.ProgressEngine`; dedicated engines (and
    multi-device striping) are allocated through :meth:`alloc_endpoint`.

    Every ``alloc_*`` resolves its knobs through the four-layer attribute
    chain (DESIGN.md §12): library defaults → ``REPRO_ATTR_*`` env →
    this runtime's config layer (``LocalCluster(attrs=...)`` merged with
    explicit ``CommConfig`` fields) → per-call named-argument overrides.
    """

    def __init__(self, rank: int, cluster: "LocalCluster",
                 config: Optional[CommConfig] = None):
        self.rank = rank
        self.cluster = cluster
        # the runtime-level layer feeding every per-resource resolution
        if config is None:
            self._attr_layer: Dict[str, Any] = dict(cluster._attr_layer)
            self.config = cluster.config
        else:
            # a per-rank config's explicit fields override the cluster
            # layer — and the effective config must be rebuilt from the
            # merge, so the data path (select_protocol reads
            # config.inject_max_bytes) agrees with introspection
            self._attr_layer = {**cluster._attr_layer,
                                **config.explicit_attrs()}
            self.config = CommConfig(**{
                f: self._attr_layer[a] for f, a in _FIELD_TO_ATTR.items()
                if a in self._attr_layer})
        resolved = _attrs.resolve(RUNTIME_ATTRS, runtime=self._attr_layer)
        self._init_attrs(resolved)
        # data-plane flags cached as plain fields: the fused doorbell path
        # (DESIGN.md §13) reads them per burst, so no attr-chain lookup on
        # the hot path
        self.doorbell_fused: bool = resolved["doorbell_fused"]
        self.fused_min_burst: int = resolved["fused_min_burst"]
        self.wire_bf16: bool = resolved["wire_bf16"]
        # observability hub (DESIGN.md §15): share the cluster's telemetry
        # unless this rank's resolved level differs (per-rank override)
        ctele = getattr(cluster, "tele", None)
        if ctele is not None and ctele.level == resolved["telemetry_level"]:
            self.tele = ctele
        else:
            self.tele = Telemetry(resolved["telemetry_level"],
                                  resolved["trace_capacity"])
        # resources (all replicable; these are the process-default set)
        self.matching = HostMatchingEngine(
            resolved["matching_buckets"], resolved["matching_locks"],
            resolved=resolved.subset(("matching_buckets",
                                      "matching_locks")),
            tele=self.tele)
        self.packet_pool = HostPacketPool(
            n_lanes=resolved["pool_lanes"] or max(1, resolved["n_channels"]),
            packets_per_lane=resolved["packets_per_lane"],
            packet_bytes=resolved["packet_bytes"],
            resolved=resolved.subset(POOL_ATTRS),
            tele=self.tele)
        self.rcomp_registry = MPMCArray()      # paper §4.1.1 MPMC array
        self.memory_regions = MPMCArray()
        self.devices: List[Device] = []
        self._next_device_index = 0
        self.stats = ProtocolStats()
        # shared per-rank op state the engines operate on
        self.pending_ops: Dict[int, PendingOp] = {}
        self.rdv = RendezvousManager(self)
        # reliability plane (DESIGN.md §16): armed explicitly via the
        # ``reliability`` attr, or automatically when the cluster fabric
        # is a message-faulting chaos transport — the zero-fault default
        # stays rel-free and byte-identical to the pre-chaos engine
        self.dead_peers: set = set()
        relr = _attrs.resolve(RELIABILITY_ATTRS, runtime=self._attr_layer)
        fabric = cluster.fabric
        chaos_faults = (isinstance(fabric, ChaosTransport)
                        and fabric.cfg.faults_messages)
        mode = relr["reliability"]
        self.rel = (ReliabilityManager(self, relr)
                    if mode == "on" or (mode == "auto" and chaos_faults)
                    else None)
        self.engine = ProgressEngine(self, name=f"rank{rank}/shared")
        self.endpoints: List[Endpoint] = []
        self.default_device = self.alloc_device(lane=0)
        # fold this rank's long-standing counters into the unified
        # telemetry snapshot (DESIGN.md §15: the registry is the one
        # read surface; the legacy accessors keep their storage)
        self.tele.attach("protocol", self._protocol_counters)
        self.tele.attach("device", self._device_counters)
        self.tele.attach("engine", lambda: {
            "passes": self.engine.passes,
            "reactions": self.engine.reactions,
            "burst_posts": self.engine.burst_posts})
        self.tele.attach("pool", self.packet_pool.telemetry_counters)
        self.tele.attach("matching", self.matching.telemetry_counters)
        if self.rel is not None:
            self.tele.attach("reliability", self.rel.counters)
        # read-only discovered attributes (LCI get_attr_* mirror)
        self._export_attr("rank_me", lambda: self.rank)
        self._export_attr("rank_n", lambda: self.cluster.n_ranks)
        self._export_attr("n_devices", lambda: len(self.devices))
        self._export_attr("n_endpoints", lambda: len(self.endpoints))
        self._export_attr("free_packets", self.packet_pool.free_packets)
        self._export_attr("telemetry", self.tele.snapshot)

    def _protocol_counters(self) -> Dict[str, int]:
        import dataclasses as _dc
        return _dc.asdict(self.stats)

    def _device_counters(self) -> Dict[str, int]:
        out = {"posts": 0, "pushes": 0, "progresses": 0,
               "lock_acquisitions": 0, "lock_contentions": 0}
        for dev in self.devices:
            out["posts"] += dev.posts
            out["pushes"] += dev.pushes
            out["progresses"] += dev.progresses
            out["lock_acquisitions"] += dev.progress_lock.acquisitions
            out["lock_contentions"] += dev.progress_lock.contentions
        return out

    # -- rank death (DESIGN.md §16) ------------------------------------------
    def mark_peer_dead(self, rank: int) -> None:
        """Declare ``rank`` dead: future posts toward it fail at post
        time with ``err(ERR_PEER_DEAD)``, queued recvs naming it are
        withdrawn and err-signaled, and the reliability layer (when
        armed) fails its unacked window on the next sweep.  Idempotent;
        typically driven by the spmd heartbeat watchdog."""
        if rank == self.rank:
            raise FatalError("a rank cannot declare itself dead")
        if not 0 <= rank < self.n_ranks:
            raise FatalError(f"bad rank {rank}")
        if rank in self.dead_peers:
            return
        self.dead_peers.add(rank)
        for value in self.matching.extract_recvs_for_rank(rank):
            _, buf, comp, rdev = value
            self.engine.signal(
                comp, err(ErrorCode.ERR_PEER_DEAD, rank=rank), rdev)
        if self.rel is not None:
            self.rel.kill_peer(rank)

    # -- rank / fabric queries ----------------------------------------------
    def get_rank_me(self) -> int:
        return self.rank

    def get_rank_n(self) -> int:
        return self.cluster.n_ranks

    @property
    def n_ranks(self) -> int:
        return self.cluster.n_ranks

    @property
    def fabric(self) -> Fabric:
        return self.cluster.fabric

    # -- resource allocation -------------------------------------------------
    def alloc_device(self, lane: Optional[int] = None,
                     **overrides) -> Device:
        """Allocate one device; ``**overrides`` are per-resource attribute
        overrides (``n_channels``, ``backlog_capacity``, ``cq_capacity``)
        validated against the registry at alloc time."""
        resolved = _attrs.resolve(DEVICE_ATTRS, runtime=self._attr_layer,
                                  overrides=overrides)
        dev = Device(self.config,
                     lane=(lane if lane is not None
                           else len(self.devices) % self.packet_pool.n_lanes),
                     resolved=resolved, tele=self.tele)
        # indices are never reused: a fabric stream keyed by a freed
        # device's index must not silently alias a later allocation
        dev.index = self._next_device_index
        self._next_device_index += 1
        self.devices.append(dev)
        return dev

    def _check_device_freeable(self, device: Device) -> None:
        if device is self.default_device:
            raise FatalError("cannot free the default device")
        if not device.backlog.empty_flag or device.pending_tx:
            raise FatalError("cannot free a device with backlogged or "
                             "in-flight operations")
        if device.index in self.fabric.pending_streams(self.rank):
            raise FatalError("cannot free a device with undrained incoming "
                             "traffic (progress it first)")

    def free_device(self, device: Device) -> None:
        self._check_device_freeable(device)
        self.devices.remove(device)

    def alloc_endpoint(self, n_devices: Optional[int] = None,
                       stripe: Optional[str] = None,
                       progress: Optional[str] = None,
                       name: Optional[str] = None, *,
                       spec: Optional[EndpointSpec] = None,
                       n_workers: Optional[int] = None,
                       worker_burst: Optional[int] = None,
                       size_boundaries=None) -> Endpoint:
        """Allocate a named multi-device endpoint (paper §3.2.3: devices
        are replicable and incrementally tunable).  Pass the knobs (each
        ``None`` resolves through the attribute chain) or a prebuilt
        :class:`EndpointSpec` (already resolved at its construction)."""
        if spec is None:
            explicit = {k: v for k, v in
                        (("n_devices", n_devices), ("stripe", stripe),
                         ("progress", progress), ("n_workers", n_workers),
                         ("worker_burst", worker_burst))
                        if v is not None}
            spec, resolved = self._materialize_spec(
                name or f"rank{self.rank}/ep{len(self.endpoints)}",
                explicit, size_boundaries)
        else:
            # a prebuilt spec pins only the fields its caller set
            # explicitly ("resource" source); everything it left to
            # defaults stays tunable through this runtime's attrs layer
            explicit = {a: spec._resolved_attrs[a] for a in ENDPOINT_ATTRS
                        if spec._resolved_attrs.source(a) == "resource"}
            spec, resolved = self._materialize_spec(
                spec.name, explicit, spec.size_boundaries)
        ep = Endpoint(self, spec, resolved=resolved)
        self.endpoints.append(ep)
        return ep

    def _materialize_spec(self, name: str, explicit: Dict[str, Any],
                          size_boundaries) -> tuple:
        """Resolve endpoint attrs through the full chain and build the
        concrete spec.  An ambient (env/runtime-layer) n_workers only
        applies to workers-mode endpoints — it is zeroed elsewhere, and
        the stored resolution is kept in sync so introspection reports
        what the endpoint actually runs with; an explicit n_workers on a
        non-worker endpoint still errors in EndpointSpec."""
        resolved = _attrs.resolve(ENDPOINT_ATTRS, runtime=self._attr_layer,
                                  overrides=explicit)
        vals = {a: resolved[a] for a in ENDPOINT_ATTRS}
        if vals["progress"] != "workers" and "n_workers" not in explicit:
            vals["n_workers"] = 0
            resolved = resolved.merged(_attrs.ResolvedAttrs(
                {"n_workers": 0},
                {"n_workers": resolved.source("n_workers")}))
        spec = EndpointSpec(name=name, size_boundaries=size_boundaries,
                            **vals)
        return spec, resolved

    def free_endpoint(self, ep: Endpoint) -> None:
        # a live worker pool must be quiesced before its devices go away
        ep.stop_workers()
        # validate every device BEFORE mutating: a busy device must not
        # leave the endpoint half-freed
        for dev in ep.devices:
            self._check_device_freeable(dev)
        for dev in ep.devices:
            self.devices.remove(dev)
        self.endpoints.remove(ep)

    def alloc_engine(self, devices: Optional[List[Device]] = None,
                     name: str = "engine") -> ProgressEngine:
        return ProgressEngine(self, devices, name=name)

    def alloc_workers(self, n_workers: Optional[int] = None, *,
                      burst: Optional[int] = None) -> ProgressWorkerPool:
        """A worker pool over this runtime's current devices, driven by
        the shared engine (paper §4.2.3 multithreaded progress).  The
        caller owns the lifecycle: ``with rt.alloc_workers(4): ...``.
        ``n_workers``/``burst`` resolve through the attribute chain
        (attrs ``n_workers`` — 0 = the pool default of 2 — and
        ``worker_burst``)."""
        n, b = _resolve_worker_args(self._attr_layer, n_workers, burst)
        return ProgressWorkerPool.for_runtime(self, n, burst=b)

    # Completion-object allocation (paper §3.2.5): every alloc_* handle
    # satisfies the unified comp protocol — signal(Status) -> Status,
    # non-blocking test(), progress-driven wait().
    def alloc_cq(self, capacity: Optional[int] = None, *,
                 threadsafe: bool = False) -> CompletionObject:
        """``threadsafe=True`` returns the LCQ-backed queue (paper §4.1.4
        FAA array) — required when worker threads signal or drain it.
        ``capacity`` resolves through the attribute chain (attr
        ``cq_capacity``; 0 = unbounded)."""
        overrides = {} if capacity is None else {"cq_capacity": capacity}
        resolved = _attrs.resolve(("cq_capacity",),
                                  runtime=self._attr_layer,
                                  overrides=overrides)
        cap = resolved["cq_capacity"] or None
        if threadsafe:
            return ThreadSafeCompletionQueue(cap, resolved=resolved,
                                             tele=self.tele)
        return CompletionQueue(cap, resolved=resolved, tele=self.tele)

    def alloc_handler(self, fn: Callable[[Status], None]) -> CompletionHandler:
        return CompletionHandler(fn)

    def alloc_sync(self, expected: int = 1) -> Synchronizer:
        return Synchronizer(expected)

    def alloc_graph(self, name: str = "graph") -> CompletionGraph:
        g = CompletionGraph(name)
        g.add_progress(self.cluster)   # default driver for wait()/execute()
        return g

    def free_comp(self, comp: CompletionObject) -> None:
        pass                                    # GC does the freeing

    def register_rcomp(self, comp: CompletionObject) -> int:
        """Register a completion object for *remote* signaling; returns the
        remote completion handle other ranks pass to post_am/put-signal."""
        return self.rcomp_registry.append(comp)

    def register_memory(self, buf: Any) -> MemoryRegion:
        view = as_bytes_view(buf)
        region = MemoryRegion(rid=len(self.memory_regions), buf=view)
        self.memory_regions.append(region)
        return region

    # -- posting / progress: thin delegation to the default engine -----------
    def _post(self, **kwargs) -> Status:
        return self.engine.post(**kwargs)

    def post_many(self, ops, *, endpoint: Optional[Endpoint] = None,
                  device: Optional[Device] = None) -> List[Status]:
        """Burst posting (paper §4.3): coalesce a sequence of ops
        (:class:`~repro.core.post.CommDesc` or unfired ``post_*_x``
        builders) into per-device doorbells — see
        :func:`repro.core.post.post_many`."""
        from .post import post_many as _post_many
        return _post_many(self, ops, endpoint=endpoint, device=device)

    def progress(self, device: Optional[Device] = None,
                 max_msgs: int = 0) -> bool:
        return self.engine.progress(device, max_msgs)

    # back-compat: rendezvous landing zones (CTS handshake state)
    @property
    def _rendezvous_landing(self) -> list:
        return self.rdv.landing

    @property
    def _pending(self) -> Dict[int, PendingOp]:
        return self.pending_ops


# -- module-level progress with the paper's OFF spelling --------------------
#    lci::progress_x().device(device)()

@off
def progress(runtime: Runtime, device: Optional[Device] = None,
             max_msgs: int = 0) -> bool:
    return runtime.progress(device=device, max_msgs=max_msgs)


progress_x = progress.x


# ---------------------------------------------------------------------------
# cluster
# ---------------------------------------------------------------------------

class LocalCluster(_attrs.AttrResource):
    """All ranks in one address space — the paper's thread-mode testbed.

    ``link_latency`` (seconds) makes the simulated wire take time: pushed
    messages become drainable only after the latency elapses.  Zero (the
    default) keeps the instant fabric; the multithreaded benchmarks use a
    real latency so completion windows model flow control.

    ``attrs`` is the **runtime-level config layer** of the attribute chain
    (DESIGN.md §12): a mapping of attribute names to values that every
    rank's ``alloc_*`` resolves beneath per-call overrides but above
    ``REPRO_ATTR_*`` env and library defaults.  Explicit ``CommConfig``
    fields join the same layer (the ``attrs`` mapping wins on conflict);
    ``fabric_depth``/``link_latency`` constructor args are the cluster's
    own per-resource overrides for its fabric.
    """

    def __init__(self, n_ranks: int, config: Optional[CommConfig] = None,
                 fabric_depth: Optional[int] = None,
                 link_latency: Optional[float] = None,
                 attrs: Optional[Mapping[str, Any]] = None,
                 fabric_backend: Optional[str] = None):
        self.n_ranks = n_ranks
        config = config or CommConfig()
        # the runtime-level layer: explicit config fields, then the attrs
        # mapping (validated against the registry — unknown names raise)
        self._attr_layer: Dict[str, Any] = {**config.explicit_attrs(),
                                            **_attrs._canonicalize(attrs)}
        for key in self._attr_layer:
            _attrs.get_spec(key)
        # rebuild the effective config so field reads
        # (config.inject_max_bytes, ...) reflect the merged layer
        config_layer = {f: self._attr_layer[a]
                        for f, a in _FIELD_TO_ATTR.items()
                        if a in self._attr_layer}
        self.config = CommConfig(**config_layer)
        fabric_overrides = {k: v for k, v in
                            (("fabric_depth", fabric_depth),
                             ("link_latency", link_latency),
                             ("fabric_backend", fabric_backend))
                            if v is not None}
        # FABRIC_ATTRS includes fabric_backend: an unknown backend name
        # raises AttrError right here, at alloc time
        fr = _attrs.resolve(FABRIC_ATTRS, runtime=self._attr_layer,
                            overrides=fabric_overrides)
        rr = _attrs.resolve(RUNTIME_ATTRS, runtime=self._attr_layer)
        # the cluster-wide telemetry hub: every rank's runtime shares it
        # unless a per-rank config resolves a different level
        self.tele = Telemetry(rr["telemetry_level"], rr["trace_capacity"])
        self.fabric = make_transport(
            fr["fabric_backend"], n_ranks, depth=fr["fabric_depth"],
            latency=fr["link_latency"], resolved=fr,
            ring_bytes=fr["shm_ring_bytes"], **self._transport_extra())
        # chaos plane (DESIGN.md §16): an active chaos_* config wraps the
        # backend in the fault-injecting transport; the zero-fault
        # default returns the backend untouched
        cr = _attrs.resolve(CHAOS_ATTRS, runtime=self._attr_layer)
        self.fabric = maybe_wrap_chaos(self.fabric, cr)
        self.fabric.set_telemetry(self.tele)
        self._init_attrs(fr.merged(rr).merged(cr))
        self._export_attr("rank_n", lambda: self.n_ranks)
        self._export_attr("in_flight", self.fabric.in_flight)
        self._export_attr("telemetry", self.telemetry_snapshot)
        self.runtimes = [Runtime(r, self) for r in self._local_ranks()]

    def _transport_extra(self) -> Dict[str, Any]:
        """Extra make_transport kwargs; the base cluster is solo-mode (all
        ranks in-process), so cross-process identity stays unset."""
        return {}

    def _local_ranks(self):
        """Which ranks live in this process (all of them here)."""
        return range(self.n_ranks)

    def local_runtimes(self) -> List[Runtime]:
        return list(self.runtimes)

    def __getitem__(self, rank: int) -> Runtime:
        return self.runtimes[rank]

    def close(self) -> None:
        """Release transport OS resources (idempotent; a no-op for the
        in-process sim backend)."""
        self.fabric.close()

    def __enter__(self) -> "LocalCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def alloc_endpoint(self, n_devices: Optional[int] = None,
                       stripe: Optional[str] = None,
                       progress: Optional[str] = None,
                       name: str = "endpoint",
                       **overrides) -> List[Endpoint]:
        """Allocate a symmetric endpoint on every rank (device streams are
        matched by index, so peers must replicate the same bundle shape);
        returns the per-rank endpoints, indexed by rank."""
        return [rt.alloc_endpoint(n_devices, stripe, progress,
                                  name=f"{name}@{rt.rank}", **overrides)
                for rt in self.runtimes]

    def alloc_workers(self, n_workers: Optional[int] = None, *,
                      burst: Optional[int] = None) -> "ProgressWorkerPool":
        """A worker pool spanning every rank's devices — the paper's
        thread-mode testbed with real threads driving all progress."""
        n, b = _resolve_worker_args(self._attr_layer, n_workers, burst)
        return ProgressWorkerPool.for_cluster(self, n, burst=b)

    def telemetry_snapshot(self) -> Dict:
        """The cluster-wide telemetry document: every distinct hub across
        the local runtimes (ranks overriding ``telemetry_level`` own their
        own), merged elementwise — the same shape
        :func:`repro.core.telemetry.merge_snapshots` gives an SPMD
        aggregation, so local and multi-process reads are uniform."""
        teles = {id(self.tele): self.tele}
        for rt in self.local_runtimes():
            teles.setdefault(id(rt.tele), rt.tele)
        return merge_snapshots([t.snapshot() for t in teles.values()])

    def export_trace(self, path: str) -> str:
        """Dump the Chrome trace (``telemetry_level=trace`` runs)."""
        return self.tele.export_trace(path)

    def progress_all(self, rounds: int = 1) -> int:
        """Drive every device of every rank; returns #work events."""
        n = 0
        for _ in range(rounds):
            for rt in self.local_runtimes():
                for dev in rt.devices:
                    n += bool(rt.progress(dev))
        return n

    def quiesce(self, max_rounds: int = 10_000) -> None:
        """Progress until no work remains (test/benchmark helper)."""
        import time as _time
        rels = [rt.rel for rt in self.local_runtimes()
                if rt.rel is not None]
        for _ in range(max_rounds):
            if not self.progress_all():
                if self.fabric.in_flight() == 0 \
                        and not any(r.busy() for r in rels):
                    return
                # messages still on the (latency-modeled) wire, held by
                # the chaos stash, or waiting out a reliability backoff
                # timer: sleep rather than declaring quiet — rel backoff
                # needs a coarser tick than the latency model
                _time.sleep(max(self.fabric.latency / 4,
                                1e-4 if rels else 1e-5))
        raise FatalError("cluster failed to quiesce")


class ProcessCluster(LocalCluster):
    """One rank of an N-process SPMD job — the paper's *process mode*.

    Each OS process holds exactly one :class:`Runtime` (its rank) and a
    cross-process transport (``shm`` rings or ``socket`` frames) to its
    peers.  Construction mirrors :class:`LocalCluster`; ``rank`` and the
    shared ``session`` (a directory name both sides derive ring/socket
    paths from) normally arrive from :mod:`repro.launch.spmd` via the
    ``REPRO_SPMD_*`` environment, so benchmark code can build either
    cluster shape from the same attrs.

    ``runtimes`` maps rank → Runtime and holds only this process's rank;
    ``cluster[r]`` for a remote rank raises — remote state is another
    process's business.
    """

    def __init__(self, n_ranks: int, rank: int,
                 config: Optional[CommConfig] = None,
                 fabric_depth: Optional[int] = None,
                 link_latency: Optional[float] = None,
                 attrs: Optional[Mapping[str, Any]] = None,
                 fabric_backend: Optional[str] = None,
                 session: Optional[str] = None):
        if not 0 <= rank < n_ranks:
            raise FatalError(f"rank {rank} out of range for {n_ranks} ranks")
        self.rank_me = rank
        self._session = session
        super().__init__(n_ranks, config, fabric_depth, link_latency,
                         attrs, fabric_backend)
        self.runtimes = {rt.rank: rt for rt in self.runtimes}
        self._export_attr("rank_me", lambda: self.rank_me)

    def _transport_extra(self) -> Dict[str, Any]:
        return {"rank": self.rank_me, "session": self._session}

    def _local_ranks(self):
        return (self.rank_me,)

    def local_runtimes(self) -> List[Runtime]:
        return list(self.runtimes.values())

    @property
    def runtime(self) -> Runtime:
        """This process's one runtime."""
        return self.runtimes[self.rank_me]

    def __getitem__(self, rank: int) -> Runtime:
        if rank != self.rank_me:
            raise FatalError(
                f"rank {rank} lives in another process (this is rank "
                f"{self.rank_me}); only the local runtime is addressable")
        return self.runtimes[rank]


# -- module-level convenience (paper's g_runtime) ---------------------------

_g_cluster: Optional[LocalCluster] = None


def g_runtime_init(n_ranks: int = 1,
                   config: Optional[CommConfig] = None,
                   attrs: Optional[Mapping[str, Any]] = None
                   ) -> LocalCluster:
    global _g_cluster
    _g_cluster = LocalCluster(n_ranks, config, attrs=attrs)
    return _g_cluster


def g_runtime() -> LocalCluster:
    if _g_cluster is None:
        raise FatalError("g_runtime_init has not been called")
    return _g_cluster


def g_runtime_fina() -> None:
    global _g_cluster
    _g_cluster = None
